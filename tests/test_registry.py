"""Tests for the autotuned-op registry (repro.core.registry / .autotuned).

The core behavioural tests run without hypothesis (they back the PR's
acceptance criteria); the property-based sections are added only when
hypothesis is installed.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ATRegion,
    AutotunedOp,
    BasicParams,
    KernelSpec,
    ParamSpace,
    PerfParam,
    Registry,
    RuntimeSelector,
    TuningDB,
    Tuner,
    pp_key,
)

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property sections skip, core tests still run
    given = None


def _toy_spec(costs, calls, name="toy"):
    """A spec whose cost function counts its own invocations."""
    space = ParamSpace([PerfParam("i", tuple(range(len(costs))))])

    def cost_factory(region, bp, args, kwargs):
        def cost(point):
            calls.append(point["i"])
            return float(costs[point["i"]])

        return cost

    return KernelSpec(
        name,
        make_region=lambda bp: ATRegion(
            name, space, lambda p: (lambda x: x * p["i"])
        ),
        shape_class=lambda x: BasicParams.make(kernel=name, n=int(x.shape[0])),
        cost_factory=cost_factory,
    )


X = jnp.ones(4)


# ---------------------------------------------------------------------------
# Acceptance: cache hits perform zero cost evaluations
# ---------------------------------------------------------------------------


def test_second_call_same_shape_class_zero_evaluations():
    calls = []
    op = AutotunedOp(_toy_spec([3.0, 1.0, 2.0], calls), db=TuningDB())
    op(X)
    assert len(calls) == 3  # exhaustive first tune
    selected = dict(op.resolve(X).region.selected)
    op(X)
    assert len(calls) == 3  # in-process hit: no re-tune
    assert op.resolve(X).region.selected == selected == {"i": 1}


def test_distinct_shape_classes_tune_independently():
    calls = []
    op = AutotunedOp(_toy_spec([2.0, 1.0], calls), db=TuningDB())
    op(jnp.ones(4))
    op(jnp.ones(8))  # different bucket -> its own tuning
    assert len(calls) == 4
    assert len(op.states()) == 2


def test_db_hit_across_fresh_op_zero_evaluations(tmp_path):
    path = str(tmp_path / "db.json")
    calls = []
    spec = _toy_spec([5.0, 4.0, 1.0, 2.0], calls)
    AutotunedOp(spec, db=TuningDB(path))(X)
    assert len(calls) == 4
    # a fresh op + fresh DB object over the same file == a fresh process
    op2 = AutotunedOp(spec, db=TuningDB(path))
    state = op2.resolve(X)
    assert len(calls) == 4  # zero evaluations
    assert state.from_cache and state.region.selected == {"i": 2}


def test_db_persists_across_real_process(tmp_path):
    path = str(tmp_path / "db.json")
    calls = []
    spec = _toy_spec([5.0, 1.0, 2.0], calls)
    AutotunedOp(spec, db=TuningDB(path))(X)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""))
    code = (
        "from repro.core import TuningDB, BasicParams;"
        f"db = TuningDB({path!r});"
        "bp = BasicParams.make(kernel='toy', n=4);"
        "assert db.best_point(bp) == {'i': 1}, db.best_point(bp);"
        "print('ok')"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=120,
    )
    assert out.returncode == 0, out.stderr
    assert "ok" in out.stdout


def test_trial_budget_caps_evaluations_and_resumes(tmp_path):
    path = str(tmp_path / "db.json")
    calls = []
    spec = _toy_spec([5.0, 4.0, 3.0, 2.0, 1.0], calls)
    op = AutotunedOp(spec, db=TuningDB(path), trial_budget=2)
    op(X)
    assert len(calls) == 2  # budget respected
    assert op.resolve(X).region.selected == {"i": 1}  # interim argmin
    # a later run resumes: recorded trials are reused, budget buys new points
    op2 = AutotunedOp(spec, db=TuningDB(path), trial_budget=2)
    op2(X)
    assert len(calls) == 4
    assert op2.resolve(X).region.selected == {"i": 3}


def test_top_k_candidates_are_warmed():
    calls = []
    op = AutotunedOp(_toy_spec([4.0, 3.0, 2.0, 1.0], calls), db=TuningDB(), top_k=3)
    state = op.resolve(X)
    assert state.warmed == 3 and state.region.compiled_points() == 3
    ranked = sorted(op.db.trials(state.bp).items(), key=lambda kv: kv[1])[:3]
    for key, _ in ranked:
        assert state.region.is_compiled_key(key)


# ---------------------------------------------------------------------------
# Registry plumbing
# ---------------------------------------------------------------------------


def test_registry_register_get_and_duplicate_policy():
    reg = Registry()
    spec = _toy_spec([1.0], [], name="dup")
    reg.register(spec)
    assert reg.get("dup") is spec
    with pytest.raises(ValueError):
        reg.register(spec)
    reg.register(_toy_spec([2.0], [], name="dup"), replace=True)
    with pytest.raises(KeyError):
        reg.get("missing")


def test_registry_default_ops_are_cached_per_name():
    reg = Registry()
    reg.register(_toy_spec([1.0, 2.0], [], name="cached"))
    assert reg.op("cached") is reg.op("cached")
    assert reg.op("cached", top_k=1) is not reg.op("cached")


def test_global_registry_serves_pallas_kernels():
    from repro.core import REGISTRY

    names = REGISTRY.names(tag="pallas")
    assert set(names) >= {"exb", "flash_attention", "rglru_scan", "ssm_scan", "stress"}


# ---------------------------------------------------------------------------
# RuntimeSelector: demotion lands on the next-best precompiled candidate
# ---------------------------------------------------------------------------


def _demotion_case(costs, warm_indices, tolerance=1.5, window=4):
    space = ParamSpace([PerfParam("i", tuple(range(len(costs))))])
    region = ATRegion("r", space, lambda p: (lambda: p["i"]))
    db = TuningDB()
    bp = BasicParams.make(arch="t")
    Tuner(db).tune(region, bp, lambda p: float(costs[p["i"]]))
    for i in warm_indices:
        region.candidate({"i": i})
    sel = RuntimeSelector(region, bp, db, tolerance=tolerance, window=window)
    return region, db, bp, sel


def test_demotion_lands_on_next_best_precompiled():
    costs = [1.0, 5.0, 2.0, 4.0, 3.0]
    region, db, bp, sel = _demotion_case(costs, warm_indices=[0, 3, 4])
    assert region.selected == {"i": 0}
    for _ in range(4):
        switched = sel.observe(100.0)  # injected cost spike
    assert switched
    # next-best among the *warmed* candidates is i=4 (cost 3.0), even though
    # i=2 (cost 2.0) ranks higher overall — switching must never compile
    assert region.selected == {"i": 4}


def test_demotion_falls_back_to_ranking_when_nothing_warm():
    region, db, bp, sel = _demotion_case([1.0, 3.0, 2.0], warm_indices=[])
    for _ in range(4):
        sel.observe(100.0)
    assert region.selected == {"i": 2}  # best-ranked non-current


def test_no_demotion_without_regression():
    region, db, bp, sel = _demotion_case([1.0, 2.0], warm_indices=[0, 1])
    for _ in range(8):
        assert not sel.observe(1.0)  # at tuned cost: no switch
    assert region.selected == {"i": 0} and sel.switches == 0


# ---------------------------------------------------------------------------
# TuningDB: save/load round-trip, merge of concurrent writers
# ---------------------------------------------------------------------------


def test_db_save_load_roundtrip_exact(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDB()
    bp = BasicParams.make(arch="a", n=4)
    for i, c in enumerate([3.0, 1.5, 2.25]):
        db.record_trial(bp, {"i": i}, c, "before_execution")
    db.record_runtime_observation(bp, {"i": 1}, 1.6)
    db.save(path)
    loaded = TuningDB.load(path)
    assert loaded.trials(bp) == db.trials(bp)
    assert loaded.best_point(bp) == db.best_point(bp) == {"i": 1}
    assert loaded.best_cost(bp) == 1.5
    assert loaded.history(bp) == db.history(bp)


def test_db_merge_concurrent_writers(tmp_path):
    path = str(tmp_path / "db.json")
    bp_a = BasicParams.make(arch="a")
    bp_b = BasicParams.make(arch="b")
    w1 = TuningDB(path)
    w2 = TuningDB(path)  # opened before w1 writes: snapshot is empty
    w1.record_trial(bp_a, {"i": 0}, 2.0, "install")
    w2.record_trial(bp_b, {"j": 1}, 3.0, "install")  # merge-on-flush
    merged = TuningDB(path)
    assert merged.trial_cost(bp_a, {"i": 0}) == 2.0
    assert merged.trial_cost(bp_b, {"j": 1}) == 3.0


def test_db_reads_legacy_v1_layout(tmp_path):
    """Seed-era DBs (bare entries mapping, no envelope) still load."""
    path = str(tmp_path / "db.json")
    bp = BasicParams.make(arch="t")
    legacy = TuningDB(path)
    legacy.record_trial(bp, {"i": 1}, 2.0, "install")
    with open(path) as f:
        data = json.load(f)
    with open(path, "w") as f:
        json.dump(data["entries"], f)  # strip the envelope back to v1
    db = TuningDB(path)
    assert db.trial_cost(bp, {"i": 1}) == 2.0
    assert db.tuned_point(bp) is None  # v1 bests carry no final flag


def test_db_rejects_future_schema(tmp_path):
    path = str(tmp_path / "db.json")
    with open(path, "w") as f:
        json.dump({"schema_version": 99, "entries": {}}, f)
    with pytest.raises(ValueError, match="newer than supported"):
        TuningDB(path)


def test_merge_final_best_beats_lower_cost_interim():
    """A completed search's argmin must never be displaced by a lucky-low
    interim cost from a crashed sweep (record_trial's running best)."""
    bp = BasicParams.make(arch="a")
    done, crashed = TuningDB(), TuningDB()
    done.record_trial(bp, {"i": 0}, 2.0, "before_execution")
    done.record_best(bp, {"i": 0}, 2.0, "before_execution")  # final
    crashed.record_trial(bp, {"i": 1}, 1.0, "before_execution")  # interim only
    done.merge(crashed)
    assert done.tuned_point(bp) == {"i": 0}  # final survived
    assert done.trial_cost(bp, {"i": 1}) == 1.0  # trial still united
    # and symmetric: merging the final INTO the crashed view adopts it
    crashed.record_trial(bp, {"i": 0}, 2.0, "before_execution")
    crashed.merge(done)
    assert crashed.tuned_point(bp) == {"i": 0}


def test_flush_keeps_fresh_measurement_over_stale_disk_min(tmp_path):
    """Re-measuring a point must stick: flush reconciliation never lets a
    stale (optimistically low) on-disk cost overwrite the fresh value."""
    path = str(tmp_path / "db.json")
    bp = BasicParams.make(arch="a")
    old = TuningDB(path)
    old.record_trial(bp, {"i": 0}, 0.001, "install")  # stale lucky timing
    fresh = TuningDB(path)
    fresh.record_trial(bp, {"i": 0}, 5.0, "install")  # honest re-measure
    assert fresh.trial_cost(bp, {"i": 0}) == 5.0
    assert TuningDB(path).trial_cost(bp, {"i": 0}) == 5.0


def test_db_merge_keeps_min_cost_and_best():
    bp = BasicParams.make(arch="a")
    d1, d2 = TuningDB(), TuningDB()
    d1.record_trial(bp, {"i": 0}, 2.0, "install")
    d2.record_trial(bp, {"i": 0}, 1.0, "install")
    d2.record_trial(bp, {"i": 1}, 5.0, "install")
    d1.merge(d2)
    assert d1.trial_cost(bp, {"i": 0}) == 1.0
    assert d1.trial_cost(bp, {"i": 1}) == 5.0
    assert d1.best_point(bp) == {"i": 0} and d1.best_cost(bp) == 1.0


# ---------------------------------------------------------------------------
# Property-based sections (hypothesis only)
# ---------------------------------------------------------------------------

if given is not None:

    @settings(max_examples=15, deadline=None)
    @given(
        costs=st.lists(
            st.floats(min_value=0.01, max_value=100, allow_nan=False),
            min_size=2, max_size=8, unique=True,
        )
    )
    def test_property_cache_hit_identical_point_no_reeval(costs):
        calls = []
        op = AutotunedOp(_toy_spec(costs, calls), db=TuningDB())
        first = dict(op.resolve(X).region.selected)
        n = len(calls)
        assert first == {"i": int(np.argmin(costs))}
        for _ in range(3):
            assert dict(op.resolve(X).region.selected) == first
        assert len(calls) == n

    @settings(max_examples=15, deadline=None)
    @given(
        trials=st.dictionaries(
            st.integers(0, 30),
            st.floats(min_value=1e-6, max_value=1e6, allow_nan=False),
            min_size=1, max_size=12,
        ),
        arch=st.sampled_from(["a", "b", "c"]),
    )
    def test_property_db_roundtrip(tmp_path_factory, trials, arch):
        path = str(tmp_path_factory.mktemp("db") / "db.json")
        db = TuningDB()
        bp = BasicParams.make(arch=arch)
        for i, c in trials.items():
            db.record_trial(bp, {"i": i}, c, "before_execution")
        db.save(path)
        loaded = TuningDB.load(path)
        assert loaded.trials(bp) == db.trials(bp)
        assert loaded.best_point(bp) == db.best_point(bp)
        assert loaded.best_cost(bp) == db.best_cost(bp)

    @settings(max_examples=20, deadline=None)
    @given(
        costs=st.lists(
            st.floats(min_value=0.01, max_value=100, allow_nan=False),
            min_size=2, max_size=10, unique=True,
        ),
        data=st.data(),
    )
    def test_property_demotion_always_lands_on_best_warm(costs, data):
        n = len(costs)
        warm = data.draw(
            st.sets(st.integers(0, n - 1), min_size=0, max_size=n)
        )
        region, db, bp, sel = _demotion_case(costs, warm_indices=sorted(warm))
        current = dict(region.selected)
        for _ in range(4):
            sel.observe(1e9)
        others = [i for i in range(n) if {"i": i} != current]
        warm_others = [i for i in others if i in warm]
        pool = warm_others or others
        expected = min(pool, key=lambda i: costs[i])
        assert region.selected == {"i": expected}
