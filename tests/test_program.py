"""Whole-program joint autotuning tests (docs/program.md).

Covers: flatten/unflatten round trips, the program fingerprint (member BPs,
PP-space signatures, extra entries), JointSearch's two pinned properties —
with per-member k = |space| and no cap it reduces to the exhaustive joint
argmin, and the joint winner is never worse than the per-kernel-greedy
composition on the same measured cost — the capped/coordinate-descent path,
persistence (a second tune of the same composition performs zero
evaluations and hot-applies the recalled winner through ``region.select``),
per-member survivor staging, and the Trainer/Server integrations.
"""
import itertools

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property sections skip, unit tests still run
    given = None

from repro.core import (
    ATRegion,
    BasicParams,
    JointSearch,
    ParamSpace,
    PerfParam,
    ProgramMember,
    ProgramSpec,
    Tuner,
    TuningDB,
    flatten_assignment,
    pp_key,
    unflatten_point,
)


def _member(name, domain, prescreen=None, db_bp=True):
    region = ATRegion(
        name, ParamSpace([PerfParam("v", tuple(domain))]), lambda p: (lambda: p)
    )
    bp = BasicParams.make(kernel=f"member_{name}") if db_bp else None
    return ProgramMember(name, region, bp=bp, prescreen=prescreen)


def _table_cost(table):
    def cost(point, budget=None):
        return table[(point["a.v"], point["b.v"])]

    return cost


def _program(domains=((0, 1, 2), (0, 1, 2)), db=None, **kw):
    return ProgramSpec(
        "prog",
        [_member("a", domains[0]), _member("b", domains[1])],
        db=db or TuningDB(),
        **kw,
    )


# ---------------------------------------------------------------------------
# plumbing: flatten/unflatten, fingerprint, joint space
# ---------------------------------------------------------------------------


def test_flatten_unflatten_roundtrip():
    a = {"m1": {"x": 1, "y": "s"}, "m2": {"z": (2, 3)}}
    assert unflatten_point(flatten_assignment(a)) == a


def test_member_name_rejects_separator():
    region = ATRegion("r", ParamSpace([PerfParam("v", (1,))]), lambda p: p)
    with pytest.raises(ValueError):
        ProgramMember("bad.name", region)


def test_fingerprint_sensitive_to_members_domains_and_extra():
    fp = _program().fingerprint().fingerprint()
    assert _program(domains=((0, 1), (0, 1, 2))).fingerprint().fingerprint() != fp
    assert _program(extra={"batch": 8}).fingerprint().fingerprint() != fp
    assert _program().fingerprint().fingerprint() == fp  # deterministic


def test_joint_space_is_member_product_with_feasibility():
    constrained = ParamSpace(
        [PerfParam("v", (0, 1, 2))], constraint=lambda p: p["v"] != 1
    )
    region = ATRegion("a", constrained, lambda p: p)
    prog = ProgramSpec(
        "p", [ProgramMember("a", region), _member("b", (0, 1))], db=TuningDB()
    )
    pts = list(prog.joint_space().points())
    assert len(pts) == 4  # (3 - 1 infeasible) x 2
    assert all(p["a.v"] != 1 for p in pts)


# ---------------------------------------------------------------------------
# JointSearch properties
# ---------------------------------------------------------------------------


def _joint_argmin(table):
    return min(table, key=table.get)


if given is not None:

    @settings(max_examples=30, deadline=None)
    @given(
        costs=st.lists(
            st.floats(min_value=0.01, max_value=100, allow_nan=False),
            min_size=9, max_size=9, unique=True,
        )
    )
    def test_property_full_k_no_cap_is_exhaustive_joint_argmin(costs):
        """Satellite property: k=|space|, cap=None == exhaustive argmin."""
        table = {
            (x, y): c
            for (x, y), c in zip(itertools.product(range(3), range(3)), costs)
        }
        prog = _program(db=TuningDB())
        result = prog.tune(cost=_table_cost(table), k=None, cap=None)
        best = _joint_argmin(table)
        assert (result.point["a.v"], result.point["b.v"]) == best
        assert result.cost == table[best]
        assert result.evaluations == 9  # every joint candidate measured once

    @settings(max_examples=30, deadline=None)
    @given(
        costs=st.lists(
            st.floats(min_value=0.01, max_value=100, allow_nan=False),
            min_size=16, max_size=16, unique=True,
        ),
        cap=st.integers(min_value=2, max_value=8),
        k=st.integers(min_value=1, max_value=4),
    )
    def test_property_joint_never_worse_than_greedy(costs, cap, k):
        """Satellite property: joint winner <= greedy composition, always —
        under every pruning regime (any k, any cap), because the greedy
        composition is always evaluated as the search's starting incumbent.
        """
        table = {
            (x, y): c
            for (x, y), c in zip(itertools.product(range(4), range(4)), costs)
        }
        db = TuningDB()
        prog = _program(domains=((0, 1, 2, 3), (0, 1, 2, 3)), db=db)
        # greedy: tune each member alone with the other at its default (0)
        Tuner(db).tune(prog.members[0].region, prog.members[0].bp,
                       lambda p: table[(p["v"], 0)], select=False)
        Tuner(db).tune(prog.members[1].region, prog.members[1].bp,
                       lambda p: table[(0, p["v"])], select=False)
        greedy = prog.greedy_composition()
        greedy_cost = table[(greedy["a"]["v"], greedy["b"]["v"])]
        result = prog.tune(cost=_table_cost(table), k=k, cap=cap)
        assert result.cost <= greedy_cost
        # and the winner is a real table entry, not an invented point
        assert result.cost == table[(result.point["a.v"], result.point["b.v"])]


def test_joint_beats_greedy_on_interaction_cost():
    """The motivating case: separable-greedy provably misses the optimum."""
    table = {(0, 0): 1.0, (0, 1): 1.2, (1, 0): 1.2, (1, 1): 0.7}
    db = TuningDB()
    prog = _program(domains=((0, 1), (0, 1)), db=db)
    Tuner(db).tune(prog.members[0].region, prog.members[0].bp,
                   lambda p: table[(p["v"], 0)], select=False)
    Tuner(db).tune(prog.members[1].region, prog.members[1].bp,
                   lambda p: table[(0, p["v"])], select=False)
    greedy = prog.greedy_composition()
    assert (greedy["a"]["v"], greedy["b"]["v"]) == (0, 0)
    result = prog.tune(cost=_table_cost(table), cap=None)
    assert (result.point["a.v"], result.point["b.v"]) == (1, 1)
    assert result.cost < table[(0, 0)]


def test_capped_search_stays_within_budget_and_descends():
    domains = (tuple(range(6)), tuple(range(6)))
    table = {
        (x, y): 1.0 + abs(x - 4) + abs(y - 3) + (0.5 if (x + y) % 2 else 0.0)
        for x in domains[0] for y in domains[1]
    }
    prog = _program(domains=domains, db=TuningDB())
    result = prog.tune(cost=_table_cost(table), cap=10)
    assert result.evaluations <= 20  # hard stop: 2x cap
    # coordinate descent over a separable-ish cost reaches near the optimum
    assert result.cost <= table[(0, 0)]


def test_joint_search_skips_infeasible_candidates():
    space = ParamSpace(
        [PerfParam("a.v", (0, 1)), PerfParam("b.v", (0, 1))],
        constraint=lambda p: not (p["a.v"] == 1 and p["b.v"] == 1),
    )
    search = JointSearch(
        groups=[("a", [{"a.v": 0}, {"a.v": 1}]), ("b", [{"b.v": 0}, {"b.v": 1}])],
        cap=None,
    )
    table = {(0, 0): 3.0, (0, 1): 2.0, (1, 0): 1.5, (1, 1): 0.1}
    result = search.run(space, lambda p: table[(p["a.v"], p["b.v"])])
    assert (result.best.point["a.v"], result.best.point["b.v"]) == (1, 0)
    assert result.evaluations == 3  # the infeasible (1,1) was never costed


def test_finals_also_run_in_exhaustive_branch():
    """The recorded winner rests on finals-budget measurements even when the
    whole product was measured (one lucky min_repeats=1 timing must not be
    recalled forever)."""
    calls = []

    def cost(point, budget=None):
        calls.append(budget)
        return float(point["a.v"] + point["b.v"])

    cost.supports_budget = True
    prog = _program(domains=((0, 1), (0, 1)), db=TuningDB())
    prog.tune(cost=cost, cap=None, final_k=2, finals_budget=3)
    assert calls.count(3) == 2  # both leaders re-measured at the finals budget


def test_finals_winner_is_always_refined():
    """Refinement can raise the leaders past an unrefined candidate; the
    loop must then refine that candidate too rather than crown a winner
    whose cost rests on one untrusted timing."""
    base = {0: 1.00, 1: 1.05, 2: 1.10, 3: 1.20, 4: 1.30}
    refined = {0: 1.25, 1: 1.28, 2: 1.30, 3: 1.50, 4: 1.60}
    budget_calls = []

    def cost(point, budget=None):
        v = point["a.v"]
        if budget is not None and budget > 1:
            budget_calls.append(v)
            return refined[v]
        return base[v]

    cost.supports_budget = True
    prog = ProgramSpec("p", [_member("a", (0, 1, 2, 3, 4))], db=TuningDB())
    result = prog.tune(cost=cost, cap=None, final_k=3, finals_budget=2)
    # leaders 0,1,2 refined upward past unrefined 3 (1.20): 3 must then be
    # refined as well, after which refined 0 (1.25) wins
    assert 3 in budget_calls
    assert result.point == {"a.v": 0}
    assert result.cost == 1.25


def test_force_retune_remeasures_recorded_trials():
    """force=True must produce fresh measurements, not recycle the trial
    cache (the machine may have changed since the recorded sweep)."""
    measured = []

    def cost(point, budget=None):
        measured.append((point["a.v"], point["b.v"], budget))
        return float(point["a.v"] + point["b.v"]) + 0.1

    cost.supports_budget = True
    db = TuningDB()
    prog = _program(domains=((0, 1), (0, 1)), db=db)
    prog.tune(cost=cost, cap=None)
    n = len(measured)
    assert n >= 4
    prog.tune(cost=cost, cap=None, force=True)
    fresh = measured[n:]
    assert len(fresh) >= 4                      # everything re-measured
    assert all(b is not None for b in fresh)    # via the cache-bypass path


def test_head_is_lazy_rank_sum_prefix():
    """_head yields the same rank-sum prefix as the full sorted product,
    without materializing the product — a huge survivor cross product must
    not blow up before the first measurement."""
    groups = [
        ("a", [{"a.v": i} for i in range(16)]),
        ("b", [{"b.v": i} for i in range(16)]),
        ("c", [{"c.v": i} for i in range(16)]),
    ]
    search = JointSearch(groups, cap=8)
    head = search._head(10)
    sums = [p["a.v"] + p["b.v"] + p["c.v"] for p in head]
    assert sums == sorted(sums)      # nondecreasing rank-sum order
    assert sums[0] == 0 and len(head) == 10
    assert search.product_size() == 16 ** 3

    # and a capped tune over the 4096-point product stays within budget
    space = ParamSpace([
        PerfParam("a.v", tuple(range(16))),
        PerfParam("b.v", tuple(range(16))),
        PerfParam("c.v", tuple(range(16))),
    ])
    result = search.run(
        space, lambda p: float(p["a.v"] + p["b.v"] + p["c.v"] + 1)
    )
    assert result.evaluations <= 16  # 2x cap hard stop
    assert result.best.cost == 1.0


def test_finals_remeasure_with_budget_aware_cost():
    calls = []

    def cost(point, budget=None):
        calls.append((point["a.v"], point["b.v"], budget))
        return float(point["a.v"] + point["b.v"])

    cost.supports_budget = True
    domains = (tuple(range(5)), tuple(range(5)))
    prog = _program(domains=domains, db=TuningDB())
    prog.tune(cost=cost, cap=8, final_k=2, finals_budget=3)
    assert [c for c in calls if c[2] == 3]  # finals re-measured at budget 3


# ---------------------------------------------------------------------------
# staging: survivors and prescreens
# ---------------------------------------------------------------------------


def test_survivors_rank_by_prescreen_and_keep_greedy():
    prescreen = lambda p: {0: 3.0, 1: 1.0, 2: 2.0}[p["v"]]  # noqa: E731
    m = _member("a", (0, 1, 2), prescreen=prescreen)
    prog = ProgramSpec("p", [m, _member("b", (0,))], db=TuningDB())
    groups, prescreen_evals = prog.survivors(k=2)
    assert prescreen_evals == 3
    ranked = [p["a.v"] for p in dict(groups)["a"]]
    # top-2 by prescreen (1 then 2), with the pruned greedy/default point
    # re-inserted at the front — it is never dropped
    assert ranked == [0, 1, 2]


def test_survivors_prefer_recorded_member_trials_over_prescreen():
    db = TuningDB()
    boom = lambda p: 1 / 0  # noqa: E731  (must never be called)
    m = _member("a", (0, 1, 2), prescreen=boom)
    prog = ProgramSpec("p", [m, _member("b", (0,))], db=db)
    Tuner(db).tune(m.region, m.bp, lambda p: {0: 5.0, 1: 0.5, 2: 2.0}[p["v"]],
                   select=False)
    groups, prescreen_evals = prog.survivors(k=2)
    assert prescreen_evals == 0
    assert [p["a.v"] for p in dict(groups)["a"]][0] == 1


def test_member_from_op_resolves_without_tuning():
    from repro.core import AutotunedOp, KernelSpec

    calls = []
    space = ParamSpace([PerfParam("i", (0, 1, 2))])
    spec = KernelSpec(
        "prog_from_op_toy",
        make_region=lambda bp: ATRegion(
            "prog_from_op_toy", space, lambda p: (lambda x: x * p["i"])
        ),
        shape_class=lambda x: BasicParams.make(
            kernel="prog_from_op_toy", n=int(x.shape[0])
        ),
        cost_factory=lambda r, b, a, k: (
            lambda p: calls.append(p["i"]) or float(p["i"]) + 1
        ),
        prescreen_factory=lambda r, b, a, k: (lambda p: float(p["i"])),
    )
    op = AutotunedOp(spec, db=TuningDB())
    x = jnp.ones(4)
    member = ProgramMember.from_op("toy", op, x)
    assert not calls                       # building a member never tunes
    assert member.bp["kernel"] == "prog_from_op_toy"
    assert member.prescreen is not None    # spec prescreen adopted
    assert member.args == (x,)
    prog = ProgramSpec("p", [member], db=op.db)
    result = prog.tune(cost=lambda pt, budget=None: float(pt["toy.i"]) + 1,
                       cap=None)
    assert result.point == {"toy.i": 0}
    assert member.region.selected == {"i": 0}


# ---------------------------------------------------------------------------
# persistence + hot apply
# ---------------------------------------------------------------------------


def test_recalled_winner_zero_evaluations_and_hot_applies(tmp_path):
    path = str(tmp_path / "db.json")
    table = {(0, 0): 1.0, (0, 1): 1.2, (1, 0): 1.2, (1, 1): 0.7}
    evals = []

    def cost(point, budget=None):
        evals.append(1)
        return table[(point["a.v"], point["b.v"])]

    prog = _program(domains=((0, 1), (0, 1)), db=TuningDB(path))
    r1 = prog.tune(cost=cost, cap=None)
    n = len(evals)
    # a fresh ProgramSpec over a fresh DB object on the same file == a
    # fresh process: the winner is recalled by program fingerprint
    prog2 = _program(domains=((0, 1), (0, 1)), db=TuningDB(path))
    r2 = prog2.tune(cost=cost, cap=None)
    assert r2.from_cache and len(evals) == n
    assert r2.point == r1.point
    # hot apply went through region.select on every member
    assert prog2.members[0].region.selected == {"v": 1}
    assert prog2.members[1].region.selected == {"v": 1}


def test_changed_domain_invalidates_recalled_winner(tmp_path):
    path = str(tmp_path / "db.json")
    cost = _table_cost({(x, y): float(x + y + 1) for x in range(3) for y in range(3)})
    _program(domains=((0, 1), (0, 1)), db=TuningDB(path)).tune(cost=cost, cap=None)
    prog2 = _program(domains=((0, 1, 2), (0, 1)), db=TuningDB(path))
    r2 = prog2.tune(cost=cost, cap=None)
    assert not r2.from_cache  # new domain -> new fingerprint -> fresh search


def test_apply_invokes_on_apply_with_assignment():
    seen = []
    prog = _program(on_apply=lambda a: seen.append(a))
    prog.apply({"a.v": 2, "b.v": 1})
    assert seen == [{"a": {"v": 2}, "b": {"v": 1}}]
    assert prog.members[0].region.selected == {"v": 2}
    # assignment form works too
    prog.apply({"a": {"v": 0}, "b": {"v": 0}})
    assert prog.members[0].region.selected == {"v": 0}


def test_tune_resumes_from_recorded_trials(tmp_path):
    """Interrupted joint sweeps resume: recorded trials are not re-measured."""
    path = str(tmp_path / "db.json")
    table = {(x, y): float(10 - x - y) for x in range(2) for y in range(2)}
    evals = []

    def cost(point, budget=None):
        evals.append(1)
        return table[(point["a.v"], point["b.v"])]

    db = TuningDB(path)
    prog = _program(domains=((0, 1), (0, 1)), db=db)
    # pre-record two of the four trials under the program fingerprint, as an
    # interrupted run would have
    bp = prog.fingerprint()
    db.record_trial(bp, {"a.v": 0, "b.v": 0}, 10.0, "before_execution")
    db.record_trial(bp, {"a.v": 0, "b.v": 1}, 9.0, "before_execution")
    prog.tune(cost=cost, cap=None)
    assert len(evals) == 2  # only the unrecorded half was measured


# ---------------------------------------------------------------------------
# integrations: Trainer and Server
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_cfg():
    from repro.configs import get_config

    return get_config("tinyllama-1.1b", smoke=True)


def test_trainer_joint_tune_end_to_end(smoke_cfg):
    from repro.data import SyntheticLMDataset
    from repro.optim import AdamWConfig
    from repro.runtime import Trainer, TrainLoopConfig

    db = TuningDB()
    loop = TrainLoopConfig(
        total_steps=1, n_microbatches=1, microbatch_candidates=(1, 2),
        joint_tune=True,
    )
    trainer = Trainer(
        smoke_cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10),
        loop, tuning_db=db,
    )
    ds = SyntheticLMDataset(smoke_cfg, global_batch=4, seq_len=16, seed=7)
    hist = trainer.run(ds)
    assert len(hist["loss"]) == 1
    r = trainer.joint_result
    assert r is not None and not r.from_cache
    assert set(r.assignment) == {"micro", "remat"}
    # the live region adopted the winner through region.select
    assert trainer.region.selected == {
        "n_micro": r.assignment["micro"]["n_micro"]
    }
    assert trainer._step_remat == r.assignment["remat"]["remat"]

    # a second trainer over the same DB recalls the winner with zero evals
    trainer2 = Trainer(
        smoke_cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10),
        loop, tuning_db=db,
    )
    r2 = trainer2.joint_tune(ds)
    assert r2.from_cache and r2.assignment == r.assignment
    assert trainer2.region.selected == trainer.region.selected


def test_server_joint_tune_end_to_end(smoke_cfg):
    from repro.data import synthetic_requests
    from repro.models import init_params, param_specs
    from repro.runtime import Server

    params = init_params(jax.random.PRNGKey(0), param_specs(smoke_cfg))
    db = TuningDB()
    server = Server(smoke_cfg, params, batch_size=4, max_len=32, tuning_db=db)
    reqs = synthetic_requests(smoke_cfg, 4, 8, 4)
    r = server.joint_tune(reqs, decode_steps=2)
    assert set(r.assignment) == {"prefill", "decode"}
    assert not r.from_cache and r.evaluations >= 1
    # winners mirrored into the degree controller per traffic label
    labels = server.traffic_classes_seen
    assert labels  # prefill + decode classes resolved
    out = server.run(reqs)
    assert len(out) == 4
    # recall on the same composition
    r2 = server.joint_tune(reqs, decode_steps=2)
    assert r2.from_cache and r2.assignment == r.assignment
