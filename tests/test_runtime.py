"""Integration tests: training loop fault tolerance, checkpointing, serving,
data determinism, optimizer correctness."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import SyntheticLMDataset, synthetic_requests
from repro.optim import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.runtime import Server, SimulatedFailure, Trainer, TrainLoopConfig

KEY = jax.random.PRNGKey(0)
SMOKE = get_config("tinyllama-1.1b", smoke=True)


def _loop_cfg(tmp_path, **kw):
    d = dict(
        total_steps=6, log_every=100, ckpt_dir=str(tmp_path / "ckpt"),
        save_every=2, n_microbatches=1, microbatch_candidates=(1, 2),
    )
    d.update(kw)
    return TrainLoopConfig(**d)


def _opt_cfg():
    return AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)


# ---------------------------------------------------------------------------
# Data pipeline: pure in (seed, step); host sharding partitions the batch
# ---------------------------------------------------------------------------


def test_dataset_determinism_and_sharding():
    ds = SyntheticLMDataset(SMOKE, global_batch=4, seq_len=32, seed=7)
    a = ds.batch(3)
    b = ds.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host shards tile the global batch
    h0 = ds.batch(3, host_id=0, n_hosts=2)
    h1 = ds.batch(3, host_id=1, n_hosts=2)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), a["tokens"]
    )
    np.testing.assert_array_equal(a["targets"][:, :-1], a["tokens"][:, 1:])


# ---------------------------------------------------------------------------
# Optimizer: descends a convex quadratic; clip and schedule behave
# ---------------------------------------------------------------------------


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_grad_clip_and_schedule():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(
        cfg.min_lr_ratio, rel=1e-2
    )
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params, cfg)
    _, _, metrics = adamw_update({"w": jnp.full(3, 1e6)}, state, params, cfg)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


def test_adamw_bf16_moment_compression():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    params2, state2, _ = adamw_update({"w": jnp.ones(4, jnp.bfloat16)}, state, params, cfg)
    assert state2["v"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Checkpointing: atomic roundtrip, rotation, reshard-on-load
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray(3, jnp.int32)}}
    path = save_checkpoint(str(tmp_path), 42, tree)
    step, restored = load_checkpoint(path, tree)
    assert step == 42
    np.testing.assert_array_equal(restored["a"], tree["a"])
    assert int(restored["b"]["c"]) == 3


def test_checkpoint_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=2)
    tree = {"x": jnp.zeros(2)}
    for s in range(1, 6):
        mgr.maybe_save(s, tree, force=True)
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000004", "step_00000005"]


# ---------------------------------------------------------------------------
# Training loop: convergence, restart determinism, failure injection
# ---------------------------------------------------------------------------


def test_train_loop_runs_and_loss_finite(tmp_path):
    trainer = Trainer(SMOKE, _opt_cfg(), _loop_cfg(tmp_path))
    ds = SyntheticLMDataset(SMOKE, global_batch=2, seq_len=32)
    hist = trainer.run(ds)
    assert len(hist["loss"]) == 6
    assert all(np.isfinite(l) for l in hist["loss"])


def test_failure_recovery_resumes_from_checkpoint(tmp_path):
    """Kill the job at step 4; the restarted loop must resume from the step-4
    checkpoint (not step 0) and finish with a loss trajectory identical to an
    uninterrupted run (determinism = the fault-tolerance contract)."""
    ds = SyntheticLMDataset(SMOKE, global_batch=2, seq_len=32)

    ref = Trainer(SMOKE, _opt_cfg(), _loop_cfg(tmp_path / "ref")).run(ds)

    fired = []

    def failure_hook(step):
        if step == 4 and not fired:
            fired.append(step)
            raise SimulatedFailure("node lost")

    trainer = Trainer(SMOKE, _opt_cfg(), _loop_cfg(tmp_path / "ft"))
    hist = trainer.run(ds, failure_hook=failure_hook)
    assert trainer.restarts == 1
    # steps 4..5 re-run after restore from the step-4 checkpoint
    assert hist["step"][-1] == 5
    np.testing.assert_allclose(hist["loss"][-1], ref["loss"][-1], rtol=1e-4)


def test_microbatch_degrees_agree(tmp_path):
    """Gradient accumulation (the degree PP) must not change the math."""
    from repro.models import param_specs, init_params
    from repro.runtime.train import make_train_step

    params = init_params(KEY, param_specs(SMOKE))
    opt = adamw_init(params, _opt_cfg())
    ds = SyntheticLMDataset(SMOKE, global_batch=4, seq_len=32)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}

    p1, _, m1 = jax.jit(make_train_step(SMOKE, _opt_cfg(), 1))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(SMOKE, _opt_cfg(), 2))(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2,
            atol=2e-2,
        )


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def test_server_generates_deterministically():
    from repro.models import init_params, param_specs

    params = init_params(KEY, param_specs(SMOKE))
    server = Server(SMOKE, params, batch_size=2, max_len=64)
    reqs = synthetic_requests(SMOKE, n=3, prompt_len=8, max_new_tokens=5)
    out = server.run(reqs)
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 5 for v in out.values())
    out2 = Server(SMOKE, params, batch_size=2, max_len=64).run(reqs)
    assert out == out2  # greedy decode is deterministic
    assert server.stats.tokens_out >= 15
