"""Fleet tuning control plane tests (ISSUE 5, docs/fleet.md).

Covers: DeviceFingerprint BP composition + device-scoped recall on
AutotunedOp, ParamSpace.shard partition invariants, the fleet-equivalence
acceptance bar (N-worker sharded search == single-process exhaustive for
any N and shard policy, merged DB independent of merge order), the spawn
backend, FleetSearch through Tuner and BackgroundTuner, and the full drift
lifecycle (injected regression -> demote -> background re-tune -> canary ->
promote / rollback, every transition in the persisted event log).
"""
import json
import time

import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property sections skip, unit tests still run
    given = None

from repro.core import (
    ATRegion,
    AutotunedOp,
    BasicParams,
    KernelSpec,
    ParamSpace,
    PerfParam,
    Tuner,
    TuningDB,
    pp_key,
)
from repro.fleet import (
    DeviceFingerprint,
    DriftMonitor,
    FleetCoordinator,
    device_bp_entries,
    local_device,
)
from repro.fleet.workloads import demo_cost, demo_space
from repro.runtime import BackgroundTuner

X = jnp.ones((4,))


def _toy_spec(costs, name="fleet_toy", calls=None):
    """A kernel with len(costs) candidates and controllable measured costs.

    ``costs`` may be mutated by the test to inject a runtime regression.
    """
    def make_region(bp):
        return ATRegion(
            name,
            ParamSpace([PerfParam("i", tuple(range(len(costs))))]),
            instantiate=lambda pt: (lambda x: x + pt["i"]),
        )

    def cost_factory(region, bp, args, kwargs):
        def cost(point):
            if calls is not None:
                calls.append(dict(point))
            return costs[point["i"]]

        return cost

    return KernelSpec(
        name=name,
        make_region=make_region,
        shape_class=lambda x: BasicParams.make(kernel=name, n=int(x.shape[0])),
        cost_factory=cost_factory,
    )


# ---------------------------------------------------------------------------
# Device fingerprinting
# ---------------------------------------------------------------------------


def test_device_fingerprint_roundtrip_and_label():
    df = DeviceFingerprint(
        backend="tpu", platform="TPU v5e", device_count=4,
        host_cores=8, memory_gib=16, schema=2,
    )
    entries = df.bp_entries()
    assert set(entries) == set(DeviceFingerprint.BP_KEYS)
    assert DeviceFingerprint.from_bp_entries(entries) == df
    assert df.label == "tpu/TPU_v5ex4/c8/m16g/v2"


def test_local_device_detected_once_and_composes():
    a, b = local_device(), local_device()
    assert a is b  # cached per process
    bp = BasicParams.make(kernel="k").with_entries(**device_bp_entries())
    assert bp["device_backend"] == a.backend
    # composing twice is idempotent (same fingerprint)
    again = bp.with_entries(**device_bp_entries())
    assert again.fingerprint() == bp.fingerprint()


def test_device_key_namespaces_the_db():
    """The same call tunes under different fingerprints with/without
    device_key, and a device-keyed DB answers the devices() query."""
    costs = [3.0, 1.0, 2.0]
    db = TuningDB()
    plain = AutotunedOp(_toy_spec(costs), db=db, warm=False, device_key=False)
    keyed = AutotunedOp(_toy_spec(costs), db=db, warm=False, device_key=True)
    s_plain, s_keyed = plain.resolve(X), keyed.resolve(X)
    assert s_plain.bp.fingerprint() != s_keyed.bp.fingerprint()
    assert s_keyed.bp["device_backend"] == local_device().backend
    assert [d.label for d in db.devices()] == [local_device().label]
    # both recall their own final with zero evaluations in a fresh op
    for op_kwargs, bp in ((dict(device_key=False), s_plain.bp),
                          (dict(device_key=True), s_keyed.bp)):
        fresh = AutotunedOp(_toy_spec(costs), db=db, warm=False, **op_kwargs)
        st2 = fresh.resolve(X)
        assert st2.from_cache and st2.cost_evaluations == 0
        assert st2.bp.fingerprint() == bp.fingerprint()


def test_foreign_device_final_not_recalled_but_warm_starts():
    """A final tuned on a *different* device must not be adopted verbatim;
    it is still reachable as a nearest-device warm-start seed."""
    costs = [3.0, 1.0, 2.0]
    db = TuningDB()
    foreign = DeviceFingerprint(
        backend="tpu", platform="TPU v5e", device_count=8,
        host_cores=64, memory_gib=128, schema=2,
    )
    foreign_bp = BasicParams.make(kernel="fleet_toy", n=4).with_entries(
        **device_bp_entries(foreign)
    )
    db.record_best(foreign_bp, {"i": 2}, 0.5, "before_execution")

    calls = []
    op = AutotunedOp(_toy_spec(costs, calls=calls), db=db, warm=False,
                     device_key=True)
    state = op.resolve(X)
    # not adopted verbatim: this device measured its own candidates
    assert state.from_cache is False and state.cost_evaluations > 0
    assert state.region.selected == {"i": 1}  # the local argmin
    # ...but the foreign final seeded the search (warm start)
    assert state.warm_seed == {"i": 2}
    near = db.nearest_tuned(state.bp)
    assert near is not None and near["point"] == {"i": 2}
    assert near["distance"] > 0  # device mismatch costs distance


# ---------------------------------------------------------------------------
# Shard protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["stride", "block"])
@pytest.mark.parametrize("n", [1, 2, 3, 7, 50])
def test_shard_partitions_every_point_exactly_once(policy, n):
    space = ParamSpace(
        [PerfParam("a", tuple(range(5))), PerfParam("b", tuple(range(3)))],
        constraint=lambda p: (p["a"] + p["b"]) % 4 != 0,
    )
    all_keys = sorted(pp_key(p) for p in space.points())
    shards = space.shard(n, policy)
    assert 1 <= len(shards) <= n
    sharded = sorted(
        pp_key(p) for shard in shards for p in shard.points()
    )
    assert sharded == all_keys  # a partition: no loss, no duplication


def test_shard_rejects_bad_inputs():
    space = ParamSpace([PerfParam("a", (1, 2))])
    with pytest.raises(ValueError, match="shard count"):
        space.shard(0)
    with pytest.raises(ValueError, match="policy"):
        space.shard(2, "roundrobin")


# ---------------------------------------------------------------------------
# Fleet equivalence (the acceptance bar)
# ---------------------------------------------------------------------------


if given is not None:

    @settings(max_examples=25, deadline=None)
    @given(
        costs=st.lists(
            st.floats(min_value=0.01, max_value=100, allow_nan=False),
            min_size=2, max_size=18, unique=True,
        ),
        workers=st.integers(1, 6),
        policy=st.sampled_from(["stride", "block"]),
        sync_every=st.sampled_from([0, 1, 3]),
    )
    def test_fleet_winner_equals_single_process_winner(
        costs, workers, policy, sync_every
    ):
        """For a deterministic cost, the N-worker sharded search returns the
        single-process exhaustive winner for ANY N and shard policy."""
        space = ParamSpace([PerfParam("i", tuple(range(len(costs))))])
        cost = lambda p: costs[p["i"]]  # noqa: E731
        bp = BasicParams.make(kernel="eq")
        fleet = FleetCoordinator(
            workers=workers, shard_policy=policy, sync_every=sync_every
        ).search(space, cost, bp=bp)
        expected = min(range(len(costs)), key=costs.__getitem__)
        assert fleet.best.point == {"i": expected}
        assert fleet.best.cost == costs[expected]
        # every candidate measured exactly once across the fleet
        assert fleet.evaluations == len(costs)
        assert fleet.merged.tuned_point(bp) == {"i": expected}

    @settings(max_examples=15, deadline=None)
    @given(
        costs=st.lists(
            st.sampled_from([0.5, 1.0, 2.0, 4.0]),
            min_size=3, max_size=12,
        ),
        split=st.integers(1, 5),
    )
    def test_merged_db_identical_regardless_of_merge_order(costs, split):
        """The merge barrier is order-independent: merging worker scratch
        DBs in any order yields byte-identical state."""
        bp = BasicParams.make(kernel="order")
        scratches = []
        for w in range(min(split, len(costs))):
            scratch = TuningDB()
            for i in list(range(len(costs)))[w::split]:
                scratch.record_trial(bp, {"i": i}, costs[i], "before_execution")
            scratches.append(scratch)

        def merged(order):
            db = TuningDB()
            for idx in order:
                db.merge(scratches[idx])
            return json.dumps(db._data, sort_keys=True, default=str)

        forward = merged(range(len(scratches)))
        backward = merged(reversed(range(len(scratches))))
        assert forward == backward


def test_fleet_balances_shards():
    space = demo_space()  # 18 points
    fleet = FleetCoordinator(workers=3).search(
        space, demo_cost, bp=BasicParams.make(kernel="bal")
    )
    sizes = [w.points for w in fleet.workers]
    assert sum(sizes) == space.size()
    assert max(sizes) - min(sizes) <= 1  # stride deals evenly


def test_fleet_spawn_backend_matches_thread(tmp_path):
    """The multiprocessing path: same winner, same trial set, scratch DBs
    persisted per worker (the sync_every flush; keep_scratch pins them
    past the barrier's cleanup)."""
    bp = BasicParams.make(kernel="spawn_eq")
    space = demo_space()
    thread = FleetCoordinator(workers=2, backend="thread").search(
        space, demo_cost, bp=bp
    )
    spawn = FleetCoordinator(
        workers=2, backend="spawn", sync_every=4,
        scratch_dir=str(tmp_path), keep_scratch=True,
    ).search(space, demo_cost, bp=bp)
    assert spawn.best.point == thread.best.point
    assert spawn.merged.trials(bp) == thread.merged.trials(bp)
    for w in spawn.workers:
        assert not w.crashed and w.resumed == 0
        scratch = TuningDB(w.scratch_path)
        assert scratch.trials(bp)  # worker flushed its scratch results


def test_fleet_cleans_up_scratch_files(tmp_path):
    """A successful barrier removes this run's scratch files AND orphans
    from a previous crashed run; keep_scratch pins everything."""
    bp = BasicParams.make(kernel="cleanup")
    space = demo_space()
    orphan = tmp_path / "fleet_worker_9.json"
    TuningDB(str(orphan)).record_trial(bp, {"block": 8, "variant": "ij"},
                                       9.0, "before_execution")
    assert orphan.exists()
    FleetCoordinator(
        workers=2, backend="spawn", sync_every=2, scratch_dir=str(tmp_path)
    ).search(space, demo_cost, bp=bp)
    assert list(tmp_path.glob("fleet_worker_*.json")) == []
    # keep_scratch leaves the files for postmortem / resume
    kept = FleetCoordinator(
        workers=2, backend="spawn", sync_every=2,
        scratch_dir=str(tmp_path), keep_scratch=True,
    ).search(space, demo_cost, bp=bp)
    assert sorted(p.name for p in tmp_path.glob("fleet_worker_*.json")) == [
        "fleet_worker_0.json", "fleet_worker_1.json",
    ]
    assert kept.best.point == {"block": 64, "variant": "ij"}


def test_fleet_spawn_crash_resume(tmp_path):
    """Kill a spawn worker mid-shard: the barrier recovers every synced
    trial from its scratch file, re-measures only the unsynced tail, and
    the winner still equals the single-process winner."""
    import os

    from repro.fleet.workloads import (
        CRASH_ONCE_ENV, CRASH_POINT_ENV, crashing_demo_cost,
    )

    bp = BasicParams.make(kernel="crash")
    space = demo_space()
    single = FleetCoordinator(workers=1).search(space, demo_cost, bp=bp)

    # poison a point late in worker 0's stride shard so trials sync first
    shard0 = [dict(p) for p in space.shard(2, "stride")[0].points()]
    poison = shard0[-2]
    marker = tmp_path / "crashed.marker"
    os.environ[CRASH_POINT_ENV] = json.dumps(poison)
    os.environ[CRASH_ONCE_ENV] = str(marker)
    try:
        fleet = FleetCoordinator(
            workers=2, backend="spawn", sync_every=1,
            scratch_dir=str(tmp_path), keep_scratch=True,
        ).search(space, crashing_demo_cost, bp=bp)
    finally:
        os.environ.pop(CRASH_POINT_ENV, None)
        os.environ.pop(CRASH_ONCE_ENV, None)

    assert marker.exists()  # the kill actually fired
    crashed = [w for w in fleet.workers if w.crashed]
    assert crashed, "no worker reported the crash"
    # the poisoned worker's synced trials were recovered, not re-measured.
    # (Only worker 0 is asserted: the dying process breaks the pool, so a
    # sibling that had not yet synced anything can be collaterally marked
    # crashed — its recovery legitimately starts from an empty scratch.)
    assert fleet.workers[0].crashed and fleet.workers[0].resumed > 0
    # completeness + equivalence: the barrier saw the whole space
    assert fleet.merged.trials(bp).keys() == single.merged.trials(bp).keys()
    assert fleet.best.point == single.best.point
    assert fleet.merged.tuned_point(bp) == single.best.point


def test_fleet_spawn_worker_resumes_from_scratch_file(tmp_path):
    """A re-run over a surviving scratch file re-measures only the missing
    points (the crash-resume path inside the worker itself)."""
    from repro.fleet.coordinator import _spawn_worker

    bp = BasicParams.make(kernel="resume")
    points = [{"block": 2 ** (3 + i), "variant": "ij"} for i in range(4)]
    scratch_path = str(tmp_path / "fleet_worker_0.json")
    prior = TuningDB(scratch_path)
    for p in points[:3]:
        prior.record_trial(bp, p, demo_cost(p), "before_execution")

    idx, trials, _, resumed = _spawn_worker(
        (0, points, bp.asdict(), demo_cost, "before_execution",
         scratch_path, 1)
    )
    assert resumed == 3
    assert len(trials) == 4  # recovered 3 + measured 1
    assert {pp_key(p) for p, _ in trials} == {pp_key(p) for p in points}


def test_fleet_search_through_tuner():
    """coordinator.as_search() drops into the Tuner: same argmin, trials
    cached in the Tuner's DB, final best recorded."""
    costs = {0: 5.0, 1: 1.0, 2: 3.0}
    space = ParamSpace([PerfParam("i", (0, 1, 2))])
    region = ATRegion("r", space, instantiate=lambda pt: (lambda: pt["i"]))
    db = TuningDB()
    bp = BasicParams.make(kernel="via_tuner")
    tuner = Tuner(db, search=FleetCoordinator(workers=2).as_search())
    result = tuner.tune(region, bp, lambda p: costs[p["i"]])
    assert result.best.point == {"i": 1}
    assert db.tuned_point(bp) == {"i": 1}
    assert len(db.trials(bp)) == 3
    assert region.selected == {"i": 1}


def test_background_tuner_fleet_sharded():
    """BackgroundTuner(fleet=...) shards the off-hot-path search and the
    hot path still pays zero evaluations."""
    costs = [4.0, 1.0, 3.0, 2.0]
    db = TuningDB()
    op = AutotunedOp(_toy_spec(costs), db=db, warm=False)
    with BackgroundTuner(fleet=FleetCoordinator(workers=2)) as tuner:
        state = tuner.submit(op, X)
        assert state.cost_evaluations == 0  # caller thread never tunes
        assert tuner.drain(timeout=60)
    assert state.region.selected == {"i": 1}
    assert db.tuned_point(state.bp) == {"i": 1}
    assert tuner.tuned_labels == ["fleet_toy"]


# ---------------------------------------------------------------------------
# Drift lifecycle (the acceptance bar)
# ---------------------------------------------------------------------------


def _drifted(op, state, monitor, cost):
    """Feed observations until the monitor demotes (bounded)."""
    for _ in range(32):
        if monitor.observe(op, state, cost, (X,), {}) == "demoted":
            return True
    return False


# the drift lifecycle's own transitions — the event log also carries
# observability audit events (search_completed, warm_start, ...) that the
# lifecycle assertions below are not about
_DRIFT_KINDS = {"demoted", "retune_scheduled", "canary_start", "promoted",
                "rolled_back", "retune_failed"}


def _drift_kinds(events):
    return [e["kind"] for e in events if e["kind"] in _DRIFT_KINDS]


def test_drift_lifecycle_promotes_winning_challenger():
    """Injected regression -> demote -> re-tune -> canary -> promote,
    every transition in the persisted event log."""
    costs = {0: 1.0, 1: 0.5, 2: 2.0}
    db = TuningDB()
    op = AutotunedOp(_toy_spec(costs), db=db, warm=False)
    state = op.resolve(X)
    assert db.tuned_point(state.bp) == {"i": 1}

    monitor = DriftMonitor(factor=2.0, min_observations=4, canary_window=3)
    # the runtime regresses the winner; candidate 0 is now fastest
    costs.update({1: 2.0, 0: 0.3})
    assert _drifted(op, state, monitor, 2.0)
    # demotion is durable: the final flag is gone, the record remains
    assert db.tuned_point(state.bp) is None
    assert db.best_point(state.bp) is not None
    # inline re-tune already canaried the challenger provisionally
    assert state.region.selected == {"i": 0}
    assert db.tuned_point(state.bp) is None  # not final until the verdict

    outcomes = [monitor.observe(op, state, 0.3, (X,), {}) for _ in range(3)]
    assert outcomes[-1] == "promoted"
    assert state.region.selected == {"i": 0}
    assert db.tuned_point(state.bp) == {"i": 0}  # the new final
    assert db.best_cost(state.bp) == pytest.approx(0.3)
    kinds = _drift_kinds(db.events(state.bp))
    assert kinds == ["demoted", "retune_scheduled", "canary_start", "promoted"]


def test_drift_lifecycle_rolls_back_losing_challenger():
    costs = {0: 1.0, 1: 0.5, 2: 2.0}
    db = TuningDB()
    op = AutotunedOp(_toy_spec(costs), db=db, warm=False)
    state = op.resolve(X)

    monitor = DriftMonitor(factor=2.0, min_observations=4, canary_window=3)
    costs.update({1: 2.0, 0: 0.3})  # re-tune will nominate 0...
    assert _drifted(op, state, monitor, 2.0)
    assert state.region.selected == {"i": 0}  # canary running
    # ...but live canary observations are WORSE than the drifted incumbent
    outcomes = [monitor.observe(op, state, 9.0, (X,), {}) for _ in range(3)]
    assert outcomes[-1] == "rolled_back"
    assert state.region.selected == {"i": 1}  # incumbent restored
    # incumbent re-finalized at its *observed* cost so the watch re-arms
    assert db.tuned_point(state.bp) == {"i": 1}
    assert db.best_cost(state.bp) == pytest.approx(2.0)
    kinds = _drift_kinds(db.events(state.bp))
    assert kinds == ["demoted", "retune_scheduled", "canary_start",
                     "rolled_back"]
    # re-armed, not flapping: normal observations trigger nothing
    for _ in range(8):
        assert monitor.observe(op, state, 2.0, (X,), {}) is None


def test_drift_retune_remeasures_instead_of_replaying_cache():
    """The re-tune must be fresh: recorded trial costs are what reality
    drifted away from, so every candidate is measured again."""
    costs = {0: 1.0, 1: 0.5, 2: 2.0}
    calls = []
    db = TuningDB()
    op = AutotunedOp(_toy_spec(costs, calls=calls), db=db, warm=False)
    state = op.resolve(X)
    first_sweep = len(calls)
    assert first_sweep == 3
    monitor = DriftMonitor(factor=2.0, min_observations=4, canary_window=2)
    costs.update({1: 2.0, 0: 0.3})
    assert _drifted(op, state, monitor, 2.0)
    # all three candidates re-measured (a cached replay would add zero)
    assert len(calls) == 2 * first_sweep


def test_drift_events_persist_across_processes(tmp_path):
    """The event log is part of the DB file: a fresh load replays it."""
    path = str(tmp_path / "db.json")
    costs = {0: 1.0, 1: 0.5}
    db = TuningDB(path)
    op = AutotunedOp(_toy_spec(costs), db=db, warm=False)
    state = op.resolve(X)
    monitor = DriftMonitor(factor=2.0, min_observations=2, canary_window=2)
    costs.update({1: 3.0, 0: 0.2})
    assert _drifted(op, state, monitor, 3.0)
    for _ in range(2):
        monitor.observe(op, state, 0.2, (X,), {})
    loaded = TuningDB(path)
    kinds = _drift_kinds(loaded.events(state.bp))
    assert kinds == ["demoted", "retune_scheduled", "canary_start", "promoted"]
    assert loaded.tuned_point(state.bp) == {"i": 0}


def test_demotion_survives_flush_reconciliation(tmp_path):
    """A stale on-disk final of the SAME point must not resurrect the
    final flag when the demoting process flushes."""
    path = str(tmp_path / "db.json")
    bp = BasicParams.make(kernel="k")
    writer = TuningDB(path)
    writer.record_best(bp, {"i": 0}, 1.0, "before_execution")
    demoter = TuningDB(path)  # loaded the final
    writer.record_trial(bp, {"i": 0}, 1.0, "before_execution")  # disk changes
    assert demoter.demote_best(bp)
    demoter.record_event(bp, "demoted")  # forces a flush + reconcile
    assert TuningDB(path).tuned_point(bp) is None


def test_drift_through_background_tuner():
    """The off-hot-path re-tune: demotion schedules the search on the
    worker thread, the canary hot-applies from its completion callback."""
    costs = {0: 1.0, 1: 0.5, 2: 2.0}
    db = TuningDB()
    op = AutotunedOp(_toy_spec(costs), db=db, warm=False)
    state = op.resolve(X)
    with BackgroundTuner() as tuner:
        monitor = DriftMonitor(
            background=tuner, factor=2.0, min_observations=4, canary_window=2
        )
        costs.update({1: 2.0, 0: 0.3})
        assert _drifted(op, state, monitor, 2.0)
        # the re-tune runs on the worker; wait for the canary to go live
        deadline = time.time() + 30
        while monitor.watch_phase(state) != "canary":
            assert time.time() < deadline, "background re-tune never landed"
            time.sleep(0.01)
        assert state.region.selected == {"i": 0}
        outcomes = [monitor.observe(op, state, 0.3, (X,), {}) for _ in range(2)]
    assert outcomes[-1] == "promoted"
    assert db.tuned_point(state.bp) == {"i": 0}
    kinds = _drift_kinds(db.events(state.bp))
    assert kinds == ["demoted", "retune_scheduled", "canary_start", "promoted"]
    assert not tuner.errors


def test_drift_rearm_when_retune_already_inflight():
    """If the class is already queued on the worker (two monitors racing on
    one DB), the dropped re-tune must re-arm the watch, not wedge it in
    'retuning' forever."""
    costs = {0: 1.0, 1: 0.5}
    db = TuningDB()
    op = AutotunedOp(_toy_spec(costs), db=db, warm=False)
    state = op.resolve(X)
    tuner = BackgroundTuner().start()
    with tuner._cv:  # simulate the racer: fingerprint already inflight
        tuner._inflight.add(state.bp.fingerprint())
    try:
        monitor = DriftMonitor(
            background=tuner, factor=2.0, min_observations=2, canary_window=2
        )
        costs.update({1: 3.0})
        assert _drifted(op, state, monitor, 3.0)
        assert monitor.watch_phase(state) == "healthy"  # re-armed, not stuck
        kinds = _drift_kinds(db.events(state.bp))
        assert kinds == ["demoted", "retune_scheduled", "retune_failed"]
    finally:
        with tuner._cv:
            tuner._inflight.discard(state.bp.fingerprint())
        tuner.stop()


def test_drift_monitor_validates_config():
    with pytest.raises(ValueError, match="factor"):
        DriftMonitor(factor=1.0)
    with pytest.raises(ValueError, match="alpha"):
        DriftMonitor(alpha=0.0)
