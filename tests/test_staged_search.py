"""Staged tuning pipeline tests (ISSUE 3).

Covers: StagedSearch invariants (prescreen-k = |space| == exhaustive argmin,
survivor budget, warm-start seed survival), warm-started CoordinateDescent
never regressing below its seed, SuccessiveHalving's on_trial/resume parity,
adaptive wall-clock timing, the TuningDB nearest-shape-class query,
PP-point projection, and the AutotunedOp/BackgroundTuner integration of the
pipeline (staged tune on the worker, cross-class warm starts, eval
accounting).
"""
import threading

import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property sections skip, unit tests still run
    given = None

from repro.core import (
    ATRegion,
    AdaptiveWallClockCost,
    AutotunedOp,
    BasicParams,
    CoordinateDescent,
    ExhaustiveSearch,
    KernelSpec,
    ParamSpace,
    PerfParam,
    StagedSearch,
    SuccessiveHalving,
    Trial,
    TuningDB,
    default_prescreen_k,
    pp_key,
    project_point,
)
from repro.runtime import BackgroundTuner


def _grid_space(na, nb):
    return ParamSpace(
        [PerfParam("a", tuple(range(na))), PerfParam("b", tuple(range(nb)))]
    )


# ---------------------------------------------------------------------------
# StagedSearch invariants
# ---------------------------------------------------------------------------


if given is not None:

    @settings(max_examples=25, deadline=None)
    @given(
        costs=st.lists(
            st.floats(min_value=0.01, max_value=100, allow_nan=False),
            min_size=2, max_size=16, unique=True,
        ),
        prescreen_seed=st.integers(0, 2**16),
    )
    def test_staged_with_full_k_equals_exhaustive(costs, prescreen_seed):
        """ISSUE 3 satellite: with prescreen-k = |space| nothing is pruned,
        so the staged result must be the exhaustive argmin of the measured
        cost — for *any* prescreen ranking, however wrong (pseudorandom)."""
        space = ParamSpace([PerfParam("i", tuple(range(len(costs))))])
        measured = lambda p: costs[p["i"]]
        prescreen = lambda p: float((p["i"] * 2654435761 + prescreen_seed) % 97)
        staged = StagedSearch(prescreen, k=space.size()).run(space, measured)
        exhaustive = ExhaustiveSearch().run(space, measured)
        assert staged.best.point == exhaustive.best.point
        assert staged.best.cost == exhaustive.best.cost
        assert staged.evaluations == exhaustive.evaluations
        assert staged.prescreen_evaluations == len(costs)

    @settings(max_examples=25, deadline=None)
    @given(
        fa=st.lists(st.integers(0, 10**6), min_size=2, max_size=6, unique=True),
        fb=st.lists(st.integers(0, 10**6), min_size=2, max_size=6, unique=True),
        seed_a=st.integers(0, 5),
        seed_b=st.integers(0, 5),
    )
    def test_warm_started_descent_never_worse_than_seed(fa, fb, seed_a, seed_b):
        """ISSUE 3 satellite: a warm-started CoordinateDescent must never
        return a point worse than the seed it started from (refinement is
        monotone)."""
        space = _grid_space(len(fa), len(fb))
        seed = {"a": seed_a % len(fa), "b": seed_b % len(fb)}
        cost = lambda p: float(fa[p["a"]] + fb[p["b"]])
        res = CoordinateDescent(start=seed).run(space, cost)
        assert res.best.cost <= cost(seed)


def test_staged_full_k_equals_exhaustive_fixed_case():
    """Deterministic companion to the property test (runs without
    hypothesis): adversarial reversed prescreen, k = |space|."""
    costs = [5.0, 0.5, 3.0, 4.0, 1.0, 2.0]
    space = ParamSpace([PerfParam("i", tuple(range(len(costs))))])
    staged = StagedSearch(lambda p: -costs[p["i"]], k=space.size()).run(
        space, lambda p: costs[p["i"]]
    )
    assert staged.best.point == {"i": 1}


def test_warm_started_descent_never_worse_than_seed_fixed_case():
    space = _grid_space(4, 4)
    cost = lambda p: float((p["a"] * 7 + p["b"] * 13) % 11)  # non-separable
    for seed in ({"a": 0, "b": 0}, {"a": 3, "b": 1}, {"a": 2, "b": 3}):
        res = CoordinateDescent(start=seed).run(space, cost)
        assert res.best.cost <= cost(seed)


# ---------------------------------------------------------------------------
# Collective parsing: -start/-done pairs count once (ISSUE 3 satellite)
# ---------------------------------------------------------------------------


def test_collective_bytes_counts_start_done_pairs_once():
    """Async collectives appear as a ``-start``/``-done`` instruction pair
    in HLO; only the ``-start`` (or the plain synchronous form) carries the
    payload.  Pinned by the cleanup that removed the dead ``seen_done`` set:
    ``-done`` lines must be skipped, never double-counted."""
    from repro.core import collective_bytes_from_hlo

    hlo = "\n".join([
        "  %ag-start = (f32[128], f32[256]) all-gather-start(f32[128] %p0)",
        "  %ag-done = f32[256] all-gather-done((f32[128], f32[256]) %ag-start)",
        "  %ar = f32[64] all-reduce(f32[64] %p1), to_apply=%sum",
        "  %cp-start = (f32[32], f32[32]) collective-permute-start(f32[32] %p2)",
        "  %cp-done = f32[32] collective-permute-done((f32[32], f32[32]) %cp-start)",
    ])
    out = collective_bytes_from_hlo(hlo)
    # all-gather: counted once, at -start (its declared result tuple)
    assert out["all-gather"] == (128 + 256) * 4
    assert out["all-reduce"] == 64 * 4
    assert out["collective-permute"] == 32 * 2 * 4
    # a lone synchronous op parses the same with or without async pairs
    solo = "  %r = f32[16] all-reduce(f32[16] %x), to_apply=%sum"
    assert collective_bytes_from_hlo(solo) == {"all-reduce": 16 * 4}


def test_staged_measures_only_k_survivors():
    space = _grid_space(5, 5)
    true_cost = lambda p: float((p["a"] - 2) ** 2 + (p["b"] - 3) ** 2)
    prescreen_calls, measured_calls = [], []

    def prescreen(p):
        prescreen_calls.append(dict(p))
        return true_cost(p)

    def measured(p):
        measured_calls.append(dict(p))
        return true_cost(p)

    res = StagedSearch(prescreen, k=4).run(space, measured)
    assert len(prescreen_calls) == 25  # stage 1: the full space
    assert len(measured_calls) == 4   # stage 2: survivors only
    assert res.best.point == {"a": 2, "b": 3}  # exact prescreen: argmin kept
    assert res.evaluations == 4
    assert res.prescreen_evaluations == 25


def test_staged_seed_survives_hostile_prescreen():
    """The warm-start seed must reach the measured finals even when the
    prescreen ranks it dead last."""
    space = ParamSpace([PerfParam("i", tuple(range(10)))])
    seed = {"i": 7}
    prescreen = lambda p: 0.0 if p["i"] != 7 else 1e9
    measured = lambda p: 0.01 if p["i"] == 7 else 1.0
    res = StagedSearch(prescreen, k=3, warm_start=seed).run(space, measured)
    assert res.best.point == seed
    # the seed *extends* the finals (k+1): it must not evict the k-th
    # prescreen survivor, and none of the top-k are shadowed by it
    assert res.evaluations == 4
    assert {t.point["i"] for t in res.trials} == {7, 0, 1, 2}


def test_staged_prescreen_failure_scores_inf_not_fatal():
    space = ParamSpace([PerfParam("i", (0, 1, 2, 3))])

    def prescreen(p):
        if p["i"] == 1:
            raise RuntimeError("lowering failed")
        return float(p["i"])

    res = StagedSearch(prescreen, k=2).run(space, lambda p: float(p["i"]))
    assert res.best.point == {"i": 0}
    assert {t.point["i"] for t in res.trials} == {0, 2}  # 1 was pruned to inf


def test_default_prescreen_k_scaling():
    assert default_prescreen_k(4) == 2
    assert default_prescreen_k(16) == 4
    assert default_prescreen_k(36) == 6
    assert all(default_prescreen_k(n) >= 2 for n in range(1, 50))


# ---------------------------------------------------------------------------
# SuccessiveHalving: on_trial hook / resume parity (ISSUE 3 satellite)
# ---------------------------------------------------------------------------


def test_successive_halving_on_trial_records_every_evaluation():
    space = ParamSpace([PerfParam("i", tuple(range(8)))])
    seen = []
    res = SuccessiveHalving(initial_budget=1, on_trial=seen.append).run(
        space, lambda p, b: abs(p["i"] - 5) + 1.0 / b
    )
    assert len(seen) == res.evaluations == len(res.trials)
    assert all(isinstance(t, Trial) for t in seen)
    assert res.best.point["i"] == 5


def test_successive_halving_interrupted_run_resumes_from_on_trial_writes():
    """The fault-tolerance parity the hook exists for: a crash mid-rung loses
    nothing that on_trial already persisted — the re-run skips re-measuring
    those points exactly like ExhaustiveSearch resume does."""
    space = ParamSpace([PerfParam("i", tuple(range(8)))])
    persisted = {}  # the "DB": pp_key -> cost, written incrementally
    measured = []
    crash_after = [5]  # evaluations until the simulated crash; then unlimited

    def cost(p, b):
        key = pp_key(p)
        if key in persisted:
            return persisted[key]  # resumed: no re-measure
        if len(measured) >= crash_after[0]:
            raise KeyboardInterrupt  # crash mid-first-rung
        measured.append(key)
        return float(abs(p["i"] - 3))

    record = lambda t: persisted.__setitem__(pp_key(t.point), t.cost)
    with pytest.raises(KeyboardInterrupt):
        SuccessiveHalving(initial_budget=1, on_trial=record).run(space, cost)
    assert len(persisted) == 5  # every completed evaluation survived

    crash_after[0] = len(measured) + 100  # the re-run completes
    res = SuccessiveHalving(initial_budget=1, on_trial=record).run(space, cost)
    assert res.best.point == {"i": 3}
    # only the 3 never-measured points paid a fresh evaluation
    assert len(measured) == 8


def test_staged_delegates_to_prescreen_score_many():
    """A prescreen exposing ``score_many`` (CompiledRooflineCost) owns the
    scoring fan-out; StagedSearch must use it rather than re-pooling."""
    space = ParamSpace([PerfParam("i", tuple(range(6)))])

    class BatchPrescreen:
        def __init__(self):
            self.batches = []

        def __call__(self, p):  # pragma: no cover - must not be used
            raise AssertionError("score_many should have been called")

        def score_many(self, points, max_workers=None):
            self.batches.append(len(points))
            return [float(p["i"]) for p in points]

    pre = BatchPrescreen()
    res = StagedSearch(pre, k=2).run(space, lambda p: float(p["i"]))
    assert pre.batches == [6]
    assert res.best.point == {"i": 0}
    assert res.prescreen_evaluations == 6


def test_successive_halving_budget_passes_through_tuner_path():
    """ISSUE 3 satellite follow-through: a budget-aware cost behind
    Tuner.tune must see SuccessiveHalving's doubling rung budgets — the DB
    trial cache must not short-circuit re-measurement at higher budget."""
    from repro.core import ATRegion, Tuner

    space = ParamSpace([PerfParam("i", tuple(range(4)))])
    region = ATRegion("r", space, lambda p: (lambda: p["i"]))
    budgets_seen = []

    def cost(point, budget=None):
        budgets_seen.append((point["i"], budget))
        return float(point["i"]) + 1.0 / (budget or 1)

    cost.supports_budget = True
    db = TuningDB()
    res = Tuner(db).tune(
        region, BasicParams.make(kernel="sh"), cost,
        search=SuccessiveHalving(initial_budget=1),
    )
    assert res.best.point == {"i": 0}
    # rung 1 measured all 4 at budget 1; later rungs re-measured the
    # survivors at doubled budgets instead of returning cached rung-1 costs
    assert [b for _, b in budgets_seen[:4]] == [1, 1, 1, 1]
    assert max(b for _, b in budgets_seen) >= 2
    assert db.trial_cost(BasicParams.make(kernel="sh"), {"i": 0}) is not None


# ---------------------------------------------------------------------------
# Adaptive wall-clock timing
# ---------------------------------------------------------------------------


def test_adaptive_cost_abandons_clear_losers_early():
    sleep_s = {0: 0.001, 1: 0.03, 2: 0.03, 3: 0.03}
    import time as _time

    def build(point):
        return lambda: _time.sleep(sleep_s[point["i"]])

    cost = AdaptiveWallClockCost(build, warmup=0, min_repeats=1, max_repeats=6)
    assert cost.supports_budget
    c0 = cost({"i": 0})  # incumbent
    runs_before = cost.timed_runs
    c1 = cost({"i": 1})  # 30x worse: must stop after one timed run
    assert cost.timed_runs - runs_before == 1
    assert c1 > c0
    assert cost.incumbent == pytest.approx(c0)
    assert cost.measured_points == 2


def test_adaptive_cost_budget_scales_repeat_cap():
    calls = []

    def build(point):
        return lambda: calls.append(1)

    cost = AdaptiveWallClockCost(build, warmup=0, min_repeats=2, max_repeats=2)
    cost({"i": 0})
    n1 = len(calls)
    cost({"i": 0}, budget=3)  # equal-cost point: CI never separates -> cap
    assert len(calls) - n1 >= n1  # budget raised the cap


# ---------------------------------------------------------------------------
# Nearest-shape-class query + PP projection (warm-start plumbing)
# ---------------------------------------------------------------------------


def test_nearest_tuned_prefers_closest_bucket_same_kernel():
    db = TuningDB()
    for seq, point in ((128, {"block": 1}), (1024, {"block": 2})):
        db.record_best(
            BasicParams.make(kernel="k", seq=seq), point, 1.0, "before_execution"
        )
    db.record_best(
        BasicParams.make(kernel="other", seq=256), {"block": 9}, 0.1,
        "before_execution",
    )
    near = db.nearest_tuned(BasicParams.make(kernel="k", seq=256))
    assert near["point"] == {"block": 1}  # 1 bucket away beats 2, kernel-matched
    assert near["distance"] == pytest.approx(1.0)


def test_nearest_tuned_ignores_self_and_non_final():
    db = TuningDB()
    bp = BasicParams.make(kernel="k", seq=256)
    db.record_best(bp, {"i": 0}, 1.0, "before_execution")
    assert db.nearest_tuned(bp) is None  # own entry never matches
    sibling = BasicParams.make(kernel="k", seq=512)
    db.record_trial(sibling, {"i": 1}, 1.0, "before_execution")  # interim only
    assert db.nearest_tuned(bp) is None  # non-final bests don't seed
    db.record_best(sibling, {"i": 1}, 1.0, "before_execution")
    assert db.nearest_tuned(bp)["point"] == {"i": 1}


def test_nearest_tuned_requires_match_key():
    db = TuningDB()
    db.record_best(
        BasicParams.make(kernel="k", seq=128), {"i": 0}, 1.0, "before_execution"
    )
    assert db.nearest_tuned(BasicParams.make(arch="no-kernel-key")) is None


def test_project_point_matches_json_roundtripped_tuple_values():
    """A disk-loaded seed carries JSON lists where domains hold tuples; the
    projection must still recognize the exact match (not degrade to the
    default)."""
    space = ParamSpace(
        [PerfParam("exchange", ((1, 2), (3, 4))), PerfParam("n", (1, 2))]
    )
    projected = project_point(space, {"exchange": [3, 4], "n": 2})
    assert projected == {"exchange": (3, 4), "n": 2}


def test_project_point_snaps_and_validates():
    space = ParamSpace(
        [PerfParam("block", (128, 256, 512)), PerfParam("variant", ("x", "y"))]
    )
    # in-domain values survive; foreign numerics snap to the nearest candidate
    assert project_point(space, {"block": 512, "variant": "y"}) == {
        "block": 512, "variant": "y",
    }
    assert project_point(space, {"block": 300, "variant": "z"}) == {
        "block": 256, "variant": "x",  # 300 -> nearest 256, z -> default
    }
    assert project_point(space, {"variant": "y"})["block"] == 128  # missing -> default
    constrained = ParamSpace(
        [PerfParam("block", (128, 256))], constraint=lambda p: p["block"] < 200
    )
    assert project_point(constrained, {"block": 250}) is None  # infeasible seed


# ---------------------------------------------------------------------------
# AutotunedOp integration: staged default + cross-class warm start
# ---------------------------------------------------------------------------


def _staged_spec(calls, prescreen_calls, name="staged_toy", na=4, nb=4):
    """Spec with a separable measured cost and an exact analytic prescreen."""
    space = _grid_space(na, nb)
    true_cost = lambda p: float((p["a"] - 1) ** 2 + (p["b"] - 2) ** 2 + 1)

    def cost_factory(region, bp, args, kwargs):
        def cost(point):
            calls.append((dict(point), threading.get_ident()))
            return true_cost(point)

        return cost

    def prescreen_factory(region, bp, args, kwargs):
        def prescreen(point):
            prescreen_calls.append(dict(point))
            return true_cost(point)

        return prescreen

    return KernelSpec(
        name,
        make_region=lambda bp: ATRegion(name, space, lambda p: (lambda x: x)),
        shape_class=lambda x: BasicParams.make(kernel=name, n=int(x.shape[0])),
        cost_factory=cost_factory,
        prescreen_factory=prescreen_factory,
    )


def test_autotuned_op_stages_by_default_with_prescreen_factory():
    calls, pres = [], []
    op = AutotunedOp(_staged_spec(calls, pres), db=TuningDB(), prescreen_k=3)
    state = op.resolve(jnp.ones(4))
    assert len(pres) == 16          # stage 1: full space, never measured
    assert len(calls) == 3          # stage 2: top-k survivors only
    assert state.cost_evaluations == 3
    assert state.prescreen_evaluations == 16
    assert state.region.selected == {"a": 1, "b": 2}  # exact prescreen: argmin
    assert op.db.tuned_point(state.bp) == {"a": 1, "b": 2}  # final: no re-tune


def test_autotuned_op_staged_false_disables_pipeline():
    calls, pres = [], []
    op = AutotunedOp(
        _staged_spec(calls, pres), db=TuningDB(), staged=False, warm_start=False
    )
    op.resolve(jnp.ones(4))
    assert pres == []
    assert len(calls) == 16  # plain exhaustive


def test_autotuned_op_warm_starts_sibling_shape_class():
    calls, pres = [], []
    spec = _staged_spec(calls, pres)
    db = TuningDB()
    AutotunedOp(spec, db=db, prescreen_k=3).resolve(jnp.ones(4))
    n_first = len(calls)

    # second shape class, same kernel: staged again but seeded by the
    # sibling's winner — the seed leads the finals
    op2 = AutotunedOp(spec, db=db, prescreen_k=3)
    state2 = op2.resolve(jnp.ones(8))
    assert state2.warm_seed == {"a": 1, "b": 2}
    assert state2.region.selected == {"a": 1, "b": 2}
    assert len(calls) - n_first == 3  # refinement run, not a full sweep

    # and with the pipeline off, the warm start alone turns the sweep into
    # a seeded hillclimb that never does worse than the seed
    calls3, pres3 = [], []
    spec3 = _staged_spec(calls3, pres3)
    db3 = TuningDB()
    AutotunedOp(spec3, db=db3, staged=False, warm_start=False).resolve(jnp.ones(4))
    full_sweep = len(calls3)
    op3 = AutotunedOp(spec3, db=db3, staged=False)
    state3 = op3.resolve(jnp.ones(8))
    assert state3.warm_seed == {"a": 1, "b": 2}
    assert len(calls3) - full_sweep < full_sweep  # CD refinement < exhaustive
    assert state3.region.selected == {"a": 1, "b": 2}


def test_staged_measured_stage_reuses_prescreen_executables():
    """The roofline prescreen compiles every candidate; the measured finals
    must execute those retained artifacts instead of instantiating (and
    recompiling) the survivors a second time."""
    from repro.core import roofline_prescreen

    space = ParamSpace([PerfParam("i", tuple(range(9)))])
    instantiated = []

    def instantiate(point):
        instantiated.append(point["i"])
        scale = float(point["i"] + 1)
        return lambda x: x * scale

    spec = KernelSpec(
        "reuse_toy",
        make_region=lambda bp: ATRegion("reuse_toy", space, instantiate),
        shape_class=lambda x: BasicParams.make(kernel="reuse_toy"),
        prescreen_factory=roofline_prescreen,
    )
    op = AutotunedOp(
        spec, db=TuningDB(), warm=False, warm_start=False, prescreen_k=3
    )
    state = op.resolve(jnp.ones(8))
    assert state.prescreen_evaluations == 9
    assert state.cost_evaluations == 3
    # one instantiate per candidate (the prescreen's lowering); the three
    # measured survivors ran the prescreen's compiled executables
    assert len(instantiated) == 9


def test_background_tuner_runs_staged_pipeline_off_hot_path():
    """The pipeline as the background tuner's default: prescreen + measured
    finals + warm start all happen on the worker thread; the submit thread
    still performs zero evaluations of either stage."""
    calls, pres = [], []
    op = AutotunedOp(
        _staged_spec(calls, pres), db=TuningDB(), tune=False, prescreen_k=3
    )
    with BackgroundTuner() as tuner:
        state = tuner.submit(op, jnp.ones(4))
        assert tuner.drain(timeout=60)
        assert state.tuned
        assert tuner.background_evaluations == 3
        assert tuner.prescreen_evaluations == 16
        me = threading.get_ident()
        assert all(t != me for _, t in calls)
        # a sibling class submitted later warm-starts from the first winner
        state2 = tuner.submit(op, jnp.ones(8))
        assert tuner.drain(timeout=60)
        assert state2.warm_seed is not None
        assert tuner.warm_started_labels == [op.spec.name]
    assert tuner.errors == []
