"""Kernel conformance: every registered kernel ≡ its ref.py oracle across all
feasible points of a small shape class (replaces the per-kernel copy-pasted
shape checks that used to live in test_kernels.py)."""
import jax
import pytest

from repro.core import REGISTRY

from conformance import CASES, assert_tree_allclose

KEY = jax.random.PRNGKey(0)


def _cases():
    for case in CASES.values():
        for dtype in case.dtypes:
            yield pytest.param(case, dtype, id=f"{case.name}-{dtype}")


@pytest.mark.parametrize("case,dtype", _cases())
def test_kernel_matches_oracle_on_all_feasible_points(case, dtype):
    region = case.region_factory()
    args = case.cast_args(case.make_args(KEY), dtype)
    expected = case.oracle(*args)
    rtol, atol = case.tol.get(dtype, (2e-2, 2e-2))
    points = list(region.space.points())
    assert points, f"{case.name}: empty feasible set"
    for point in points:
        out = region.candidate(point)(*args)
        assert_tree_allclose(
            out, expected, rtol, atol, label=f"{case.name}@{point} [{dtype}]"
        )


def test_conformance_covers_every_registered_kernel():
    """Adding a kernel to the registry without a conformance case is an error
    — the harness is the registration contract (docs/registry.md)."""
    registered = set(REGISTRY.names(tag="pallas"))
    assert registered, "no kernels registered"
    covered = {c.kernel_name for c in CASES.values()}
    assert registered == covered, (
        f"conformance cases out of sync with registry: "
        f"missing={registered - covered} stale={covered - registered}"
    )


def test_candidate_family_is_interchangeable():
    """Selecting any feasible point must not change results — the property
    that makes run-time switching free *and safe*."""
    case = CASES["stress"]
    region = case.region_factory()
    args = case.make_args(KEY)
    outs = []
    for point in region.space.points():
        region.select(point)
        outs.append(region(*args))
    first = outs[0]
    for out in outs[1:]:
        assert_tree_allclose(out, first, 1e-6, 1e-7, label="stress family")
