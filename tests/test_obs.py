"""Observability-layer tests (ISSUE 10 acceptance, docs/observability.md).

Covers: the tracer primitives and deterministic Perfetto export, the
event-log truncation tombstone (local trim + lattice merge laws), the
metrics registry / Prometheus text round-trip, the ``as_metrics()``
adapters, byte-identical engine traces across two seeded-chaos runs on
the ``TickTimer`` clock, span-nesting laminarity under the background
tuner's worker thread, the retire-uniqueness timeline property (one
terminal ``engine.retire`` instant per admitted rid, matching its
``RequestResult.status``), and the explain report's decision chain.
"""
import json
import threading

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property section skips, unit tests still run
    given = None

from repro.configs import get_config
from repro.core import (
    ATRegion,
    AutotunedOp,
    BasicParams,
    KernelSpec,
    ParamSpace,
    PerfParam,
    TrafficClass,
    TuningDB,
)
from repro.core.db import EVENT_LIMIT, TOMBSTONE_KIND
from repro.data import synthetic_requests
from repro.models import init_params, param_specs
from repro.obs import (
    MetricsRegistry,
    TickTimer,
    Tracer,
    current_tracer,
    parse_prometheus,
    snapshot_stats,
    use_tracer,
)
from repro.obs.explain import db_summary, explain_fingerprint, render_report
from repro.runtime import BackgroundTuner, ChaosInjector, StreamingEngine
from repro.runtime.engine import REQUEST_STATUSES

KEY = jax.random.PRNGKey(0)
SMOKE = get_config("tinyllama-1.1b", smoke=True)
MAX_LEN = 16


@pytest.fixture(scope="module")
def smoke_params():
    return init_params(KEY, param_specs(SMOKE))


# ---------------------------------------------------------------------------
# Tracer primitives + deterministic export
# ---------------------------------------------------------------------------


def test_tick_timer_is_deterministic_and_thread_safe():
    t = TickTimer(0.5)
    assert [t() for _ in range(3)] == [0.5, 1.0, 1.5]
    t2 = TickTimer(0.5)
    out = []
    threads = [
        threading.Thread(target=lambda: out.append(t2())) for _ in range(8)
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    # every call got a distinct tick regardless of interleaving
    assert sorted(out) == [pytest.approx(0.5 * i) for i in range(1, 9)]


def test_span_nesting_and_attrs():
    tr = Tracer(clock=TickTimer(1.0))
    with tr.span("outer", cat="t", track="main") as attrs:
        with tr.span("inner", cat="t", track="main"):
            pass
        attrs["verdict"] = "ok"  # body can attach results before close
    evs = tr.events()
    by_name = {e["name"]: e for e in evs}
    # inner closes first (LIFO) and sits inside outer's [ts, ts+dur]
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"] and i["ts"] + i["dur"] <= o["ts"] + o["dur"]
    assert o["args"]["verdict"] == "ok"


def test_ring_buffer_bounds_memory_and_counts_drops():
    tr = Tracer(capacity=4)
    for k in range(10):
        tr.instant("e", t=float(k))
    assert len(tr.events()) == 4
    assert tr.emitted == 10 and tr.dropped == 6


def test_trace_export_is_a_pure_function_of_the_event_set():
    """Same events captured in different arrival order -> same bytes."""

    def _fill(tr, order):
        for k in order:
            if k % 2:
                tr.complete("step", k * 1e-3, (k + 1) * 1e-3,
                            track=f"w{k % 3}", idx=k)
            else:
                tr.instant("mark", t=k * 1e-3, track=f"w{k % 3}", idx=k)

    a, b = Tracer(), Tracer()
    _fill(a, range(12))
    _fill(b, reversed(range(12)))
    assert a.to_json() == b.to_json()
    # and the export is well-formed for the observe CLI's validator
    doc = json.loads(a.to_json())
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "i", "M")
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        if ev["ph"] != "M":
            assert isinstance(ev["ts"], int)
        if ev["ph"] == "X":
            assert isinstance(ev["dur"], int)
    # one thread_name meta event per track, tids dense from 1
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert sorted(e["tid"] for e in meta) == [1, 2, 3]


def test_use_tracer_restores_previous():
    assert current_tracer() is None
    outer = Tracer()
    with use_tracer(outer):
        assert current_tracer() is outer
        with use_tracer(None):
            assert current_tracer() is None
        assert current_tracer() is outer
    assert current_tracer() is None


def test_nonfinite_and_exotic_attrs_stay_jsonable():
    tr = Tracer()
    tr.instant("e", t=0.0, bad=float("nan"), obj=object(), seq=(1, 2))
    ev = tr.events()[0]
    json.dumps(ev)  # must not raise
    assert ev["args"]["bad"] == "nan" and ev["args"]["seq"] == [1, 2]


# ---------------------------------------------------------------------------
# Event-log truncation tombstone (satellite: db.record_event)
# ---------------------------------------------------------------------------


def _bp(kernel="tomb"):
    return BasicParams.make(kernel=kernel)


def test_event_overflow_folds_into_tombstone():
    db = TuningDB()
    bp = _bp()
    extra = 10
    for k in range(EVENT_LIMIT + extra):
        db.record_event(bp, "noise", k=k)
    events = db.events(bp)
    assert len(events) == EVENT_LIMIT
    tomb = events[0]
    assert tomb["kind"] == TOMBSTONE_KIND
    # tombstone + survivors account for every event ever recorded
    assert tomb["count"] + (len(events) - 1) == EVENT_LIMIT + extra
    assert tomb["oldest_t"] <= tomb["newest_t"]
    # newest events survive, oldest were folded
    assert events[-1]["k"] == EVENT_LIMIT + extra - 1


def test_tombstone_accumulates_across_repeated_trims():
    db = TuningDB()
    bp = _bp()
    for k in range(EVENT_LIMIT * 3):
        db.record_event(bp, "noise", k=k)
    events = db.events(bp)
    assert len(events) == EVENT_LIMIT
    assert events[0]["kind"] == TOMBSTONE_KIND
    assert events[0]["count"] + (len(events) - 1) == EVENT_LIMIT * 3


def _overflowed_db(seed, n):
    db = TuningDB()
    bp = _bp()
    for k in range(n):
        db.record_event(bp, "noise", host=seed, k=k)
    return db, bp


def test_tombstone_merge_is_commutative_and_idempotent():
    a, bp = _overflowed_db("a", EVENT_LIMIT + 7)
    b, _ = _overflowed_db("b", EVENT_LIMIT + 3)

    def _merged(x, y):
        out = TuningDB()
        out.merge(x)
        out.merge(y)
        return out.events(bp)

    ab, ba = _merged(a, b), _merged(b, a)
    assert ab == ba  # commutative
    twice = TuningDB()
    twice.merge(a)
    twice.merge(b)
    twice.merge(b)  # idempotent: re-delivery changes nothing
    assert twice.events(bp) == ab
    # exactly one joined tombstone, pinned first; the merged union re-trims
    # so the joined count covers at least what either host had folded
    tombs = [e for e in ab if e["kind"] == TOMBSTONE_KIND]
    assert len(tombs) == 1 and ab[0]["kind"] == TOMBSTONE_KIND
    assert len(ab) <= EVENT_LIMIT
    assert tombs[0]["count"] >= max(
        a.events(bp)[0]["count"], b.events(bp)[0]["count"]
    )
    # join of *identical* logs takes max, not sum (no double-counting)
    same = TuningDB()
    same.merge(a)
    same.merge(a)
    assert same.events(bp) == a.events(bp)


# ---------------------------------------------------------------------------
# Metrics registry + Prometheus round-trip
# ---------------------------------------------------------------------------


def test_registry_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.counter("req_total", help="requests").inc(3, status="ok")
    reg.counter("req_total").inc(1, status="error")
    reg.gauge("queue_depth").set(7)
    h = reg.histogram("lat_s", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.prometheus_text()
    fams = parse_prometheus(text)
    assert fams["req_total"] == [
        ({"status": "error"}, 1.0), ({"status": "ok"}, 3.0),
    ]
    assert fams["queue_depth"] == [({}, 7.0)]
    assert fams["lat_s_count"] == [({}, 3.0)]
    assert fams["lat_s_sum"] == [({}, pytest.approx(5.55))]
    buckets = {lab["le"]: v for lab, v in fams["lat_s_bucket"]}
    assert buckets == {"0.1": 1.0, "1": 2.0, "+Inf": 3.0}
    # deterministic: a second exposition is byte-identical
    assert reg.prometheus_text() == text


def test_registry_rejects_kind_clash_and_negative_counter():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.counter("x").inc(-1)


def test_register_stats_pulls_live_values():
    class Stats:
        def __init__(self):
            self.n = 0

        def as_metrics(self):
            return {"n": self.n, "flag": True}

    s = Stats()
    reg = MetricsRegistry()
    reg.register_stats("toy", s, worker="w0")
    first = parse_prometheus(reg.prometheus_text())
    s.n = 5  # mutate after registration: pull model must observe it
    second = parse_prometheus(reg.prometheus_text())
    assert first["toy_n"] == [({"worker": "w0"}, 0.0)]
    assert second["toy_n"] == [({"worker": "w0"}, 5.0)]
    assert second["toy_flag"] == [({"worker": "w0"}, 1.0)]


def test_parse_prometheus_rejects_malformed():
    for bad in ("metric{ 1", "# BOGUS comment\nm 1\nnot a line", ""):
        with pytest.raises(ValueError):
            parse_prometheus(bad)


def test_snapshot_stats_fallbacks():
    assert snapshot_stats({"a": 1, "b": "skip"}) == {"a": 1.0}

    class Plain:
        def __init__(self):
            self.x = 2
            self.name = "not-numeric"
            self._hidden = 9

    assert snapshot_stats(Plain()) == {"x": 2.0}


def test_ad_hoc_stats_all_speak_as_metrics():
    """Every stats class named in docs/observability.md flows through the
    one ``as_metrics()`` pipe with numeric-only fields."""
    from repro.fleet.coordinator import WorkerReport
    from repro.fleet.service import ClientStats
    from repro.runtime.chaos import ChaosStats
    from repro.runtime.engine import StreamStats

    for stats in (
        StreamStats(),
        ChaosStats(),
        ClientStats(),
        WorkerReport(worker=0, points=3, evaluations=3, best_cost=1.0,
                     best_point={"i": 0}, wall_s=0.1),
    ):
        snap = snapshot_stats(stats)
        assert snap, f"{type(stats).__name__} produced an empty snapshot"
        assert all(isinstance(v, float) for v in snap.values())


# ---------------------------------------------------------------------------
# Engine timelines: deterministic bytes + retire uniqueness
# ---------------------------------------------------------------------------


def _traced_run(smoke_params, reqs_seed=5, n=4, chaos_seed=11):
    """One seeded-chaos engine run with a pinned tracer on the TickTimer
    measurement clock; returns (engine, tracer, requests)."""
    reqs = synthetic_requests(
        SMOKE, n, prompt_len=3, max_new_tokens=4, seed=reqs_seed
    )
    if n >= 2:  # one malformed straggler exercises the error-retire path
        reqs[-1].max_new_tokens = MAX_LEN + 8
    tracer = Tracer(clock=TickTimer(1e-3))
    eng = StreamingEngine(
        SMOKE, smoke_params, n_blocks=3, max_len=MAX_LEN,
        queue_limit=3, default_ttl_s=30.0,
        chaos=ChaosInjector(seed=chaos_seed, step_fault_rate=0.2),
        timer=TickTimer(1e-3), tracer=tracer,
    )
    eng.serve(reqs)
    return eng, tracer, reqs


def test_engine_trace_is_byte_identical_across_runs(smoke_params):
    """ISSUE 10 acceptance: two runs of the same seeded-chaos trace on the
    virtual clock produce byte-identical Perfetto files."""
    _, tr1, _ = _traced_run(smoke_params)
    _, tr2, _ = _traced_run(smoke_params)
    assert tr1.to_json() == tr2.to_json()
    assert tr1.emitted > 0 and tr1.dropped == 0


def _retire_check(eng, tracer, reqs):
    """Exactly one terminal ``engine.retire`` instant per admitted rid,
    matching the recorded RequestResult status."""
    retires = [e for e in tracer.events() if e["name"] == "engine.retire"]
    by_rid = {}
    for e in retires:
        by_rid.setdefault(e["args"]["rid"], []).append(e["args"]["status"])
    assert set(by_rid) == set(eng.results)
    for rid, statuses in by_rid.items():
        assert len(statuses) == 1, f"rid {rid} retired {len(statuses)} times"
        assert statuses[0] == eng.results[rid].status
        assert statuses[0] in REQUEST_STATUSES
    # every admit instant has a matching terminal retire (admits that shed
    # or error later still retire exactly once — checked above)
    admits = {e["args"]["rid"] for e in tracer.events()
              if e["name"] == "engine.admit"}
    assert admits <= set(by_rid)


def test_engine_timeline_retire_uniqueness(smoke_params):
    eng, tracer, reqs = _traced_run(smoke_params)
    _retire_check(eng, tracer, reqs)


def test_engine_events_carry_virtual_clock_timestamps(smoke_params):
    """prefill/decode complete-events sit inside the serve span and never
    run backwards — the timeline is on the virtual clock, not wall time."""
    _, tracer, _ = _traced_run(smoke_params)
    evs = tracer.events()
    serve = [e for e in evs if e["name"] == "engine.serve"]
    assert len(serve) == 1
    lo, hi = serve[0]["ts"], serve[0]["ts"] + serve[0]["dur"]
    steps = [e for e in evs if e["name"] in ("engine.prefill", "engine.decode")]
    assert steps
    for e in steps:
        assert lo <= e["ts"] and e["ts"] + e["dur"] <= hi
        assert e["dur"] >= 0


if given is not None:

    @settings(max_examples=5, deadline=None)
    @given(
        reqs_seed=st.integers(0, 50),
        chaos_seed=st.integers(0, 50),
        n=st.integers(1, 5),
    )
    def test_retire_uniqueness_property(smoke_params, reqs_seed, chaos_seed, n):
        """Under arbitrary seeded traces + chaos, every admitted request's
        timeline carries exactly one terminal retire instant whose status
        matches the engine's recorded RequestResult."""
        eng, tracer, reqs = _traced_run(
            smoke_params, reqs_seed=reqs_seed, n=n, chaos_seed=chaos_seed
        )
        _retire_check(eng, tracer, reqs)


# ---------------------------------------------------------------------------
# Span nesting under the background tuner's worker thread
# ---------------------------------------------------------------------------


def _toy_spec(costs, name="obs_toy"):
    space = ParamSpace([PerfParam("i", tuple(range(len(costs))))])

    def cost_factory(region, bp, args, kwargs):
        return lambda point: float(costs[point["i"]])

    return KernelSpec(
        name,
        make_region=lambda bp: ATRegion(
            name, space, lambda p: (lambda x: x * (p["i"] + 1))
        ),
        shape_class=lambda x: BasicParams.make(kernel=name),
        cost_factory=cost_factory,
        traffic_class=lambda x: TrafficClass.of(
            "prefill", int(x.shape[0]), int(x.shape[1])
        ),
    )


def _laminar(spans):
    """Complete spans on one track must be properly nested: any two either
    disjoint or one inside the other (the flame-graph invariant)."""
    for a in spans:
        for b in spans:
            a0, a1 = a["ts"], a["ts"] + a["dur"]
            b0, b1 = b["ts"], b["ts"] + b["dur"]
            disjoint = a1 <= b0 or b1 <= a0
            nested = (a0 <= b0 and b1 <= a1) or (b0 <= a0 and a1 <= b1)
            if not (disjoint or nested):
                return False, (a, b)
    return True, None


def test_background_tuner_spans_nest_on_worker_track():
    tracer = Tracer()
    op = AutotunedOp(_toy_spec([3.0, 1.0, 2.0]), db=TuningDB(), tune=False)
    x = jnp.ones((2, 8))
    with use_tracer(tracer):
        with BackgroundTuner() as tuner:
            state = tuner.submit(op, x)
            assert tuner.drain(timeout=60)
    assert state.tuned
    evs = tracer.events()
    worker_tracks = {e["track"] for e in evs if e["name"] == "bgtuner.job"}
    assert len(worker_tracks) == 1  # all tune work on the one worker thread
    track = worker_tracks.pop()
    spans = [e for e in evs if e["ph"] == "X" and e["track"] == track]
    names = {e["name"] for e in spans}
    assert {"bgtuner.job", "tuner.tune", "tuner.trial"} <= names
    ok, pair = _laminar(spans)
    assert ok, f"overlapping spans on worker track: {pair}"
    # tuner.tune nests inside bgtuner.job; every trial inside tuner.tune
    job = next(e for e in spans if e["name"] == "bgtuner.job")
    tune = next(e for e in spans if e["name"] == "tuner.tune")
    assert job["ts"] <= tune["ts"] <= tune["ts"] + tune["dur"] <= job["ts"] + job["dur"]
    for trial in (e for e in spans if e["name"] == "tuner.trial"):
        assert tune["ts"] <= trial["ts"]
        assert trial["ts"] + trial["dur"] <= tune["ts"] + tune["dur"]
    # thread interleaving cannot perturb the export (determinism contract)
    assert tracer.to_json() == tracer.to_json()


def test_disabled_tracer_emits_nothing():
    """With no tracer installed the instrumented paths run silently — the
    zero-cost-when-disabled contract's functional half."""
    assert current_tracer() is None
    op = AutotunedOp(_toy_spec([2.0, 1.0], name="obs_off"), db=TuningDB(),
                     tune=False)
    x = jnp.ones((2, 8))
    with BackgroundTuner() as tuner:
        tuner.submit(op, x)
        assert tuner.drain(timeout=60)
    # nothing to assert on a tracer — the assertion is that this ran with
    # current_tracer() None throughout and no error surfaced


# ---------------------------------------------------------------------------
# Explainability
# ---------------------------------------------------------------------------


def test_explain_reconstructs_decision_chain():
    db = TuningDB()
    op = AutotunedOp(_toy_spec([3.0, 1.0, 2.0], name="obs_explain"), db=db)
    x = jnp.ones((2, 8))
    op(x)  # tunes inline, recording trials + search_completed
    fp = next(iter(db.fingerprints()))
    report = explain_fingerprint(db, fp)
    assert report["kernel"] == "obs_explain"
    assert report["final"]["point"] == {"i": 1}
    assert report["final"]["final"] and report["final"]["source"] == "local_search"
    assert report["search"]["evaluations"] >= 3
    trials = report["measured_trials"]
    assert trials[0]["cost"] <= trials[-1]["cost"]  # ranked best-first
    text = render_report(report)
    assert "obs_explain" in text and "<- winner" in text
    assert "decision:" in text and "local_search" in text


def test_explain_unknown_fingerprint_raises():
    with pytest.raises(KeyError):
        explain_fingerprint(TuningDB(), "no-such-entry")


def test_db_summary_counts():
    db = TuningDB()
    op = AutotunedOp(_toy_spec([2.0, 1.0], name="obs_summary"), db=db)
    op(jnp.ones((2, 8)))
    s = db_summary(db)
    assert s["entries"] == 1 and s["finals"] == 1
    assert s["trials"] >= 2 and s["events"] >= 1
    reg = MetricsRegistry()
    reg.register_stats("tuning_db", s)
    assert "tuning_db_entries 1" in reg.prometheus_text()
