"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.exb import ops as exb_ops
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rglru_scan import ops as rg_ops, ref as rg_ref
from repro.kernels.ssm_scan import ops as ssm_ops, ref as ssm_ref


# ---------------------------------------------------------------------------
# exb (GKV) — oracle conformance lives in test_conformance.py
# ---------------------------------------------------------------------------


def test_exb_vmem_constraint_prunes():
    region = exb_ops.exb_region(dims=(16, 16, 128, 65), vmem_budget=4 * 2**20)
    pts = list(region.space.points())
    assert 0 < len(pts) < region.space.size()
    for p in pts:
        assert exb_ops.vmem_bytes(p["block_iv"], p["block_iz"]) <= 4 * 2**20


# ---------------------------------------------------------------------------
# stress (Seism3D) — oracle conformance lives in test_conformance.py
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# flash attention — hypothesis sweep over shapes/dtypes/blocks
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    nkv_heads=st.integers(1, 2),
    g=st.integers(1, 3),
    log_s=st.integers(5, 7),
    hd=st.sampled_from([8, 16]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 100),
)
def test_flash_attention_property(b, nkv_heads, g, log_s, hd, dtype, seed):
    S = 2**log_s
    H = nkv_heads * g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, S, nkv_heads, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, S, nkv_heads, hd), jnp.float32).astype(dtype)
    o = fa_ops.attention(q, k, v, block_q=32, block_kv=32)
    o_ref = fa_ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), rtol=tol, atol=tol
    )


def test_flash_matches_xla_flash():
    """Pallas kernel ≡ the XLA flash path used by the models."""
    from repro.models.attention import flash_attention_xla

    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 128, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 16), jnp.float32)
    o_pl = fa_ops.attention(q, k, v, block_q=64, block_kv=64)
    o_xla = flash_attention_xla(q, k, v, 64, 64)
    np.testing.assert_allclose(o_pl, o_xla, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssm scan — property: kernel ≡ sequential oracle for random chunkings
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    log_s=st.integers(4, 6),
    d=st.sampled_from([16, 32]),
    n=st.sampled_from([4, 8]),
    chunk_div=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 100),
)
def test_ssm_scan_property(b, log_s, d, n, chunk_div, seed):
    S = 2**log_s
    x, dt, A, Bc, Cc, D = ssm_ref.make_inputs(
        jax.random.PRNGKey(seed), B=b, S=S, D=d, N=n
    )
    y_ref = ssm_ref.ssm_scan_ref(x, dt, A, Bc, Cc, D)
    y = ssm_ops.scan(x, dt, A, Bc, Cc, D, block_d=d, chunk=S // chunk_div)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_ssm_state_continuity_across_chunks():
    """Chunked kernel must carry h across chunk boundaries exactly — compare
    chunk=S (single) vs chunk=S/4 on inputs with long-range decay."""
    x, dt, A, Bc, Cc, D = ssm_ref.make_inputs(jax.random.PRNGKey(7), B=1, S=64, D=16, N=4)
    dt = dt * 0.01  # slow decay -> state carries far
    y1 = ssm_ops.scan(x, dt, A, Bc, Cc, D, block_d=16, chunk=64)
    y2 = ssm_ops.scan(x, dt, A, Bc, Cc, D, block_d=16, chunk=16)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    log_s=st.integers(4, 6),
    w=st.sampled_from([16, 32]),
    chunk_div=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 100),
)
def test_rglru_scan_property(b, log_s, w, chunk_div, seed):
    S = 2**log_s
    x, r, i, lam = rg_ref.make_inputs(jax.random.PRNGKey(seed), B=b, S=S, W=w)
    y_ref = rg_ref.rglru_scan_ref(x, r, i, lam)
    y = rg_ops.scan(x, r, i, lam, block_w=w, chunk=S // chunk_div)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_rglru_stability_bound():
    """|h_t| stays bounded when a∈(0,1) and inputs bounded (Griffin's
    sqrt(1-a²) normalization) — property of the kernel math."""
    x, r, i, lam = rg_ref.make_inputs(jax.random.PRNGKey(9), B=1, S=256, W=8)
    x = jnp.clip(x, -1, 1)
    y = rg_ops.scan(x, r, i, lam, block_w=8, chunk=64)
    assert float(jnp.max(jnp.abs(y))) < 10.0
