"""End-to-end dry-run test: one real cell through the production-mesh
lower+compile pipeline in a subprocess (so the 512-device XLA flag never
leaks into this test process)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("mesh_flag", [[], ["--multi-pod"]])
def test_dryrun_single_cell(tmp_path, mesh_flag):
    out = str(tmp_path / "cell.jsonl")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen3-0.6b", "--shape", "decode_32k", "--out", out]
        + mesh_flag,
        env=env, capture_output=True, text=True, timeout=560, cwd=ROOT,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.loads(open(out).readline())
    assert rec["status"] == "ok"
    assert rec["chips"] == (512 if mesh_flag else 256)
    r = rec["roofline"]
    assert r["compute_s"] > 0 and r["memory_s"] > 0
    assert r["bottleneck"] in ("compute", "memory", "collective")
    assert rec["memory"]["per_device_total"] > 0
    assert 0 < rec["useful_flops_ratio"] < 10
