"""MODEL_FLOPS accounting sanity: analytic_step_flops across the pool."""
import pytest

from repro.configs import ARCH_IDS, cells_for, get_config
from repro.models import analytic_param_count, analytic_step_flops


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_step_flops_positive_and_ordered(arch):
    cfg = get_config(arch)
    for cell in cells_for(arch):
        f = analytic_step_flops(cfg, cell.kind, cell.global_batch, cell.seq_len)
        assert f > 0
        if cell.kind == "train":
            fwd = analytic_step_flops(cfg, "prefill", cell.global_batch, cell.seq_len)
            assert f > fwd  # train = fwd + bwd must exceed fwd alone


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_flops_at_least_6nd(arch):
    """Weight term 6·N_active·D is a floor; attention/scan terms only add."""
    cfg = get_config(arch)
    B, S = 256, 4096
    f = analytic_step_flops(cfg, "train", B, S)
    floor = 6.0 * analytic_param_count(cfg, active_only=True) * B * S
    assert f >= floor * 0.999


def test_attention_dominates_at_long_context():
    """At 32k, attention flops must exceed the weight flops for a small
    dense model — the reason 6·N·D alone was replaced (EXPERIMENTS §Roofline)."""
    cfg = get_config("qwen3-0.6b")
    B, S = 32, 32768
    total = analytic_step_flops(cfg, "prefill", B, S)
    weights = 2.0 * analytic_param_count(cfg, active_only=True) * B * S
    assert total > 2.0 * weights
