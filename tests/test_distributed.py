"""Sharding-rule unit tests + HLO analyzer validation (known-FLOP programs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.hlo_analysis import analyze_hlo_text, parse_hlo_computations
from repro.core.cost import collective_bytes_from_hlo, roofline_from_compiled, TPU_V5E
from repro.distributed.sharding import (
    RULES,
    ShardingRule,
    logical_to_spec,
    zero_spec,
)


class _FakeMesh:
    """Mesh stand-in exposing .shape only (rule logic needs nothing else)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


MESH1 = _FakeMesh(data=16, model=16)
MESH2 = _FakeMesh(pod=2, data=16, model=16)


def test_divisibility_guard_replicates_indivisible_axes():
    rule = RULES["tp"]
    # 8 kv heads on a 16-way model axis -> replicated
    spec = logical_to_spec(rule, (22, 2048, 8, 64), ("layers", "embed", "kv_heads", "head_dim"), MESH1)
    assert spec == P()
    # 32 q heads -> sharded
    spec = logical_to_spec(rule, (22, 2048, 32, 64), ("layers", "embed", "q_heads", "head_dim"), MESH1)
    assert spec == P(None, None, "model")


def test_pod_axis_dropped_on_single_pod_mesh():
    rule = RULES["tp"]
    spec1 = logical_to_spec(rule, (256, 4096), ("batch", "seq"), MESH1)
    assert spec1 == P("data",)
    spec2 = logical_to_spec(rule, (256, 4096), ("batch", "seq"), MESH2)
    assert spec2 == P(("pod", "data"),)


def test_axis_never_used_twice_in_one_array():
    rule = ShardingRule.make("t", a="model", b="model")
    spec = logical_to_spec(rule, (32, 32), ("a", "b"), MESH1)
    assert spec == P("model",)  # second dim must not reuse "model"


def test_zero_spec_adds_data_axis_to_largest_free_dim():
    rule = RULES["tp"]
    spec = zero_spec(rule, (22, 2048, 32, 64), ("layers", "embed", "q_heads", "head_dim"), MESH1)
    assert spec == P(None, "data", "model")  # embed dim (largest free, /16)
    # scalar opt count: stays unsharded
    assert zero_spec(rule, (), (), MESH1) == P()


def test_kvseq_rule_shards_cache_slots():
    rule = RULES["tp_kvseq"]
    spec = logical_to_spec(
        rule, (22, 128, 32768, 8, 64),
        ("layers", "batch", "kv_slots", "act_kv", None), MESH1,
    )
    assert spec == P(None, "data", "model")


# ---------------------------------------------------------------------------
# HLO analyzer: trip counts, collectives, fusion laziness
# ---------------------------------------------------------------------------


def test_analyzer_scan_equals_unroll():
    W = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    X = jax.ShapeDtypeStruct((32, 128), jnp.float32)

    def f_scan(w, x):
        return jax.lax.scan(lambda h, wl: (jnp.tanh(h @ wl), None), x, w)[0]

    def f_unroll(w, x):
        for i in range(6):
            x = jnp.tanh(x @ w[i])
        return x

    fs = analyze_hlo_text(jax.jit(f_scan).lower(W, X).compile().as_text())
    fu = analyze_hlo_text(jax.jit(f_unroll).lower(W, X).compile().as_text())
    expected = 6 * 2 * 32 * 128 * 128
    assert abs(fs.flops - expected) / expected < 0.05
    assert abs(fs.flops - fu.flops) / fu.flops < 0.01
    assert not fs.warnings


def test_analyzer_nested_scan_multiplies():
    W = jax.ShapeDtypeStruct((2, 64, 64), jnp.float32)
    X = jax.ShapeDtypeStruct((16, 64), jnp.float32)

    def f(w, x):
        def outer(h, _):
            def inner(h2, __):
                return jnp.tanh(h2 @ w[0]), None
            return jax.lax.scan(inner, h, None, length=5)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    c = analyze_hlo_text(jax.jit(f).lower(W, X).compile().as_text())
    expected = 15 * 2 * 16 * 64 * 64
    assert abs(c.flops - expected) / expected < 0.05


def test_analyzer_seq_scan_bytes_not_exploded():
    """The falcon regression: a scan slicing one step per iteration from a
    stacked buffer must charge slice bytes, not the whole buffer."""
    X = jax.ShapeDtypeStruct((1024, 64), jnp.float32)

    def f(xs):
        def body(h, x_t):
            return h * 0.9 + x_t, h
        return jax.lax.scan(body, jnp.zeros((64,)), xs)

    c = analyze_hlo_text(jax.jit(f).lower(X).compile().as_text())
    # true traffic ~ read xs once + write ys once + O(1)/step state ≈ few MB
    assert c.bytes < 30e6, f"bytes exploded: {c.bytes:.2e}"


def test_roofline_terms_from_compiled():
    def f(a, b):
        return a @ b

    A = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    lowered = jax.jit(f).lower(A, A)
    compiled = lowered.compile()
    terms = roofline_from_compiled(lowered, compiled, n_chips=1, hw=TPU_V5E)
    expected_flops = 2 * 512**3
    assert abs(terms.hlo_flops - expected_flops) / expected_flops < 0.05
    assert terms.bottleneck in ("compute", "memory", "collective")
    assert terms.total_s == max(terms.compute_s, terms.memory_s, terms.collective_s)


def test_collective_regex_on_synthetic_hlo():
    txt = """
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %all-reduce = f32[16,16]{1,0} all-reduce(%p), channel_id=1, replica_groups={{0,1}}
  ROOT %all-gather = f32[16,32]{1,0} all-gather(%all-reduce), channel_id=2, dimensions={1}
}
"""
    c = analyze_hlo_text(txt)
    assert c.collectives["all-reduce"] == 16 * 16 * 4
    assert c.collectives["all-gather"] == 16 * 32 * 4
