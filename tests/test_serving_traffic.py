"""Traffic-class serving autotuner tests (ISSUE 2 acceptance).

Covers: traffic-class bucketing, the traffic dimension in the TuningDB key,
background-tuner hand-off (safe default -> tuned hot swap, off the calling
thread), DB merge of concurrently tuned classes, chunked-degree semantic
equivalence, and the headline invariant — a Server with a BackgroundTuner
performs **zero** tuning cost evaluations on the serve hot path, cold and
after warmup.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    ATRegion,
    AutotunedOp,
    BasicParams,
    KernelSpec,
    ParamSpace,
    PerfParam,
    TrafficClass,
    TuningDB,
    bucket_pow2,
)
from repro.data import mixed_traffic_trace, synthetic_requests
from repro.distributed.sharding import mesh_bp_entries, mesh_fingerprint
from repro.models import init_params, param_specs
from repro.runtime import BackgroundTuner, Server

KEY = jax.random.PRNGKey(0)
SMOKE = get_config("tinyllama-1.1b", smoke=True)


def _traffic_spec(costs, calls, name="toy_traffic"):
    """Toy spec whose default point (i=0) is deliberately not the argmin and
    whose cost function records which thread evaluated it."""
    space = ParamSpace([PerfParam("i", tuple(range(len(costs))))])

    def cost_factory(region, bp, args, kwargs):
        def cost(point):
            calls.append((point["i"], threading.get_ident()))
            return float(costs[point["i"]])

        return cost

    return KernelSpec(
        name,
        make_region=lambda bp: ATRegion(name, space, lambda p: (lambda x: x * p["i"])),
        shape_class=lambda x: BasicParams.make(kernel=name),
        cost_factory=cost_factory,
        traffic_class=lambda x: TrafficClass.of(
            "prefill", int(x.shape[0]), int(x.shape[1])
        ),
    )


# ---------------------------------------------------------------------------
# Traffic-class bucketing
# ---------------------------------------------------------------------------


def test_bucket_pow2_rounds_up():
    assert [bucket_pow2(n) for n in (1, 2, 3, 5, 8, 9, 100)] == [
        1, 2, 4, 8, 8, 16, 128,
    ]
    with pytest.raises(ValueError):
        bucket_pow2(0)


def test_traffic_class_bucketing_and_label():
    tc = TrafficClass.of("prefill", 3, 100)
    assert (tc.batch_bucket, tc.seq_bucket) == (4, 128)
    assert tc.label == "prefill/b4/s128"
    # same bucket -> same class; over the boundary -> a new class
    assert TrafficClass.of("prefill", 4, 65) == tc
    assert TrafficClass.of("prefill", 4, 129) != tc
    assert TrafficClass.of("decode", 4, 100) != tc
    with pytest.raises(ValueError):
        TrafficClass.of("train", 1, 1)
    assert TrafficClass.from_bp_entries(tc.bp_entries()) == tc


def test_traffic_class_is_a_db_dimension():
    """Calls in the same bucket share one tuning entry; crossing a bucket
    boundary tunes a fresh class — traffic is part of the BP fingerprint."""
    calls = []
    op = AutotunedOp(_traffic_spec([3.0, 1.0], calls), db=TuningDB())
    op(jnp.ones((2, 100)))
    assert len(calls) == 2
    op(jnp.ones((2, 80)))  # same b2/s128 bucket: no re-tune
    assert len(calls) == 2
    op(jnp.ones((2, 200)))  # s256 bucket: its own search
    assert len(calls) == 4
    states = list(op.states().values())
    assert sorted(s.traffic.label for s in states) == [
        "prefill/b2/s128", "prefill/b2/s256",
    ]
    assert len(op.db.traffic_classes()) == 2
    assert len(op.db.entries_matching(phase="prefill")) == 2
    assert op.db.entries_matching(phase="decode") == {}


# ---------------------------------------------------------------------------
# Background tuner: default -> tuned hand-off, off the calling thread
# ---------------------------------------------------------------------------


def test_background_handoff_default_then_hot_swap():
    calls = []
    op = AutotunedOp(_traffic_spec([3.0, 1.0, 2.0], calls), db=TuningDB(), tune=False)
    x = jnp.ones((2, 16))
    with BackgroundTuner() as tuner:
        state = tuner.submit(op, x)
        # submit never evaluates on the caller: the safe default is live
        assert state.region.selected == {"i": 0}
        assert not state.tuned and not state.from_cache
        assert tuner.drain(timeout=60)
        assert state.region.selected == {"i": 1}  # the hot swap
        assert state.tuned
        # every evaluation ran on the worker thread, none on ours
        assert len(calls) == 3
        assert all(t != threading.get_ident() for _, t in calls)
        assert state.tune_thread != threading.get_ident()
        # top-k warmed off-path: demotion switching stays free
        assert state.warmed >= 1 and state.region.is_compiled(state.region.selected)
        assert tuner.tuned_labels == ["prefill/b2/s16"]
        assert tuner.background_evaluations == 3
        assert tuner.errors == []


def test_background_submit_dedupes_inflight_classes():
    calls, started = [], threading.Event()
    release = threading.Event()

    space = ParamSpace([PerfParam("i", (0, 1))])

    def cost_factory(region, bp, args, kwargs):
        def cost(point):
            started.set()
            release.wait(30)  # hold the worker so resubmits race the tune
            calls.append(point["i"])
            return float(point["i"] + 1)

        return cost

    spec = KernelSpec(
        "dedupe",
        make_region=lambda bp: ATRegion(
            "dedupe", space, lambda p: (lambda x: x)
        ),
        shape_class=lambda x: BasicParams.make(kernel="dedupe"),
        cost_factory=cost_factory,
        traffic_class=lambda x: TrafficClass.of("prefill", 1, int(x.shape[1])),
    )
    op = AutotunedOp(spec, db=TuningDB(), tune=False)
    x = jnp.ones((1, 8))
    with BackgroundTuner() as tuner:
        s1 = tuner.submit(op, x)
        assert started.wait(30)
        s2 = tuner.submit(op, x)  # same class while tuning: not re-queued
        assert s1 is s2 and tuner.pending == 1
        release.set()
        assert tuner.drain(timeout=60)
        assert len(tuner.completed) == 1


def test_background_failed_class_is_not_retried():
    """A class whose search raises keeps serving the safe default and is
    never re-enqueued (no silent background retry storm); the failure stays
    visible in errors/failed_labels."""
    calls = []
    space = ParamSpace([PerfParam("i", (0, 1))])

    def cost_factory(region, bp, args, kwargs):
        def cost(point):
            calls.append(point["i"])
            raise RuntimeError("boom")

        return cost

    spec = KernelSpec(
        "failing",
        make_region=lambda bp: ATRegion("failing", space, lambda p: (lambda x: x)),
        shape_class=lambda x: BasicParams.make(kernel="failing"),
        cost_factory=cost_factory,
        traffic_class=lambda x: TrafficClass.of("prefill", 1, int(x.shape[1])),
    )
    op = AutotunedOp(spec, db=TuningDB(), tune=False)
    x = jnp.ones((1, 8))
    with BackgroundTuner() as tuner:
        tuner.submit(op, x)
        assert tuner.drain(timeout=30)
        assert tuner.failed_labels == ["prefill/b1/s8"]
        n_calls = len(calls)
        state = tuner.submit(op, x)  # resubmission of a failed class: no-op
        assert tuner.drain(timeout=30)
        assert len(calls) == n_calls and len(tuner.errors) == 1
        assert state.region.selected == {"i": 0}  # still the safe default


def test_db_merge_of_concurrently_tuned_classes():
    """Two processes tune disjoint traffic classes into separate DBs; merge
    unions them and both winners stay final (zero re-tune on either side)."""
    calls_a, calls_b = [], []
    db_a, db_b = TuningDB(), TuningDB()
    AutotunedOp(_traffic_spec([3.0, 1.0], calls_a), db=db_a)(jnp.ones((2, 16)))
    AutotunedOp(_traffic_spec([2.0, 4.0], calls_b), db=db_b)(jnp.ones((4, 64)))

    db_a.merge(db_b)
    labels = [tc.label for tc in db_a.traffic_classes()]
    assert labels == ["prefill/b2/s16", "prefill/b4/s64"]

    # a fresh op over the merged DB serves both classes with zero evaluations
    calls = []
    op = AutotunedOp(_traffic_spec([0.0, 0.0], calls), db=db_a)
    assert op.resolve(jnp.ones((2, 16))).from_cache
    assert op.resolve(jnp.ones((4, 64))).from_cache
    assert calls == []
    assert op.resolve(jnp.ones((2, 16))).region.selected == {"i": 1}
    assert op.resolve(jnp.ones((4, 64))).region.selected == {"i": 0}


# ---------------------------------------------------------------------------
# Mesh-shape DB keys
# ---------------------------------------------------------------------------


def test_mesh_fingerprint_keys_bp():
    assert mesh_fingerprint(None) == "host"
    assert mesh_bp_entries() == {"mesh": "host"}
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    assert mesh_fingerprint(mesh) == "data1xmodel1"
    a = BasicParams.make(kernel="k", **mesh_bp_entries(mesh))
    b = BasicParams.make(kernel="k", **mesh_bp_entries(None))
    assert a.fingerprint() != b.fingerprint()  # resharding -> fresh entries


# ---------------------------------------------------------------------------
# Serving: chunked-degree equivalence + the zero-hot-path-evals invariant
# ---------------------------------------------------------------------------


def _smoke_server(**kw):
    params = init_params(KEY, param_specs(SMOKE))
    return Server(SMOKE, params, batch_size=2, max_len=64, **kw), params


def test_exact_batch_size_keys_serve_entries():
    """Two servers whose batch sizes share a pow2 traffic bucket must not
    share tuned winners: the degree domain is 'divisors of batch_size', so a
    degree tuned at batch 4 is invalid (or row-dropping) at batch 3."""
    params = init_params(KEY, param_specs(SMOKE))
    db = TuningDB()

    def prefill_state(server, plen=8):
        reqs = synthetic_requests(SMOKE, server.batch_size, plen, 1)
        batch = server._batch_inputs(reqs, plen)
        return server.prefill_op.resolve_deferred(server.params, batch)

    s3 = Server(SMOKE, params, batch_size=3, max_len=64, tuning_db=db)
    st3 = prefill_state(s3)
    s4 = Server(SMOKE, params, batch_size=4, max_len=64, tuning_db=db)
    st4 = prefill_state(s4)
    assert st3.traffic == st4.traffic  # same prefill/b4 bucket...
    assert st3.bp.fingerprint() != st4.bp.fingerprint()  # ...distinct entries
    assert st3.region.space.size() == 1  # batch 3: only degree 1 is valid
    assert st4.region.space.size() == 3  # batch 4: degrees (1, 2, 4)


def test_chunked_degree_candidates_are_semantically_identical():
    """degree=2 (batch chunked) must reproduce degree=1 exactly — switching
    candidates mid-serve cannot change greedy outputs."""
    server, _ = _smoke_server()
    trace = mixed_traffic_trace(SMOKE, 2, seed=3, scale=0.25)
    plen = max(len(r.prompt) for r in trace)
    batch = server._batch_inputs(trace, plen)

    state = server.prefill_op.resolve(server.params, batch)
    f1 = state.region.candidate({"degree": 1})
    f2 = state.region.candidate({"degree": 2})
    logits1, cache1 = f1(server.params, batch)
    logits2, cache2 = f2(server.params, batch)
    np.testing.assert_allclose(
        np.asarray(logits1, np.float32), np.asarray(logits2, np.float32),
        rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_array_equal(
        np.argmax(logits1, axis=-1), np.argmax(logits2, axis=-1)
    )

    dbatch = {"tokens": jnp.argmax(logits1, axis=-1).astype(jnp.int32)[:, None]}
    dstate = server.decode_op.resolve(server.params, dbatch, cache1)
    d1, _ = dstate.region.candidate({"degree": 1})(server.params, dbatch, cache1)
    d2, _ = dstate.region.candidate({"degree": 2})(server.params, dbatch, cache2)
    np.testing.assert_allclose(
        np.asarray(d1, np.float32), np.asarray(d2, np.float32),
        rtol=1e-5, atol=1e-5,
    )


def test_chunked_degree_handles_hybrid_cache_layout():
    """Hybrid-family caches mix (layers, B, ...) and tail (B, ...) leaves;
    chunked candidates must split/concat the right axis per leaf."""
    cfg = get_config("recurrentgemma-2b", smoke=True)
    params = init_params(KEY, param_specs(cfg))
    server = Server(cfg, params, batch_size=2, max_len=64)
    reqs = synthetic_requests(cfg, 2, 8, 1)
    batch = server._batch_inputs(reqs, 8)

    state = server.prefill_op.resolve_deferred(server.params, batch)
    l1, c1 = state.region.candidate({"degree": 1})(server.params, batch)
    l2, c2 = state.region.candidate({"degree": 2})(server.params, batch)
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32),
        rtol=1e-5, atol=1e-5,
    )
    dbatch = {"tokens": jnp.argmax(l1, axis=-1).astype(jnp.int32)[:, None]}
    dstate = server.decode_op.resolve_deferred(server.params, dbatch, c1)
    d1, _ = dstate.region.candidate({"degree": 1})(server.params, dbatch, c1)
    d2, _ = dstate.region.candidate({"degree": 2})(server.params, dbatch, c2)
    np.testing.assert_allclose(
        np.asarray(d1, np.float32), np.asarray(d2, np.float32),
        rtol=1e-5, atol=1e-5,
    )


def test_server_background_tuning_zero_hot_path_evaluations():
    """ISSUE 2 acceptance: on a mixed prefill/decode trace the serve hot path
    performs zero tuning cost evaluations, cold AND after warmup — every
    evaluation happens on the background worker."""
    trace = mixed_traffic_trace(SMOKE, 4, seed=11, scale=0.25)
    with BackgroundTuner() as tuner:
        server, params = _smoke_server(background_tuner=tuner)
        out = server.run(trace)  # cold: unseen classes queue, defaults serve
        assert len(out) == len(trace)
        assert server.hot_path_cost_evaluations == 0
        assert len(server.traffic_classes_seen) >= 2  # mixed trace, >1 class

        assert tuner.drain(timeout=300)
        assert tuner.errors == []
        assert tuner.background_evaluations > 0
        # warm replay: tuned winners serve, still zero hot-path evaluations
        server.run(trace)
        assert server.hot_path_cost_evaluations == 0
        serve_thread = threading.get_ident()
        for op in (server.prefill_op, server.decode_op):
            for st in op.states().values():
                assert st.tuned
                assert st.tune_thread != serve_thread
        # degree protocol: tuned degrees mirrored, max restored on exit
        for label, _ in tuner.completed:
            assert server.degree.tuned(label) in server._degree_domain()
        assert server.degree.current == server.degree.max_degree

        # a second server over the same DB is warm from the first request on
        server2, _ = _smoke_server(background_tuner=tuner, tuning_db=server.db)
        server2.run(trace)
        assert server2.hot_path_cost_evaluations == 0
        assert all(
            st.from_cache
            for op in (server2.prefill_op, server2.decode_op)
            for st in op.states().values()
        )


def test_server_inline_tuning_pays_on_the_hot_path():
    """Accounting sanity: without the background tuner, inline tuning is
    correctly attributed to the serving thread (the bench baseline)."""
    trace = mixed_traffic_trace(SMOKE, 2, seed=5, scale=0.25)
    server, _ = _smoke_server(inline_tune=True)
    server.run(trace)
    assert server.hot_path_cost_evaluations > 0
    assert server.stats.batch_latencies  # p50/p99 source is populated
    assert server.stats.latency_percentile(99) >= server.stats.latency_percentile(50)
