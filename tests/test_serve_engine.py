"""Continuous-batching engine tests (ISSUE 6 acceptance).

Covers: the BlockAllocator free list, paged-cache bookkeeping, the serve
loop's fixed wasted-decode and token-accounting bugs (exact decode counts,
real delivered tokens only), the `_slice_axis` / duplicate-rid guards, the
tail-batch + heterogeneous ``max_new_tokens`` property, engine-vs-sequential
conformance for a dense and a VLM config, open-loop trace determinism, and
the headline invariant carried over from the static server: an engine with a
BackgroundTuner performs **zero** tuning cost evaluations on the hot path,
cold and after drain — with the scheduler-knob classes tuned off it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import bursty_open_loop_trace, synthetic_requests
from repro.data.pipeline import ServingRequest
from repro.models import init_params, param_specs
from repro.runtime import (
    BackgroundTuner,
    BlockAllocator,
    PagedKVCache,
    Server,
    StreamingEngine,
)
from repro.runtime.serve import _slice_axis, check_unique_rids

KEY = jax.random.PRNGKey(0)
SMOKE = get_config("tinyllama-1.1b", smoke=True)


@pytest.fixture(scope="module")
def smoke_params():
    return init_params(KEY, param_specs(SMOKE))


def _reference(cfg, params, reqs, max_len):
    """One-request-at-a-time greedy decode: the exactness oracle."""
    srv = Server(cfg, params, batch_size=1, max_len=max_len)
    out = {}
    for r in reqs:
        out.update(srv.run([r]))
    return out


# ---------------------------------------------------------------------------
# BlockAllocator / PagedKVCache bookkeeping
# ---------------------------------------------------------------------------


def test_block_allocator_free_list():
    alloc = BlockAllocator(3)
    assert alloc.free == 3 and alloc.in_use == 0
    a, b = alloc.allocate(), alloc.allocate()
    assert alloc.in_use == 2 and alloc.peak_in_use == 2
    alloc.release(a)
    assert alloc.free == 2
    c = alloc.allocate()
    d = alloc.allocate()
    assert len({a, b, c, d}) >= 3  # blocks recycle, never invent new ids
    with pytest.raises(RuntimeError):
        alloc.allocate()  # pool exhausted
    with pytest.raises(ValueError):
        alloc.release(99)  # out of range
    alloc.release(b)
    with pytest.raises(ValueError):
        alloc.release(b)  # double free
    assert alloc.peak_in_use == 3


def test_paged_cache_block_table():
    cache = PagedKVCache(SMOKE, n_blocks=2, capacity=8)
    cache.allocate(rid=7)
    with pytest.raises(ValueError):
        cache.allocate(rid=7)  # rid already holds a block
    cache.allocate(rid=9)
    with pytest.raises(RuntimeError):
        cache.allocate(rid=11)
    cache.release(7)
    assert cache.free == 1
    cache.allocate(rid=11)
    assert cache.block_of(11) in (0, 1)


# ---------------------------------------------------------------------------
# Serve-loop bugfix regressions
# ---------------------------------------------------------------------------


def test_slice_axis_rejects_uneven_split():
    x = jnp.zeros((2, 6))
    assert _slice_axis(x, 0, 1, 2).shape == (1, 6)
    with pytest.raises(ValueError, match="cannot split"):
        _slice_axis(x, 0, 0, 3)  # 2 rows into 3 chunks would truncate


def test_duplicate_rid_rejected(smoke_params):
    reqs = synthetic_requests(SMOKE, 2, prompt_len=4, max_new_tokens=2)
    reqs[1].rid = reqs[0].rid
    with pytest.raises(ValueError, match="duplicate request rid"):
        check_unique_rids(reqs)
    with pytest.raises(ValueError, match="duplicate request rid"):
        Server(SMOKE, smoke_params, batch_size=2).run(reqs)
    # the un-hardened engine keeps the strict upfront contract
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=2, max_len=16,
                          hardened=False)
    with pytest.raises(ValueError, match="duplicate request rid"):
        eng.serve(reqs)
    # the hardened default absorbs the duplicate: the first wins, the
    # duplicate is recorded for the operator and never double-served
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=2, max_len=16)
    out = eng.serve(reqs)
    assert list(out) == [reqs[0].rid]
    assert eng.duplicate_rids == [reqs[0].rid]
    assert eng.stats.duplicates == 1


def test_server_rejects_malformed_request(smoke_params):
    """The static server's strict contract: named errors, not jit shape
    explosions (the hardened engine absorbs the same inputs per-request)."""
    srv = Server(SMOKE, smoke_params, batch_size=1)
    empty = synthetic_requests(SMOKE, 1, prompt_len=4, max_new_tokens=2)
    empty[0].prompt = empty[0].prompt[:0]
    with pytest.raises(ValueError, match="empty prompt"):
        srv.run(empty)
    zero = synthetic_requests(SMOKE, 1, prompt_len=4, max_new_tokens=2)
    zero[0].max_new_tokens = 0
    with pytest.raises(ValueError, match="max_new_tokens"):
        srv.run(zero)


def test_server_exact_decode_count_and_tokens(smoke_params):
    """The old loop ran ``n_steps`` decodes and threw the last token away,
    and credited ``n_steps * batch`` tokens to padded/over-max rows."""
    reqs = synthetic_requests(SMOKE, 5, prompt_len=4, max_new_tokens=3)
    for r, mnt in zip(reqs, (3, 1, 2, 3, 2)):
        r.max_new_tokens = mnt
    srv = Server(SMOKE, smoke_params, batch_size=2, max_len=16)
    out = srv.run(reqs)
    # groups (3,1) (2,3) (2): prefill yields token #1, decodes cover the
    # rest of the group max — (3-1) + (3-1) + (2-1) at degree 1
    assert srv.stats.prefill_calls == 3
    assert srv.stats.decode_calls == 5
    # delivered tokens only: never the padded tail, never beyond a row's own
    # max_new_tokens
    assert srv.stats.tokens_out == sum(r.max_new_tokens for r in reqs)
    for r in reqs:
        assert len(out[r.rid]) == r.max_new_tokens


def test_server_tail_batch_matches_sequential(smoke_params):
    """Trace length not a multiple of batch_size + heterogeneous
    max_new_tokens must match the one-request-at-a-time oracle."""
    reqs = synthetic_requests(SMOKE, 5, prompt_len=6, max_new_tokens=4)
    for r, mnt in zip(reqs, (4, 1, 3, 2, 4)):
        r.max_new_tokens = mnt
    ref = _reference(SMOKE, smoke_params, reqs, max_len=16)
    out = Server(SMOKE, smoke_params, batch_size=2, max_len=16).run(reqs)
    assert out == ref


# ---------------------------------------------------------------------------
# Engine conformance
# ---------------------------------------------------------------------------


def _engine_conformance(cfg, n_requests, max_len):
    params = init_params(KEY, param_specs(cfg))
    trace = bursty_open_loop_trace(cfg, n_requests, seed=3, scale=0.25)
    ref = _reference(cfg, params, trace, max_len)
    eng = StreamingEngine(cfg, params, n_blocks=4, max_len=max_len)
    out = eng.serve(trace)
    assert out == ref
    s = eng.stats
    assert s.tokens_out == sum(r.max_new_tokens for r in trace)
    assert set(s.ttft_s) == {r.rid for r in trace}
    assert set(s.finish_s) == {r.rid for r in trace}
    # blocks recycled: everything released, peak bounded by the pool
    assert eng.cache.free == eng.cache.n_blocks
    assert eng.cache.block_table == {}
    assert 1 <= eng.cache.allocator.peak_in_use <= eng.cache.n_blocks
    return eng


def test_engine_matches_sequential_dense(smoke_params):
    trace = bursty_open_loop_trace(SMOKE, 6, seed=3, scale=0.25)
    ref = _reference(SMOKE, smoke_params, trace, max_len=32)
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=4, max_len=32)
    out = eng.serve(trace)
    assert out == ref
    assert eng.stats.tokens_out == sum(r.max_new_tokens for r in trace)
    assert eng.cache.free == eng.cache.n_blocks  # all blocks retired
    assert eng.cache.allocator.peak_in_use >= 1


def test_engine_matches_sequential_vlm():
    cfg = get_config("qwen2-vl-2b", smoke=True)
    _engine_conformance(cfg, n_requests=4, max_len=32)


def test_engine_rejects_overlong_request(smoke_params):
    bad = synthetic_requests(SMOKE, 1, prompt_len=6, max_new_tokens=4)
    # un-hardened: overlong is a caller bug and raises upfront
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=2, max_len=8,
                          hardened=False)
    with pytest.raises(ValueError, match="KV slots"):
        eng.serve(bad)
    # hardened: per-request validation retires it with ``error`` status
    # instead of taking the whole trace down
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=2, max_len=8)
    out = eng.serve(bad)
    assert out == {}
    res = eng.results[bad[0].rid]
    assert res.status == "error" and "malformed" in res.detail
    assert eng.stats.errors == 1


# ---------------------------------------------------------------------------
# Off-hot-path scheduler tuning
# ---------------------------------------------------------------------------


def test_engine_zero_hot_evals_and_tuned_scheduler(smoke_params):
    trace = bursty_open_loop_trace(SMOKE, 6, seed=5, scale=0.25)
    with BackgroundTuner() as tuner:
        eng = StreamingEngine(
            SMOKE, smoke_params, n_blocks=4, max_len=32,
            background_tuner=tuner,
        )
        out_cold = eng.serve(trace)
        assert eng.hot_path_cost_evaluations == 0  # cold: defaults only
        assert tuner.drain(timeout=600)
        assert not tuner.errors
        assert eng.tuned_scheduler_classes  # knob classes landed off-path
        out_warm = eng.serve(trace)
        assert eng.hot_path_cost_evaluations == 0  # warm: winners, no evals
        # greedy decode is selection-invariant: every candidate (chunking
        # degree, scheduler knobs) must produce the same tokens
        assert out_cold == out_warm


# ---------------------------------------------------------------------------
# Open-loop trace
# ---------------------------------------------------------------------------


def test_bursty_trace_deterministic():
    a = bursty_open_loop_trace(SMOKE, 9, seed=11, scale=0.5, burst_size=3)
    b = bursty_open_loop_trace(SMOKE, 9, seed=11, scale=0.5, burst_size=3)
    assert [r.rid for r in a] == [r.rid for r in b]
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    # arrivals sorted, grouped into ceil(9/3)=3 bursts ~burst_gap apart
    arr = [r.arrival_s for r in a]
    assert arr == sorted(arr)
    assert max(arr) >= 2 * 0.05
    with pytest.raises(ValueError, match="burst_size"):
        bursty_open_loop_trace(SMOKE, 4, burst_size=0)


def test_bursty_trace_mix_matches_mixed_trace():
    from repro.data import mixed_traffic_trace

    mixed = mixed_traffic_trace(SMOKE, 6, seed=2, scale=0.5)
    bursty = bursty_open_loop_trace(SMOKE, 6, seed=2, scale=0.5)
    by_rid = {r.rid: r for r in bursty}
    for m in mixed:
        assert np.array_equal(by_rid[m.rid].prompt, m.prompt)
        assert by_rid[m.rid].max_new_tokens == m.max_new_tokens
