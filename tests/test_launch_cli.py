"""Subprocess smoke tests for the launch CLIs (dryrun is covered in
test_dryrun.py; here: tune_cell's tuner-driven before-execution AT and the
train/serve entry points)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


def _run(args, timeout=560):
    return subprocess.run(
        [sys.executable, "-m"] + args, env=ENV, capture_output=True,
        text=True, timeout=timeout, cwd=ROOT,
    )


def test_tune_cell_selects_kvseq_for_decode(tmp_path):
    """The FIBER tuner must discover the KV-length sharding rule on a decode
    cell (EXPERIMENTS.md §Perf cell 5) — end-to-end through lower+compile."""
    db = str(tmp_path / "db.json")
    proc = _run(
        ["repro.launch.tune_cell", "--arch", "qwen3-0.6b",
         "--shape", "decode_32k", "--db", db]
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "best PP" in proc.stdout
    assert "'rule': 'tp_kvseq'" in proc.stdout
    data = json.load(open(db))
    assert data["schema_version"] == 2
    assert len(data["entries"]) == 1  # one BP entry persisted


def test_train_cli_runs():
    proc = _run(
        ["repro.launch.train", "--arch", "tinyllama-1.1b", "--steps", "3",
         "--batch", "2", "--seq", "32"]
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "final loss" in proc.stdout


def test_serve_cli_runs():
    proc = _run(
        ["repro.launch.serve", "--arch", "qwen3-0.6b", "--requests", "2",
         "--prompt-len", "8", "--new-tokens", "4"]
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "served 2 requests" in proc.stdout
