"""Hardened-engine failure-path tests (ISSUE 8 acceptance, docs/serving.md).

Covers: the typed ``KVPoolExhausted`` pool contract and rid-idempotent
``PagedKVCache.release``, per-request deadlines/TTL (``timed_out``
retirement with partial tokens), priority-driven KV-block preemption with
bit-exact forced-replay recompute, the three load-shedding policies, fault
isolation (a poisoned request retires ``error`` while its batchmates
survive; transient faults are retried invisibly), the stall watchdog
(``EngineStalled`` instead of a silent wedge), the un-hardened crash
baseline, and the hypothesis drain property: under arbitrary seeded
traces + chaos the hardened engine never raises, retires every request
exactly once with a valid status, bit-matches the sequential oracle on
``ok`` requests, frees every KV block, and pays zero hot-path tuning
evaluations.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property section skips, unit tests still run
    given = None

from repro.configs import get_config
from repro.data import adversarial_trace, synthetic_requests
from repro.data.pipeline import ServingRequest
from repro.models import init_params, param_specs
from repro.runtime import (
    BlockAllocator,
    ChaosError,
    ChaosInjector,
    EngineStalled,
    KVPoolExhausted,
    PagedKVCache,
    Server,
    StreamingEngine,
)
from repro.runtime.engine import REQUEST_STATUSES

KEY = jax.random.PRNGKey(0)
SMOKE = get_config("tinyllama-1.1b", smoke=True)
MAX_LEN = 16


@pytest.fixture(scope="module")
def smoke_params():
    return init_params(KEY, param_specs(SMOKE))


@pytest.fixture(scope="module")
def oracle_server(smoke_params):
    """One shared sequential-oracle server so jits compile once."""
    return Server(SMOKE, smoke_params, batch_size=1, max_len=MAX_LEN)


def _oracle(srv, reqs):
    out = {}
    for r in reqs:
        out.update(srv.run([ServingRequest(
            rid=r.rid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
        )]))
    return out


def _drained(eng, reqs):
    """The drain contract every hardened serve must satisfy."""
    rids = {r.rid for r in reqs}
    assert set(eng.results) == rids
    assert all(res.status in REQUEST_STATUSES for res in eng.results.values())
    assert eng.cache.free == eng.cache.n_blocks
    assert eng.cache.block_table == {}
    assert eng.hot_path_cost_evaluations == 0


# ---------------------------------------------------------------------------
# Typed pool exhaustion + idempotent release
# ---------------------------------------------------------------------------


def test_kv_pool_exhausted_typed():
    alloc = BlockAllocator(2)
    alloc.allocate()
    alloc.allocate()
    with pytest.raises(KVPoolExhausted) as ei:
        alloc.allocate()
    exc = ei.value
    assert isinstance(exc, RuntimeError)  # pre-hardening except clauses hold
    assert (exc.n_blocks, exc.in_use, exc.free) == (2, 2, 0)
    assert "allocator.free" in str(exc)


def test_cache_release_is_rid_idempotent():
    cache = PagedKVCache(SMOKE, n_blocks=2, capacity=8)
    cache.allocate(rid=7)
    assert cache.free == 1
    cache.release(7)
    cache.release(7)  # every retirement path releases unconditionally
    cache.release(99)  # never-allocated rid: also a no-op
    assert cache.free == 2 and cache.block_table == {}
    # the allocator itself stays strict: double-free is still a caller bug
    alloc = BlockAllocator(1)
    b = alloc.allocate()
    alloc.release(b)
    with pytest.raises(ValueError):
        alloc.release(b)


# ---------------------------------------------------------------------------
# Deadlines / TTL
# ---------------------------------------------------------------------------


def test_deadline_retires_timed_out(smoke_params):
    reqs = synthetic_requests(SMOKE, 2, prompt_len=4, max_new_tokens=8)
    # r0's deadline is over before its first decode round can complete;
    # r1 has no deadline and must be untouched by r0's fate
    reqs[0].deadline_s = 1e-6
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=2, max_len=MAX_LEN)
    out = eng.serve(reqs)
    _drained(eng, reqs)
    assert eng.results[0].status == "timed_out"
    assert eng.stats.timeouts == 1
    # partial progress is preserved on the result, not delivered as ok
    assert 0 not in out and len(eng.results[0].tokens) < 8
    assert eng.results[1].status == "ok" and len(out[1]) == 8


def test_engine_default_ttl(smoke_params):
    reqs = synthetic_requests(SMOKE, 3, prompt_len=4, max_new_tokens=8)
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=2, max_len=MAX_LEN,
                          default_ttl_s=1e-6)
    out = eng.serve(reqs)
    _drained(eng, reqs)
    assert out == {} and eng.stats.timeouts == 3
    assert all(r.status == "timed_out" for r in eng.results.values())


# ---------------------------------------------------------------------------
# KV-block preemption + forced-replay recompute
# ---------------------------------------------------------------------------


def test_preemption_recompute_bitmatch(smoke_params, oracle_server):
    """A higher-priority arrival evicts the low-priority in-flight request;
    the victim re-admits with its delivered tokens as forced replay and its
    final output is bit-identical to the uncontended oracle."""
    reqs = synthetic_requests(SMOKE, 2, prompt_len=4, max_new_tokens=6)
    reqs[1].arrival_s = 1e-4   # arrives while r0 is mid-decode
    reqs[1].priority = 1       # strictly higher: may evict r0
    ref = _oracle(oracle_server, reqs)
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=1, max_len=MAX_LEN)
    out = eng.serve(reqs)
    _drained(eng, reqs)
    assert eng.stats.preempted >= 1
    assert all(r.status == "ok" for r in eng.results.values())
    assert out == ref  # forced replay reproduces the evicted trajectory


def test_preemption_is_bounded(smoke_params):
    """max_preemptions bounds the evict/recompute cycle: a victim evicted
    that many times becomes ineligible, so the engine still drains."""
    reqs = synthetic_requests(SMOKE, 3, prompt_len=4, max_new_tokens=6)
    for i, r in enumerate(reqs):
        r.arrival_s = i * 1e-4
        r.priority = i  # every arrival outranks everything in flight
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=1, max_len=MAX_LEN,
                          max_preemptions=1)
    out = eng.serve(reqs)
    _drained(eng, reqs)
    assert all(r.status == "ok" for r in eng.results.values())
    assert len(out) == 3


# ---------------------------------------------------------------------------
# Load shedding
# ---------------------------------------------------------------------------


def _shed_trace(n=6):
    reqs = synthetic_requests(SMOKE, n, prompt_len=4, max_new_tokens=4)
    for r in reqs:
        r.arrival_s = 0.0  # one instantaneous burst: the queue must overflow
    return reqs


@pytest.mark.parametrize("policy", ["reject-new", "drop-oldest",
                                    "deadline-aware"])
def test_shed_policies_drain(smoke_params, policy):
    reqs = _shed_trace()
    if policy == "deadline-aware":
        reqs[2].deadline_s = 10.0  # ample slack: the preferred victim
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=1, max_len=MAX_LEN,
                          queue_limit=2, shed_policy=policy)
    eng.serve(reqs)
    _drained(eng, reqs)
    shed = sorted(r.rid for r in eng.results.values() if r.status == "shed")
    assert len(shed) >= 1 and eng.stats.sheds == len(shed)
    if policy == "drop-oldest":
        assert shed[0] < max(
            r.rid for r in eng.results.values() if r.status == "ok"
        )
    if policy == "deadline-aware":
        assert 2 in shed  # most slack goes first


def test_shed_victims_keep_partial_tokens(smoke_params):
    reqs = _shed_trace(8)
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=1, max_len=MAX_LEN,
                          queue_limit=1, shed_policy="reject-new")
    eng.serve(reqs)
    _drained(eng, reqs)
    assert eng.stats.sheds >= 1
    for res in eng.results.values():
        if res.status == "shed":
            assert res.tokens == []  # never admitted: nothing delivered


# ---------------------------------------------------------------------------
# Fault isolation
# ---------------------------------------------------------------------------


def test_poisoned_request_is_isolated(smoke_params, oracle_server):
    reqs = synthetic_requests(SMOKE, 3, prompt_len=4, max_new_tokens=4)
    ref = _oracle(oracle_server, reqs)
    chaos = ChaosInjector(seed=0, poison_rids=(1,))
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=3, max_len=MAX_LEN,
                          chaos=chaos)
    out = eng.serve(reqs)
    _drained(eng, reqs)
    assert eng.results[1].status == "error"
    assert "ChaosError" in eng.results[1].detail
    assert eng.stats.errors == 1 and eng.stats.step_faults >= 1
    # the batchmates' outputs are untouched by the poisoned row's fate
    assert out == {0: ref[0], 2: ref[2]}


def test_transient_faults_are_retried(smoke_params, oracle_server):
    """Transient (one-off) step faults fail a batch step once; the isolating
    retry succeeds and every request still finishes ok and bit-exact."""
    reqs = synthetic_requests(SMOKE, 3, prompt_len=4, max_new_tokens=4)
    ref = _oracle(oracle_server, reqs)
    chaos = ChaosInjector(seed=3, step_fault_rate=0.3)
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=3, max_len=MAX_LEN,
                          chaos=chaos)
    out = eng.serve(reqs)
    _drained(eng, reqs)
    assert chaos.stats.transient_faults >= 1  # the schedule actually fired
    # a transient can strike the isolating retry too (an error retirement);
    # everything that finished must be bit-exact
    for rid, toks in out.items():
        assert toks == ref[rid]
    assert eng.stats.step_faults >= 1  # at least one batch step was absorbed


def test_unhardened_engine_crashes_under_chaos(smoke_params):
    reqs = synthetic_requests(SMOKE, 2, prompt_len=4, max_new_tokens=4)
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=2, max_len=MAX_LEN,
                          hardened=False,
                          chaos=ChaosInjector(seed=0, poison_rids=(0,)))
    with pytest.raises(ChaosError):
        eng.serve(reqs)


# ---------------------------------------------------------------------------
# Stall watchdog
# ---------------------------------------------------------------------------


def test_watchdog_fails_loudly_on_stall(smoke_params):
    """A permanently squeezed 1-block pool can never admit the request; the
    watchdog must convert the silent wedge into EngineStalled + state dump."""
    reqs = synthetic_requests(SMOKE, 1, prompt_len=4, max_new_tokens=4)
    chaos = ChaosInjector(seed=0, squeeze_rate=1.0, squeeze_hold=10**9)
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=1, max_len=MAX_LEN,
                          watchdog_limit=25, chaos=chaos)
    with pytest.raises(EngineStalled) as ei:
        eng.serve(reqs)
    msg = str(ei.value)
    assert "waiting" in msg and "free" in msg  # the state dump, not a wedge


# ---------------------------------------------------------------------------
# Malformed inputs + duplicate absorption on the adversarial trace
# ---------------------------------------------------------------------------


def test_adversarial_trace_malformed_isolated(smoke_params):
    trace = adversarial_trace(
        SMOKE, 8, seed=11, scale=0.1, deadline_fraction=0.0,
        malformed_rate=0.5, max_len_hint=MAX_LEN,
    )
    malformed = {
        r.rid for r in trace
        if len(r.prompt) == 0 or r.max_new_tokens < 1
        or len(r.prompt) + r.max_new_tokens > MAX_LEN
    }
    assert malformed and len(malformed) < len(trace)  # both kinds present
    eng = StreamingEngine(SMOKE, smoke_params, n_blocks=2, max_len=MAX_LEN)
    out = eng.serve(trace)
    _drained(eng, trace)
    for rid in malformed:
        res = eng.results[rid]
        assert res.status == "error" and "malformed" in res.detail
    assert set(out) == {r.rid for r in trace} - malformed


# ---------------------------------------------------------------------------
# The drain property (hypothesis)
# ---------------------------------------------------------------------------

if given is not None:

    @st.composite
    def _chaos_traces(draw):
        n = draw(st.integers(1, 5))
        reqs = []
        for rid in range(n):
            kind = draw(st.sampled_from(["ok", "ok", "ok", "empty",
                                         "zero_tok", "overlong"]))
            plen = draw(st.integers(1, 4))
            mnt = draw(st.integers(1, 4))
            if kind == "empty":
                plen = 0
            elif kind == "zero_tok":
                mnt = 0
            elif kind == "overlong":
                plen = MAX_LEN + 1
            prompt = np.arange(1, plen + 1, dtype=np.int32) % 64
            reqs.append(ServingRequest(
                rid=rid, prompt=prompt, max_new_tokens=mnt,
                arrival_s=float(draw(st.sampled_from([0.0, 0.001]))),
                deadline_s=draw(st.sampled_from([None, None, 0.002])),
                priority=draw(st.integers(0, 2)),
            ))
        knobs = dict(
            n_blocks=draw(st.integers(1, 3)),
            queue_limit=draw(st.sampled_from([None, 1, 2])),
            seed=draw(st.integers(0, 2**16)),
            fault_rate=draw(st.sampled_from([0.0, 0.2])),
            squeeze=draw(st.sampled_from([0.0, 0.3])),
        )
        return reqs, knobs

    @settings(max_examples=8, deadline=None)
    @given(tc=_chaos_traces())
    def test_property_every_request_retired_exactly_once(
        smoke_params, oracle_server, tc
    ):
        reqs, knobs = tc
        chaos = ChaosInjector(
            seed=knobs["seed"], step_fault_rate=knobs["fault_rate"],
            squeeze_rate=knobs["squeeze"], squeeze_hold=2,
            delay_rate=0.2, delay_s=0.005,
        )
        eng = StreamingEngine(
            SMOKE, smoke_params, n_blocks=knobs["n_blocks"], max_len=MAX_LEN,
            queue_limit=knobs["queue_limit"], chaos=chaos,
        )
        out = eng.serve(reqs)  # must never raise
        _drained(eng, reqs)    # exactly once, valid status, blocks freed
        well_formed = [
            r for r in reqs
            if len(r.prompt) >= 1 and r.max_new_tokens >= 1
            and len(r.prompt) + r.max_new_tokens <= MAX_LEN
        ]
        ref = _oracle(oracle_server, [r for r in well_formed if r.rid in out])
        for rid, toks in out.items():
            assert eng.results[rid].status == "ok"
            assert toks == ref[rid]  # ok => bit-identical to the oracle
