"""Tuner measurement guardrail + crash-safe TuningDB tests (ISSUE 8).

Covers: NaN/inf trial costs are quarantined and can never win an argmin
(locally or after a merge), raising cost functions quarantine the candidate
instead of aborting the sweep (control-flow exceptions still propagate),
``tuned_point`` refuses a quarantined final, quarantine markers survive the
CRDT merge in both directions, the all-candidates-quarantined search fails
loudly, the BackgroundTuner surfaces quarantined classes, and the
crash-safe two-step flush: a corrupted (or mid-rename vanished) main DB
file salvages from the ``.bak`` of the last good flush with the recovery
recorded in ``db_events``.
"""
import json
import math
import os

import pytest

from repro.core import (
    ATRegion,
    BasicParams,
    ParamSpace,
    PerfParam,
    Tuner,
    TuningDB,
    pp_key,
)
from repro.core.autotuned import TrialBudgetExhausted

BP = BasicParams.make(kernel="guard", n=8)
SPACE = ParamSpace([PerfParam("i", (0, 1, 2))])


def _region():
    return ATRegion("guard", SPACE, lambda p: (lambda: p["i"]))


# ---------------------------------------------------------------------------
# Measurement guardrail
# ---------------------------------------------------------------------------


def test_nan_cost_is_quarantined_and_never_wins():
    db = TuningDB()
    costs = {0: 3.0, 1: float("nan"), 2: 2.0}
    result = Tuner(db=db).tune(_region(), BP, lambda p: costs[p["i"]])
    assert result.best.point == {"i": 2}  # NaN survived no comparison
    assert db.tuned_point(BP) == {"i": 2}
    assert db.is_quarantined(BP, {"i": 1})
    assert not db.is_quarantined(BP, {"i": 2})
    assert pp_key({"i": 1}) not in db.trials(BP)  # never recorded as a trial
    assert "non-finite" in db.quarantined(BP)[pp_key({"i": 1})]["reason"]


def test_raising_cost_is_quarantined_not_fatal():
    db = TuningDB()

    def cost(p):
        if p["i"] == 0:
            raise ZeroDivisionError("broken candidate")
        return float(p["i"])

    result = Tuner(db=db).tune(_region(), BP, cost)
    assert result.best.point == {"i": 1}
    reason = db.quarantined(BP)[pp_key({"i": 0})]["reason"]
    assert "ZeroDivisionError" in reason and "broken candidate" in reason


def test_quarantined_candidate_is_never_remeasured():
    db = TuningDB()
    calls = []

    def cost(p):
        calls.append(p["i"])
        return float("inf") if p["i"] == 0 else float(p["i"])

    tuner = Tuner(db=db)
    tuner.tune(_region(), BP, cost)
    n = calls.count(0)
    tuner.tune(_region(), BP, cost, fresh=True)
    assert calls.count(0) == n  # known-broken: short-circuited to +inf


def test_all_candidates_quarantined_fails_loudly():
    db = TuningDB()
    with pytest.raises(RuntimeError, match="every candidate quarantined"):
        Tuner(db=db).tune(_region(), BP, lambda p: float("nan"))
    assert db.tuned_point(BP) is None  # nothing finalized
    assert len(db.quarantined(BP)) == SPACE.size()


def test_control_flow_exceptions_still_propagate():
    db = TuningDB()

    def cost(p):
        raise TrialBudgetExhausted("budget spent")

    assert TrialBudgetExhausted.tuning_control
    with pytest.raises(TrialBudgetExhausted):
        Tuner(db=db).tune(_region(), BP, cost)
    assert db.quarantined(BP) == {}  # control flow, not a broken candidate


def test_record_best_refuses_non_finite():
    db = TuningDB()
    with pytest.raises(ValueError, match="never become a final best"):
        db.record_best(BP, {"i": 0}, float("nan"), "before_execution")


def test_quarantine_survives_merge_both_directions():
    ours, theirs = TuningDB(), TuningDB()
    # theirs tuned {"i": 0} as a legitimate final; ours quarantined it
    theirs.record_trial(BP, {"i": 0}, 1.0, "before_execution")
    theirs.record_best(BP, {"i": 0}, 1.0, "before_execution")
    ours.record_quarantine(BP, {"i": 0}, "non-finite trial cost nan")
    assert theirs.tuned_point(BP) == {"i": 0}
    merged_a = TuningDB().merge(ours).merge(theirs)
    merged_b = TuningDB().merge(theirs).merge(ours)
    for m in (merged_a, merged_b):
        # the sticky distrust wins: the quarantined final is refused
        assert m.is_quarantined(BP, {"i": 0})
        assert m.tuned_point(BP) is None
    fp = BP.fingerprint()
    assert merged_a.export_entries()[fp]["quarantined"] \
        == merged_b.export_entries()[fp]["quarantined"]


def test_nearest_tuned_skips_quarantined_final():
    db = TuningDB()
    near = BasicParams.make(kernel="guard", n=9)
    db.record_trial(near, {"i": 0}, 1.0, "before_execution")
    db.record_best(near, {"i": 0}, 1.0, "before_execution")
    assert db.nearest_tuned(BP) is not None
    db.record_quarantine(near, {"i": 0}, "drifted to nan")
    assert db.nearest_tuned(BP) is None


def test_background_tuner_surfaces_quarantined_labels():
    import jax.numpy as jnp

    from repro.core import AutotunedOp, KernelSpec, TrafficClass
    from repro.runtime import BackgroundTuner

    space = ParamSpace([PerfParam("i", (0, 1))])

    def cost_factory(region, bp, args, kwargs):
        return lambda p: float("nan") if p["i"] == 0 else 1.0

    spec = KernelSpec(
        "half_broken",
        make_region=lambda bp: ATRegion(
            "half_broken", space, lambda p: (lambda x: x)
        ),
        shape_class=lambda x: BasicParams.make(kernel="half_broken"),
        cost_factory=cost_factory,
        traffic_class=lambda x: TrafficClass.of("prefill", 1, int(x.shape[1])),
    )
    op = AutotunedOp(spec, db=TuningDB(), tune=False)
    with BackgroundTuner() as tuner:
        state = tuner.submit(op, jnp.ones((1, 8)))
        assert tuner.drain(timeout=60)
    assert tuner.quarantined_labels == ["prefill/b1/s8"]
    assert tuner.failed_labels == []  # the class still tuned on the survivor
    assert state.region.selected == {"i": 1}


# ---------------------------------------------------------------------------
# Crash-safe flush + salvage-on-load
# ---------------------------------------------------------------------------


def _seeded_db(path):
    db = TuningDB(path)
    db.record_trial(BP, {"i": 0}, 2.0, "before_execution")
    db.record_best(BP, {"i": 0}, 2.0, "before_execution")
    # one more flush so the .bak (always the last-but-one flush) holds the
    # finalized state the salvage tests expect to recover
    db.record_trial(BP, {"i": 2}, 3.0, "before_execution")
    return db


def test_flush_keeps_bak_of_last_good_flush(tmp_path):
    path = str(tmp_path / "db.json")
    _seeded_db(path)
    assert os.path.exists(path + ".bak")  # second flush demoted the first
    with open(path + ".bak") as f:
        json.load(f)  # the backup is itself valid JSON


def test_corrupted_main_salvages_from_bak(tmp_path):
    path = str(tmp_path / "db.json")
    _seeded_db(path)
    with open(path, "w") as f:
        f.write('{"schema_version": 2, "entries": {TRUNCATED')  # torn write
    db = TuningDB(path)
    assert db.tuned_point(BP) == {"i": 0}  # the last good flush survived
    events = db.db_events()
    assert events and events[-1]["kind"] == "db_salvaged"
    assert events[-1]["source"].endswith(".bak")
    # the salvage event itself persists through the next flush
    db.record_trial(BP, {"i": 1}, 1.0, "before_execution")
    assert TuningDB(path).db_events()[-1]["kind"] != "db_salvage_failed"
    assert any(e["kind"] == "db_salvaged" for e in TuningDB(path).db_events())


def test_kill_between_renames_salvages_from_bak(tmp_path):
    """Simulate a crash after demoting main to .bak but before promoting the
    tmp file: main is gone, .bak holds the last good flush."""
    path = str(tmp_path / "db.json")
    _seeded_db(path)
    os.replace(path, path + ".bak")  # the mid-_flush crash window
    db = TuningDB(path)
    assert db.tuned_point(BP) == {"i": 0}
    assert db.db_events()[-1]["kind"] == "db_salvaged"


def test_both_files_unreadable_starts_empty_and_logs(tmp_path):
    path = str(tmp_path / "db.json")
    _seeded_db(path)
    for p in (path, path + ".bak"):
        with open(p, "w") as f:
            f.write("not json at all")
    db = TuningDB(path)
    assert db.tuned_point(BP) is None and db.fingerprints() == []
    assert db.db_events()[-1]["kind"] == "db_salvage_failed"


def test_schema_too_new_still_raises_through_salvage(tmp_path):
    path = str(tmp_path / "db.json")
    with open(path, "w") as f:
        json.dump({"schema_version": 99, "entries": {}}, f)
    with pytest.raises(ValueError, match="schema"):
        TuningDB(path)
