"""Architecture model + emit layer (docs/arch.md).

Covers the tentpole contracts: ArchSpec/DeviceFingerprint BP round-trips,
deterministic emitted-space signatures, the signature-gated DB recall
(changed arch invalidates stale finals; unchanged arch recalls with zero
evals), the EmptySpace constructor guard, and the pinned-point escape hatch.
"""
import dataclasses

import pytest

from repro.core import BasicParams, EmptySpace, ParamSpace, PerfParam, pp_key
from repro.core.arch import ArchSpec, default_interpret, detect, local_arch
from repro.core.db import TuningDB
from repro.core.emit import TileDim, TilePolicy, hint_prescreen
from repro.fleet.fingerprint import DeviceFingerprint, _pow2_bucket, local_device


# ---------------------------------------------------------------------------
# fingerprint round-trips
# ---------------------------------------------------------------------------


def test_device_fingerprint_bp_roundtrip_identity():
    fp = local_device()
    assert DeviceFingerprint.from_bp_entries(fp.bp_entries()) == fp


def test_device_fingerprint_roundtrip_synthetic():
    fp = DeviceFingerprint(
        backend="tpu", platform="TPU v5e", device_count=4,
        host_cores=8, memory_gib=16, schema=2,
    )
    assert DeviceFingerprint.from_bp_entries(fp.bp_entries()) == fp


@pytest.mark.parametrize(
    "gib,bucket",
    [(0.1, 1), (1.0, 1), (1.0001, 2), (1.5, 2), (2.0, 2), (2.1, 4),
     (4.0, 4), (63.9, 64), (64.0, 64), (64.1, 128)],
)
def test_pow2_bucket_boundaries(gib, bucket):
    assert _pow2_bucket(gib) == bucket


def test_arch_spec_bp_roundtrip_identity():
    arch = local_arch()
    assert ArchSpec.from_bp_entries(arch.bp_entries()) == arch
    assert all(k.startswith("arch_") for k in arch.bp_entries())


def test_fingerprint_hangs_arch_spec():
    fp = local_device()
    arch = fp.arch_spec()
    assert isinstance(arch, ArchSpec)
    assert arch.backend == fp.backend
    assert arch == detect(fp.backend)


def test_default_interpret_matches_backend():
    import jax

    assert default_interpret() == (jax.default_backend() == "cpu")


# ---------------------------------------------------------------------------
# emitted spaces
# ---------------------------------------------------------------------------


def _toy_policy(**kw):
    return TilePolicy(
        kernel="toy",
        dims=lambda bp: (
            TileDim("block", bp["n"], semantic="lane"),
            TileDim("chunk", bp["s"], semantic="sequential"),
        ),
        vmem_model=lambda bp, p: p["block"] * p["chunk"] * 4,
        traffic_model=lambda bp, p: (bp["n"] * bp["s"] * 8.0,
                                     bp["n"] * bp["s"] * 4.0),
        **kw,
    )


def test_same_arch_same_signature_property():
    """Same ArchSpec → byte-identical signature, across shapes and repeats."""
    arch = detect("cpu")
    policy = _toy_policy()
    for n in (128, 256, 1024):
        for s in (64, 512):
            sigs = {
                policy.emit(arch, {"n": n, "s": s}).signature
                for _ in range(3)
            }
            assert len(sigs) == 1
            sig = sigs.pop()
            assert isinstance(sig, str) and len(sig) == 16
            # a fresh policy object emits the identical signature too
            assert _toy_policy().emit(arch, {"n": n, "s": s}).signature == sig


def test_changed_arch_changes_signature():
    arch = detect("cpu")
    policy = _toy_policy()
    bp = {"n": 1024, "s": 512}
    base = policy.emit(arch, bp).signature
    smaller = dataclasses.replace(arch, vmem_bytes=arch.vmem_bytes // 8)
    assert policy.emit(smaller, bp).signature != base
    # a pure metadata change (bandwidth) also re-signs: the model changed
    faster = dataclasses.replace(arch, hbm_bandwidth=arch.hbm_bandwidth * 2)
    assert policy.emit(faster, bp).signature != base


def test_emitted_space_respects_vmem_budget():
    arch = detect("cpu")
    emitted = _toy_policy().emit(arch, {"n": 2048, "s": 2048},
                                 vmem_budget=256 * 1024)
    for p in emitted.space.points():
        assert p["block"] * p["chunk"] * 4 <= 256 * 1024
        h = emitted.hints[pp_key(p)]
        assert h["vmem_bytes"] <= 256 * 1024
        assert h["memory_space"] == "vmem"
        assert h["stages"] in (1, 2)
        assert h["programs"] >= 1


def test_emitted_points_are_hint_ordered():
    arch = detect("cpu")
    emitted = _toy_policy().emit(arch, {"n": 1024, "s": 512})
    ests = [emitted.hints[pp_key(p)]["est_s"] for p in emitted.space.points()]
    assert ests == sorted(ests)
    # the space default (untuned baseline) is the model's best guess
    assert pp_key(emitted.space.default()) == pp_key(
        min(emitted.space.points(),
            key=lambda p: emitted.hints[pp_key(p)]["est_s"])
    )


def test_ladder_respects_semantics():
    arch = detect("cpu")
    emitted = _toy_policy().emit(arch, {"n": 1024, "s": 512})
    blocks = {p["block"] for p in emitted.space.points()}
    chunks = {p["chunk"] for p in emitted.space.points()}
    assert min(blocks) >= arch.lane_width          # lane dim floor
    assert min(chunks) >= arch.sublane_width * 4   # sequential dim floor
    for b in blocks:
        assert 1024 % b == 0                       # no padding unless allowed


def test_padding_dim_emits_nondividing_candidates():
    arch = detect("cpu")
    policy = TilePolicy(
        kernel="toy_pad",
        dims=lambda bp: (
            TileDim("block", bp["n"], semantic="lane", allow_padding=True),
        ),
        vmem_model=lambda bp, p: p["block"] * 4,
    )
    emitted = policy.emit(arch, {"n": 200})
    blocks = sorted(p["block"] for p in emitted.space.points())
    assert blocks == [128, 200]  # padded pow2 + the exact extent
    assert emitted.hints[pp_key({"block": 128})]["pad_factor"] > 1.0


def test_pinned_escape_hatch_unions_points():
    """Hand-pinned points survive even outside ladder and budget."""
    arch = detect("cpu")
    pinned = [{"block": 384, "chunk": 512}]  # 384 is not a pow2 ladder value
    emitted = _toy_policy().emit(
        arch, {"n": 1024, "s": 512}, pinned=pinned, vmem_budget=64 * 1024
    )
    keys = {pp_key(p) for p in emitted.space.points()}
    assert pp_key(pinned[0]) in keys
    # and pinning changes the signature (the space genuinely differs)
    base = _toy_policy().emit(arch, {"n": 1024, "s": 512},
                              vmem_budget=64 * 1024)
    assert emitted.signature != base.signature


def test_empty_space_raises_typed_error_naming_arch():
    arch = detect("cpu")
    with pytest.raises(EmptySpace) as exc:
        _toy_policy().emit(arch, {"n": 1024, "s": 512}, vmem_budget=16)
    msg = str(exc.value)
    assert "toy" in msg and "cpu_host" in msg and "16" in msg
    assert exc.value.context["vmem_budget"] == 16


def test_param_space_empty_constraint_raises_at_construction():
    with pytest.raises(EmptySpace):
        ParamSpace(
            [PerfParam("x", (1, 2, 3))],
            constraint=lambda p: False,
            label="always_empty",
        )


def test_hint_prescreen_ranks_without_example_args():
    from repro.kernels.flash_attention.ops import flash_region

    region = flash_region(1024, 64)
    score = hint_prescreen(region, None, (), {})
    assert score is not None  # emitted regions always have a prescreen
    pts = list(region.space.points())
    scores = [score(p) for p in pts]
    assert all(s >= 0 for s in scores)
    assert scores == sorted(scores)  # points() is already hint-ordered


# ---------------------------------------------------------------------------
# signature-gated DB recall
# ---------------------------------------------------------------------------


def _bp():
    return BasicParams.make(kernel="toy", n=1024)


def test_unchanged_signature_recalls_final(tmp_path):
    db = TuningDB(str(tmp_path / "db.json"))
    bp = _bp()
    db.record_best(bp, {"block": 128}, 1.0, "install", space_signature="sigA")
    assert db.tuned_point(bp, space_signature="sigA") == {"block": 128}
    assert db.space_signature(bp) == "sigA"
    assert db.invalidate_stale_final(bp, "sigA") is False  # nothing stale


def test_changed_signature_blocks_recall_and_invalidates(tmp_path):
    db = TuningDB(str(tmp_path / "db.json"))
    bp = _bp()
    db.record_trial(bp, {"block": 128}, 1.0, "install")
    db.record_best(bp, {"block": 128}, 1.0, "install", space_signature="sigA")
    # a region emitted under a different arch model must not recall it
    assert db.tuned_point(bp, space_signature="sigB") is None
    assert db.invalidate_stale_final(bp, "sigB") is True
    assert db.tuned_point(bp) is None          # final flag stripped
    assert db.trials(bp) == {}                 # stale trials dropped
    kinds = [e["kind"] for e in db.events(bp)]
    assert "space_invalidated" in kinds
    ev = [e for e in db.events(bp) if e["kind"] == "space_invalidated"][0]
    assert ev["old_sig"] == "sigA" and ev["new_sig"] == "sigB"


def test_legacy_final_without_signature_is_stale_for_emitted_region(tmp_path):
    db = TuningDB(str(tmp_path / "db.json"))
    bp = _bp()
    db.record_best(bp, {"block": 128}, 1.0, "install")  # pre-emit final
    assert db.tuned_point(bp) == {"block": 128}          # legacy callers OK
    assert db.tuned_point(bp, space_signature="sigA") is None
    assert db.invalidate_stale_final(bp, "sigA") is True


def test_signature_survives_merge(tmp_path):
    a = TuningDB(str(tmp_path / "a.json"))
    b = TuningDB(str(tmp_path / "b.json"))
    bp = _bp()
    a.record_best(bp, {"block": 128}, 1.0, "install", space_signature="sigA")
    b.merge(a.export_entries())
    assert b.tuned_point(bp, space_signature="sigA") == {"block": 128}
    assert b.space_signature(bp) == "sigA"


def test_autotuned_op_invalidates_on_arch_change(tmp_path):
    """End to end: tune once, re-resolve with a changed emitted space →
    the stale final is demoted and the op re-tunes; unchanged space →
    zero-eval recall (the hot path stays hot)."""
    from repro.core import ATRegion, AutotunedOp, KernelSpec

    def make_spec(signature):
        def make_region(bp):
            space = ParamSpace([PerfParam("block", (128, 256))])
            return ATRegion(
                "toy", space, lambda pt: (lambda x: x * pt["block"]),
                space_signature=signature,
            )

        return KernelSpec(
            "toy_sig", make_region=make_region,
            shape_class=lambda x: BasicParams.make(kernel="toy_sig", n=int(x)),
        )

    db = TuningDB(str(tmp_path / "db.json"))
    evals = []

    def cost_factory(region, bp, args, kwargs):
        return lambda point: (evals.append(dict(point)) or 0.1)

    op = AutotunedOp(make_spec("sigA"), db=db, cost_factory=cost_factory,
                     warm=False, device_key=False)
    first = op.resolve(7)
    assert evals  # searched
    assert db.space_signature(first.bp) == "sigA"

    # same arch model: a fresh op recalls with zero evaluations
    evals.clear()
    op2 = AutotunedOp(make_spec("sigA"), db=db, cost_factory=cost_factory,
                      warm=False, device_key=False)
    state = op2.resolve(7)
    assert state.from_cache and not evals

    # changed arch model: stale final demoted, search re-runs
    op3 = AutotunedOp(make_spec("sigB"), db=db, cost_factory=cost_factory,
                      warm=False, device_key=False)
    state = op3.resolve(7)
    assert not state.from_cache and evals
    bp = state.bp
    assert db.space_signature(bp) == "sigB"
    assert any(e["kind"] == "space_invalidated" for e in db.events(bp))
