"""Unit + property tests for the AT framework (repro.core)."""
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ATRegion,
    BasicParams,
    CoordinateDescent,
    DegreeController,
    ExchangeVariant,
    ExhaustiveSearch,
    GKV_FIGURE_OF_VARIANT,
    LoopNest,
    ParamSpace,
    PerfParam,
    RuntimeSelector,
    SuccessiveHalving,
    Tuner,
    TuningDB,
    enumerate_exchange_variants,
    pp_key,
)


# ---------------------------------------------------------------------------
# BP / PP
# ---------------------------------------------------------------------------


def test_bp_fingerprint_stable_and_order_independent():
    a = BasicParams.make(arch="x", n=16, mesh=(16, 16))
    b = BasicParams.make(mesh=(16, 16), n=16, arch="x")
    assert a.fingerprint() == b.fingerprint()
    assert a["n"] == 16
    c = BasicParams.make(arch="x", n=17, mesh=(16, 16))
    assert a.fingerprint() != c.fingerprint()


def test_param_space_enumeration_and_constraint():
    space = ParamSpace(
        [PerfParam("a", (1, 2, 4)), PerfParam("b", ("x", "y"))],
        constraint=lambda p: not (p["a"] == 4 and p["b"] == "y"),
    )
    pts = list(space.points())
    assert len(pts) == 5  # 6 - 1 infeasible
    assert space.size() == 6
    for p in pts:
        space.validate(p)
    with pytest.raises(ValueError):
        space.validate({"a": 3, "b": "x"})


def test_param_space_rejects_duplicates():
    with pytest.raises(ValueError):
        PerfParam("a", (1, 1))
    with pytest.raises(ValueError):
        ParamSpace([PerfParam("a", (1,)), PerfParam("a", (2,))])


# ---------------------------------------------------------------------------
# Exchange variant enumeration — N(N+1)/2, paper's 10 for N=4
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=6))
def test_variant_count_formula(n):
    vs = enumerate_exchange_variants(n)
    assert len(vs) == n * (n + 1) // 2
    assert len({(v.m, v.j) for v in vs}) == len(vs)


def test_paper_figure_mapping_complete():
    vs = enumerate_exchange_variants(4)
    assert len(vs) == 10
    assert {(v.m, v.j) for v in vs} == set(GKV_FIGURE_OF_VARIANT)


# ---------------------------------------------------------------------------
# LoopNest: every (variant × degree) is semantics-preserving (property)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    dims=st.lists(st.integers(min_value=1, max_value=7), min_size=1, max_size=4),
    degree=st.integers(min_value=1, max_value=33),
    seed=st.integers(min_value=0, max_value=2**30),
)
def test_all_variants_allclose_to_reference(dims, degree, seed):
    nest = LoopNest(
        "t", [(f"d{i}", n) for i, n in enumerate(dims)], lambda x: x * 2.0 + 1.0
    )
    x = jax.random.normal(jax.random.PRNGKey(seed), tuple(dims), jnp.float32)
    ref = nest.reference(x)
    for v in enumerate_exchange_variants(len(dims)):
        out = nest.variant_fn(v, degree)(x)
        np.testing.assert_allclose(out, ref, rtol=1e-6)
        assert out.shape == x.shape


def test_variant_labels():
    v = ExchangeVariant(m=3, j=1)
    assert v.label(("iv", "iz", "mx", "my")) == "OMP[iv]>iz>mx_my"
    with pytest.raises(ValueError):
        ExchangeVariant(m=2, j=3)


# ---------------------------------------------------------------------------
# Tuner: argmin correctness (property) + DB persistence + resume
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    costs=st.lists(
        st.floats(min_value=0.01, max_value=100, allow_nan=False), min_size=2,
        max_size=12, unique=True,
    )
)
def test_tuner_finds_argmin(costs):
    space = ParamSpace([PerfParam("i", tuple(range(len(costs))))])
    region = ATRegion("r", space, lambda p: (lambda: p["i"]))
    tuner = Tuner(TuningDB())
    bp = BasicParams.make(arch="t")
    res = tuner.tune(region, bp, lambda p: costs[p["i"]])
    assert res.best.point["i"] == int(np.argmin(costs))
    assert region.selected == res.best.point


def test_tuner_db_roundtrip_and_resume(tmp_path):
    path = str(tmp_path / "db.json")
    space = ParamSpace([PerfParam("i", (0, 1, 2, 3))])
    region = ATRegion("r", space, lambda p: (lambda: p["i"]))
    calls = []

    def cost(p):
        calls.append(p["i"])
        return float(p["i"] != 2)

    t1 = Tuner(TuningDB(path))
    t1.tune(region, BasicParams.make(arch="t"), cost)
    assert len(calls) == 4
    # resume: a new tuner over the same DB re-uses recorded trials
    t2 = Tuner(TuningDB(path))
    res = t2.tune(region, BasicParams.make(arch="t"), cost)
    assert len(calls) == 4  # no new evaluations
    assert res.best.point == {"i": 2}
    # persisted best is readable directly
    db = TuningDB(path)
    assert db.best_point(BasicParams.make(arch="t")) == {"i": 2}


def test_db_atomic_write(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDB(path)
    bp = BasicParams.make(arch="t")
    db.record_trial(bp, {"i": 0}, 1.0, "install")
    with open(path) as f:
        data = json.load(f)
    assert data["schema_version"] == TuningDB.SCHEMA_VERSION
    assert bp.fingerprint() in data["entries"]


# ---------------------------------------------------------------------------
# Searches
# ---------------------------------------------------------------------------


def _quad_cost(p):
    return (p["a"] - 3) ** 2 + (p["b"] - 5) ** 2 + 1.0


def test_coordinate_descent_on_separable_cost():
    space = ParamSpace(
        [PerfParam("a", tuple(range(8))), PerfParam("b", tuple(range(8)))]
    )
    res = CoordinateDescent().run(space, _quad_cost)
    assert res.best.point == {"a": 3, "b": 5}
    assert res.evaluations < space.size()  # cheaper than exhaustive


def test_successive_halving():
    space = ParamSpace([PerfParam("i", tuple(range(16)))])
    res = SuccessiveHalving(initial_budget=1).run(
        space, lambda p, budget: abs(p["i"] - 7) + 1.0 / budget
    )
    assert res.best.point["i"] == 7


# ---------------------------------------------------------------------------
# Search-strategy invariants
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    fa=st.lists(st.integers(0, 10**6), min_size=2, max_size=6, unique=True),
    fb=st.lists(st.integers(0, 10**6), min_size=2, max_size=6, unique=True),
)
def test_coordinate_descent_equals_exhaustive_on_separable(fa, fb):
    """On separable costs f(a)+g(b) the hillclimb is exact: it must land on
    the same argmin as exhaustive enumeration."""
    space = ParamSpace(
        [PerfParam("a", tuple(range(len(fa)))), PerfParam("b", tuple(range(len(fb))))]
    )
    cost = lambda p: float(fa[p["a"]] + fb[p["b"]])
    exhaustive = ExhaustiveSearch().run(space, cost)
    descent = CoordinateDescent().run(space, cost)
    assert descent.best.point == exhaustive.best.point
    assert descent.best.cost == exhaustive.best.cost


def test_successive_halving_never_returns_infeasible():
    space = ParamSpace(
        [PerfParam("i", tuple(range(12)))],
        constraint=lambda p: p["i"] % 3 != 0,  # prune a third of the space
    )
    res = SuccessiveHalving(initial_budget=1).run(
        space, lambda p, budget: float(p["i"]) + 1.0 / budget
    )
    assert space.feasible(res.best.point)
    assert all(space.feasible(t.point) for t in res.trials)


@pytest.mark.parametrize(
    "search,budgeted",
    [
        (ExhaustiveSearch(), False),
        (CoordinateDescent(), False),
        (SuccessiveHalving(initial_budget=1), True),
    ],
    ids=["exhaustive", "coordinate_descent", "successive_halving"],
)
def test_every_strategy_records_every_evaluation(search, budgeted):
    """SearchResult.trials is the audit log the DB persists: one entry per
    cost-function invocation, no more (dedup) and no fewer (no silent evals)."""
    space = ParamSpace(
        [PerfParam("a", (0, 1, 2, 3)), PerfParam("b", (0, 1, 2))],
        constraint=lambda p: p["a"] + p["b"] < 6,
    )
    calls = []

    def base(p):
        calls.append(dict(p))
        return float((p["a"] - 1) ** 2 + (p["b"] - 2) ** 2)

    cost = (lambda p, budget: base(p)) if budgeted else base
    res = search.run(space, cost)
    assert len(res.trials) == len(calls)
    assert res.evaluations == len(calls)
    assert all(space.feasible(t.point) for t in res.trials)
    recorded = {pp_key(t.point) for t in res.trials}
    assert pp_key(res.best.point) in recorded


# ---------------------------------------------------------------------------
# Degree controller (omp_set_num_threads semantics)
# ---------------------------------------------------------------------------


def test_degree_controller_set_restore():
    ctl = DegreeController(max_degree=32)
    ctl.set_tuned("k1", 4)
    assert ctl.current == 32
    with ctl.region("k1") as d:
        assert d == 4 and ctl.current == 4
        with ctl.region("unknown") as d2:  # untuned: stays at max
            assert d2 == 32
    assert ctl.current == 32
    with pytest.raises(ValueError):
        ctl.set_tuned("k1", 64)


# ---------------------------------------------------------------------------
# Run-time layer: straggler-triggered re-selection among precompiled
# ---------------------------------------------------------------------------


def test_runtime_selector_switches_on_regression():
    space = ParamSpace([PerfParam("i", (0, 1))])
    region = ATRegion("r", space, lambda p: (lambda: p["i"]))
    db = TuningDB()
    bp = BasicParams.make(arch="t")
    Tuner(db).tune(region, bp, lambda p: [1.0, 2.0][p["i"]])
    assert region.selected == {"i": 0}
    sel = RuntimeSelector(region, bp, db, tolerance=1.5, window=4)
    for _ in range(4):
        switched = sel.observe(10.0)  # 10x regression vs tuned 1.0
    assert switched and region.selected == {"i": 1} and sel.switches == 1


# ---------------------------------------------------------------------------
# Precompile: AOT candidates, zero-compile switching
# ---------------------------------------------------------------------------


def test_region_precompile_and_dispatch():
    space = ParamSpace([PerfParam("s", (1.0, 2.0, 3.0))])
    region = ATRegion("r", space, lambda p: (lambda x: x * p["s"]))
    x = jnp.ones((4,))
    n = region.precompile([x])
    assert n == 3 and region.compiled_points() == 3
    region.select({"s": 2.0})
    np.testing.assert_allclose(region(x), 2.0 * np.ones(4))
