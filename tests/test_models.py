"""Per-arch smoke tests (reduced configs) + model-level unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, cells_for, get_config, skipped_cells
from repro.models import (
    analytic_param_count,
    count_params,
    decode_fn,
    init_params,
    make_concrete_batch,
    param_specs,
    prefill_fn,
    train_loss,
)
from repro.models.layers import mrope_apply, rope_apply
from repro.models.moe import moe_block, moe_block_dense_oracle, moe_spec

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# The assigned-architecture smoke tests: one fwd/train step on CPU,
# asserting output shapes + no NaNs (assignment requirement).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(KEY, param_specs(cfg))
    batch = make_concrete_batch(KEY, cfg, "train", global_batch=2, seq_len=32)
    loss, grads = jax.jit(
        jax.value_and_grad(lambda p: train_loss(p, batch["batch"], cfg))
    )(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(KEY, param_specs(cfg))
    pb = make_concrete_batch(KEY, cfg, "prefill", global_batch=2, seq_len=32)
    logits, cache = jax.jit(lambda p, b: prefill_fn(p, b, cfg))(params, pb["batch"])
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
    db = make_concrete_batch(KEY, cfg, "decode", global_batch=2, seq_len=32)
    dlogits, cache2 = jax.jit(lambda p, b, c: decode_fn(p, b, c, cfg))(
        params, db["batch"], cache
    )
    assert dlogits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(dlogits).all(), arch
    assert int(cache2["len"]) == int(cache["len"]) + 1


def test_cell_accounting_covers_assignment():
    """10 archs × 4 shapes = 40 assigned cells: runnable + skipped = 40."""
    runnable = sum(len(cells_for(a)) for a in ARCH_IDS)
    skipped = len(skipped_cells())
    assert runnable + skipped == 40
    assert skipped == 8  # long_500k for the 8 full-attention archs


# ---------------------------------------------------------------------------
# Decode ≡ prefill consistency: prefill(t_1..t_n) then decode(t_{n+1})
# must equal prefill(t_1..t_{n+1}) logits.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["tinyllama-1.1b", "falcon-mamba-7b", "recurrentgemma-2b",
             "granite-moe-1b-a400m", "whisper-large-v3", "qwen2-vl-2b",
             "llama4-scout-17b-a16e", "qwen3-0.6b"]
)
def test_decode_matches_prefill(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(KEY, param_specs(cfg))
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size - 1, jnp.int32)
    extra = {}
    if cfg.is_encoder_decoder:
        extra["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_len, cfg.d_model), jnp.float32
        ).astype(jnp.bfloat16)

    full_logits, _ = prefill_fn(params, {"tokens": toks, **extra}, cfg)
    short_logits, cache = prefill_fn(
        params, {"tokens": toks[:, :S], **extra}, cfg
    )
    # grow attention caches by one slot for the incoming token
    cache = _grow(cache, 1)
    step_logits, _ = decode_fn(params, {"tokens": toks[:, S:]}, cache, cfg)
    # bf16 params: the decode path computes the conv/attention in a different
    # association order than prefill (einsum-over-window vs shifted adds), so
    # agreement is to bf16 accumulation noise, not exact.
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full_logits), rtol=0.1, atol=0.08
    )


def _grow(cache, extra):
    """Grow LINEAR attention caches by one slot for the incoming token.
    Hybrid ``b*_k``/``t*_k`` caches are ring buffers of exactly ``window``
    slots — growing them would corrupt the ring indexing, so only the
    dense/moe/whisper self-attention caches (exact keys) are padded."""
    out = {}
    for k, v in cache.items():
        if k in ("k", "v", "self_k", "self_v"):
            pad = [(0, 0)] * v.ndim
            pad[-3] = (0, extra)
            out[k] = jnp.pad(v, pad)
        else:
            out[k] = v
    return out


# ---------------------------------------------------------------------------
# Analytic vs instantiated parameter counts (smoke configs, exact)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_analytic_param_count_matches_specs(arch):
    cfg = get_config(arch, smoke=True)
    analytic = analytic_param_count(cfg)
    actual = count_params(param_specs(cfg))
    assert abs(analytic - actual) / actual < 0.02, (arch, analytic, actual)


# ---------------------------------------------------------------------------
# RoPE properties
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), shift=st.integers(0, 64))
def test_rope_relativity(seed, shift):
    """q·k after RoPE depends only on relative positions."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    q = jax.random.normal(ks[0], (1, 4, 1, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 4, 1, 16), jnp.float32)
    pos = jnp.arange(4)[None, :]
    def scores(p):
        qr = rope_apply(q, p, 10000.0)
        kr = rope_apply(k, p, 10000.0)
        return jnp.einsum("bqhd,bkhd->bqk", qr, kr)
    np.testing.assert_allclose(
        scores(pos), scores(pos + shift), rtol=1e-3, atol=1e-3
    )


def test_mrope_degenerates_to_rope_for_text():
    """With t=h=w position ids, M-RoPE ≡ standard RoPE (Qwen2-VL property)."""
    q = jax.random.normal(KEY, (2, 8, 2, 32), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    pos3 = jnp.broadcast_to(pos, (3, 2, 8))
    out_m = mrope_apply(q, pos3, 10000.0, (4, 6, 6))
    out_s = rope_apply(q, pos, 10000.0)
    np.testing.assert_allclose(out_m, out_s, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE: sort-based dispatch ≡ dense oracle in the no-drop regime
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), topk=st.sampled_from([1, 2]))
def test_moe_dispatch_matches_dense_oracle(seed, topk):
    from repro.models.config import ModelConfig
    from repro.models.spec import init_params as init_p

    cfg = ModelConfig(
        name="moe-test", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=1, d_ff=24, vocab_size=64, n_experts=4, top_k=topk,
        capacity_factor=8.0,  # capacity >> tokens: nothing drops
    )
    p = init_p(jax.random.PRNGKey(seed), moe_spec(cfg))
    p = jax.tree.map(lambda x: x.astype(jnp.float32), p)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 16), jnp.float32)
    out, aux = moe_block(x, p, cfg)
    ref = moe_block_dense_oracle(x, p, cfg)
    np.testing.assert_allclose(out, ref, rtol=5e-3, atol=5e-3)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 and adversarially skewed routing, output
    degrades gracefully (dropped tokens pass through residual as zeros)."""
    from repro.models.config import ModelConfig
    from repro.models.spec import init_params as init_p

    cfg = ModelConfig(
        name="moe-test", family="moe", n_layers=1, d_model=8, n_heads=2,
        n_kv_heads=1, d_ff=16, vocab_size=64, n_experts=4, top_k=1,
        capacity_factor=1.0,
    )
    p = init_p(KEY, moe_spec(cfg))
    x = jnp.ones((1, 16, 8), jnp.bfloat16)  # identical tokens -> one expert
    out, _ = moe_block(x, p, cfg)
    assert jnp.isfinite(out.astype(jnp.float32)).all()
