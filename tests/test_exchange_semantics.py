"""Degree semantics of the Exchange runner (OpenMP thread-pool behaviour)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ExchangeVariant, LoopNest


def _nest():
    return LoopNest("t", [("a", 4), ("b", 6), ("c", 5)], lambda x: x * 3.0 - 1.0)


def test_degree_beyond_loop_length_idles():
    """Threads beyond the parallel loop length idle (paper §V: 16-long iv
    loop with 32 threads) — degree > P must equal degree == P exactly."""
    nest = _nest()
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 5), jnp.float32)
    v = ExchangeVariant(m=3, j=1)  # parallel loop = a, length 4
    out_p = nest.variant_fn(v, 4)(x)
    out_over = nest.variant_fn(v, 64)(x)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_over))


def test_uneven_degree_padding_is_masked():
    """P=5 split 2 ways -> chunks of 3 with 1 padded slot; the pad must never
    leak into outputs (edge-replicated input, sliced output)."""
    nest = LoopNest("t", [("c", 5)], lambda x: 1.0 / (x + 10.0))
    x = jnp.arange(5, dtype=jnp.float32)
    ref = nest.reference(x)
    for d in (2, 3, 4):
        np.testing.assert_allclose(nest.variant_fn(ExchangeVariant(1, 1), d)(x), ref, rtol=1e-6)


def test_region_joint_space_size():
    region = _nest().at_region(degrees=(1, 2, 4))
    assert region.space.size() == 6 * 3  # N(N+1)/2 variants x degrees
