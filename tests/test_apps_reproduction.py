"""Paper-reproduction tests: GKV exb + Seism3D stress AT regions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import gkv, seism3d
from repro.core import (
    BasicParams,
    ExchangeVariant,
    GKV_FIGURE_OF_VARIANT,
    Tuner,
    TuningDB,
    WallClockCost,
    enumerate_exchange_variants,
)

SMALL_GKV = (("iv", 4), ("iz", 4), ("mx", 16), ("my", 9))
SMALL_SEISM = (("k", 8), ("j", 8), ("i", 8))


def test_gkv_all_ten_variants_match_oracle():
    key = jax.random.PRNGKey(0)
    inp = gkv.make_inputs(key, SMALL_GKV)
    nest = gkv.exb_nest(SMALL_GKV)
    ref = nest.reference(inp)
    for v in enumerate_exchange_variants(4):
        for degree in (1, 3, 32):
            out = nest.variant_fn(v, degree)(inp)
            np.testing.assert_allclose(
                out["wkdf1"], ref["wkdf1"], rtol=1e-4, atol=1e-8
            )


def test_gkv_complex_packing_is_componentwise():
    """The cmplx() trick packs two independent real products — verify the
    real/imag parts never mix (regression guard on the kernel math)."""
    key = jax.random.PRNGKey(1)
    inp = gkv.make_inputs(key, SMALL_GKV)
    zeroed = dict(inp)
    for name in ("wkdf1", "wkdf2", "wkexw", "wkeyw", "wkbxw", "wkbyw"):
        zeroed[name] = inp[name].real.astype(jnp.complex64)  # imag parts = 0
    out = gkv.reference(zeroed)["wkdf1"]
    np.testing.assert_allclose(np.imag(out), 0.0, atol=1e-12)


def test_seism3d_variants_match_oracle():
    key = jax.random.PRNGKey(0)
    inp = seism3d.make_inputs(key, SMALL_SEISM)
    nest = seism3d.stress_nest(SMALL_SEISM)
    ref = nest.reference(inp)
    for v in enumerate_exchange_variants(3):
        out = nest.variant_fn(v, 8)(inp)
        for name in ref:
            np.testing.assert_allclose(out[name], ref[name], rtol=1e-5, atol=1e-6)


def test_gkv_before_execution_at_end_to_end(tmp_path):
    """FIBER before-execution AT over the joint (variant × degree) space on a
    reduced GKV domain, with measured wall-clock cost — the paper's §V
    experiment in miniature.  Asserts the tuned candidate is no slower than
    the original loop (Fig-1 structure, max threads)."""
    key = jax.random.PRNGKey(0)
    inp = gkv.make_inputs(key, SMALL_GKV)
    region = gkv.exb_region(SMALL_GKV, degrees=(1, 4))
    region.precompile([inp])

    cost = WallClockCost(
        build=lambda p: (lambda: region.candidate(p)(inp)), warmup=1, repeats=2
    )
    db = TuningDB(str(tmp_path / "gkv.json"))
    bp = BasicParams.make(arch="gkv_exb", dims=SMALL_GKV)
    result = Tuner(db).tune(region, bp, cost)

    original = next(
        t for t in result.trials
        if t.point["variant"] == (4, 2) and t.point["degree"] == 4
    )
    assert result.best.cost <= original.cost * 1.05
    assert db.best_point(bp) == result.best.point
