"""Global tuning service tests (ISSUE 7, docs/fleet.md).

Covers: the ServiceClient's failure policy (timeout -> bounded-backoff
retry sequencing on a virtual clock, no real sleeps anywhere), partition ->
local-only degradation -> reconnect reconciliation, service restart
resuming from its persisted DB, the lost-demotion race (concurrent final +
demoted pushes for the same fingerprint must keep the demotion until a
completed re-tune supersedes it), pull semantics (exact-fingerprint final /
nearest-device warm seed / nothing), the remote FleetCoordinator backend
under deterministic fault injection, BackgroundTuner pull-adoption with
zero evaluations, AntiEntropySync re-tune propagation into the
DriftMonitor lifecycle, and one end-to-end run over the real stdlib HTTP
transport.
"""
import json

import jax.numpy as jnp
import pytest

from repro.core import AutotunedOp, BasicParams, ParamSpace, PerfParam, TuningDB
from repro.fleet import (
    AntiEntropySync,
    DriftMonitor,
    FaultInjectionTransport,
    FleetCoordinator,
    HTTPTransport,
    InProcessTransport,
    ServiceClient,
    ServiceUnavailable,
    Transport,
    TransportError,
    TuningService,
    VirtualClock,
    serve_http,
)
from repro.fleet.workloads import demo_cost, demo_space
from repro.runtime import BackgroundTuner

from test_fleet import X, _toy_spec

BP = BasicParams.make(kernel="svc", n=4)
POINT = {"i": 1}


def make_client(service, clock=None, **kw):
    clock = clock or VirtualClock()
    kw.setdefault("retries", 3)
    client = ServiceClient(InProcessTransport(service),
                          sleep=clock.sleep, now=clock.now, **kw)
    return client, clock


def db_with_final(cost=1.0, point=POINT, bp=BP):
    db = TuningDB()
    for i, c in enumerate([3.0, cost, 2.0]):
        db.record_trial(bp, {"i": i}, c, "before_execution")
    db.record_best(bp, point, cost, "before_execution")
    return db


class FlakyTransport(Transport):
    """Fails the first ``failures`` calls, then delegates (scripted)."""

    def __init__(self, inner, failures):
        self.inner = inner
        self.failures = failures
        self.calls = 0

    def request(self, op, payload):
        self.calls += 1
        if self.calls <= self.failures:
            raise TransportError(f"{op}: scripted failure {self.calls}")
        return self.inner.request(op, payload)


# ---------------------------------------------------------------------------
# Client failure policy: timeout -> backoff -> retry (virtual clock)
# ---------------------------------------------------------------------------


def test_backoff_schedule_is_bounded_exponential_with_jitter():
    client, clock = make_client(TuningService(), retries=6,
                                backoff_base=0.05, backoff_cap=0.4)
    delays = [client.backoff_s(a) for a in range(7)]
    for attempt, d in enumerate(delays):
        base = min(0.4, 0.05 * 2 ** attempt)
        assert 0.5 * base <= d <= 1.5 * base  # jitter factor in [0.5, 1.5)
    # the cap actually binds: late attempts stop growing
    assert all(d <= 0.4 * 1.5 for d in delays)
    # seeded jitter is reproducible
    again, _ = make_client(TuningService(), retries=6,
                           backoff_base=0.05, backoff_cap=0.4)
    assert [again.backoff_s(a) for a in range(7)] == delays


def test_retry_sequencing_sleeps_between_attempts_then_succeeds():
    """2 failures -> exactly 2 backoff sleeps at attempts 0 and 1, then
    the call lands; all timing on the virtual clock."""
    service = TuningService()
    clock = VirtualClock()
    flaky = FlakyTransport(InProcessTransport(service), failures=2)
    client = ServiceClient(flaky, retries=3, jitter_seed=0,
                           sleep=clock.sleep, now=clock.now)
    expected = [client.backoff_s(0), client.backoff_s(1)]
    # rebuild (backoff_s consumed jitter RNG state above)
    client = ServiceClient(flaky, retries=3, jitter_seed=0,
                           sleep=clock.sleep, now=clock.now)
    resp = client.push(db_with_final())
    assert resp["ok"] and flaky.calls == 3
    assert clock.sleeps == pytest.approx(expected)
    assert client.stats.retries == 2 and client.stats.failures == 0
    assert client.available
    assert service.db.tuned_point(BP) == POINT


def test_exhausted_retries_degrade_then_any_success_reconnects():
    service = TuningService()
    clock = VirtualClock()
    flaky = FlakyTransport(InProcessTransport(service), failures=10)
    client = ServiceClient(flaky, retries=2, sleep=clock.sleep, now=clock.now)
    with pytest.raises(ServiceUnavailable):
        client.push(db_with_final())
    assert not client.available
    assert flaky.calls == 3  # 1 + 2 retries
    assert len(clock.sleeps) == 2
    # degraded: try_* are single-probe (no retry ladder, no sleeps)
    assert client.try_push(db_with_final()) is False
    assert flaky.calls == 4 and len(clock.sleeps) == 2
    # the service comes back: the next probe reconnects
    flaky.failures = 0
    assert client.try_push(db_with_final()) is True
    assert client.available and client.stats.reconnects == 1


# ---------------------------------------------------------------------------
# Partition -> local-only degradation -> heal -> reconciliation
# ---------------------------------------------------------------------------


def test_partition_degrades_to_local_only_then_heals_and_reconciles():
    service = TuningService()
    clock = VirtualClock()
    ft = FaultInjectionTransport(InProcessTransport(service))
    client = ServiceClient(ft, retries=2, sleep=clock.sleep, now=clock.now)
    host_db = TuningDB()
    sync = AntiEntropySync(client, host_db)

    # healthy round first
    assert sync.sync_once()["ok"]

    ft.partition()
    # the host keeps tuning locally while partitioned
    host_db.record_trial(BP, POINT, 1.0, "before_execution")
    host_db.record_best(BP, POINT, 1.0, "before_execution")
    out = sync.sync_once()
    assert out == {"ok": False, "degraded": True, "retunes": 0}
    assert not client.available
    assert service.db.tuned_point(BP) is None  # nothing crossed the wire
    assert host_db.tuned_point(BP) == POINT    # local tuning unaffected

    # meanwhile the other side of the partition made progress too
    other = BasicParams.make(kernel="svc", n=8)
    service.push(db_with_final(bp=other).export_entries())

    ft.heal()
    out = sync.sync_once()
    assert out["ok"] and not out["degraded"]
    assert client.available and client.stats.reconnects == 1
    # both sides converged to the union
    assert service.db.tuned_point(BP) == POINT
    assert host_db.tuned_point(other) == POINT
    assert sync.failed_rounds == 1 and sync.rounds == 3


def test_service_restart_resumes_from_persisted_db(tmp_path):
    """Kill the service mid-run; a restart on the same path serves every
    entry any host pushed before the crash."""
    path = str(tmp_path / "service-db.json")
    first = TuningService(path=path)
    client, _ = make_client(first)
    client.push(db_with_final())
    del first  # "crash"

    restarted = TuningService(path=path)
    assert restarted.db.tuned_point(BP) == POINT
    client2, _ = make_client(restarted)
    resp = client2.pull(BP)
    assert resp["found"] == "final"
    assert resp["entry"]["best"]["point"] == POINT
    # pushes keep accumulating across the restart
    other = BasicParams.make(kernel="svc", n=8)
    client2.push(db_with_final(bp=other))
    assert TuningService(path=path).db.tuned_point(other) == POINT


# ---------------------------------------------------------------------------
# Pull semantics
# ---------------------------------------------------------------------------


def test_pull_final_nearest_none():
    service = TuningService()
    client, _ = make_client(service)
    assert client.pull(BP)["found"] is None
    client.push(db_with_final())
    exact = client.pull(BP)
    assert exact["found"] == "final" and exact["fingerprint"] == BP.fingerprint()
    # a sibling class: no exact final -> the nearest entry as a warm seed
    sibling = BasicParams.make(kernel="svc", n=16)
    near = client.pull(sibling)
    assert near["found"] == "nearest"
    assert near["fingerprint"] == BP.fingerprint()
    assert near["distance"] > 0
    assert near["entry"]["best"]["point"] == POINT
    assert client.stats.pulled_finals == 1 and client.stats.pulled_seeds == 1


# ---------------------------------------------------------------------------
# The lost-demotion race (ISSUE 7 satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("final_first", [True, False])
def test_concurrent_final_and_demotion_keep_the_demotion(final_first):
    """Host A pushes the final {P, C}; host B pushes the same record
    demoted.  In either arrival order the service must end demoted with a
    re-tune pending — merge alone would let A's final resurrect B's
    demotion."""
    service = TuningService()
    a = db_with_final()                    # host A: live final
    b = db_with_final()                    # host B: same final, demoted
    assert b.demote_best(BP)
    pushes = [a, b] if final_first else [b, a]
    for db in pushes:
        service.push(db.export_entries())
    assert service.db.tuned_point(BP) is None
    pending = service.retune_pending()
    assert pending == {BP.fingerprint(): {"point": POINT, "cost": 1.0}}
    # A's stale final re-pushed later (a retry, a laggard sync): still down
    service.push(a.export_entries())
    assert service.db.tuned_point(BP) is None
    assert BP.fingerprint() in service.retune_pending()


def test_retune_request_cleared_by_a_different_final():
    """A completed re-tune (new point, or same point at a freshly observed
    cost) supersedes the demotion; the stale final stays dead."""
    service = TuningService()
    stale = db_with_final()
    demoted = db_with_final()
    demoted.demote_best(BP)
    service.push(stale.export_entries())
    service.push(demoted.export_entries())
    assert service.db.tuned_point(BP) is None

    # host B finishes the re-tune: same point, re-finalized at observed cost
    retuned = db_with_final()
    retuned.demote_best(BP)
    retuned.record_best(BP, POINT, 1.7, "run_time")
    service.push(retuned.export_entries())
    assert service.db.tuned_point(BP) == POINT
    assert service.db.best_cost(BP) == pytest.approx(1.7)
    assert service.retune_pending() == {}
    # and the original stale final cannot resurrect the old record now:
    # the new final (1.7, run_time) wins the merge resolution for good
    service.push(stale.export_entries())
    assert service.db.best_cost(BP) == pytest.approx(1.7)


def test_explicit_demote_via_client_propagates_to_other_hosts():
    """host A demotes through the service; host B's next anti-entropy
    round demotes locally and schedules the DriftMonitor lifecycle."""
    service = TuningService()
    costs = [3.0, 1.0, 2.0]
    db_b = TuningDB()
    op = AutotunedOp(_toy_spec(costs), db=db_b, warm=False)
    state = op.resolve(X)
    assert db_b.tuned_point(state.bp) == {"i": 1}

    # host B publishes its final; host A (same device class) demotes it
    client_b, _ = make_client(service)
    client_b.push(db_b)
    client_a, _ = make_client(service)
    assert client_a.try_demote(state.bp)
    assert service.db.tuned_point(state.bp) is None

    monitor = DriftMonitor(factor=2.0, min_observations=1, canary_window=2)
    sync = AntiEntropySync(client_b, db_b, monitor=monitor).watch(op)
    costs[0] = 0.3  # the re-tune will nominate candidate 0
    out = sync.sync_once()
    assert out["ok"] and out["retunes"] == 1
    assert db_b.tuned_point(state.bp) is None  # demoted locally too
    # the inline re-tune canaried the challenger; promote it
    assert monitor.watch_phase(state) == "canary"
    for _ in range(2):
        monitor.observe(op, state, 0.3, (X,), {})
    assert db_b.tuned_point(state.bp) == {"i": 0}
    # next round publishes the verdict and the request clears fleet-wide
    sync.sync_once()
    assert service.db.tuned_point(state.bp) == {"i": 0}
    assert service.retune_pending() == {}


# ---------------------------------------------------------------------------
# Remote fleet backend under deterministic fault injection
# ---------------------------------------------------------------------------


def test_remote_backend_requires_service():
    with pytest.raises(ValueError, match="remote"):
        FleetCoordinator(backend="remote")
    with pytest.raises(ValueError, match="host_index"):
        FleetCoordinator(hosts=2, host_index=2)


def test_two_host_remote_fleet_converges_under_faults():
    """The acceptance scenario: 2 hosts, seeded drops + duplicates + one
    partition/heal, and the service's final best is byte-identical to the
    single-process run's."""
    space = demo_space()
    bp = BasicParams.make(kernel="remote_eq")
    single = FleetCoordinator(workers=1).search(space, demo_cost, bp=bp)

    service = TuningService()
    injectors = []
    for host in range(2):
        clock = VirtualClock()
        ft = FaultInjectionTransport(
            InProcessTransport(service), seed=7 + host,
            drop_request=0.2, drop_response=0.2, duplicate=0.2, reorder=0.1,
        )
        injectors.append(ft)
        client = ServiceClient(ft, retries=6, jitter_seed=host,
                               sleep=clock.sleep, now=clock.now)
        if host == 1:
            # one full partition mid-run: heal before the barrier retries
            ft.partition()
            assert client.try_push(TuningDB()) is False
            ft.heal()
        fleet = FleetCoordinator(
            workers=2, backend="remote", service=client,
            hosts=2, host_index=host, sync_every=2,
        ).search(space, demo_cost, bp=bp)
        assert fleet.service_synced is True
        assert len(clock.sleeps) == 0 or clock.sleeps  # virtual time only

    assert sum(ft.stats.faults for ft in injectors) > 0  # faults really fired
    # identical final-best entries vs the single-process run
    assert service.db.tuned_point(bp) == single.best.point
    assert service.db.best_cost(bp) == single.best.cost
    assert service.db.trials(bp) == single.merged.trials(bp)
    svc_best = service.db._data[bp.fingerprint()]["best"]
    single_best = single.merged._data[bp.fingerprint()]["best"]
    assert json.dumps(svc_best, sort_keys=True) == \
        json.dumps(single_best, sort_keys=True)


@pytest.mark.parametrize("seed", [0, 1, 7, 42, 1234])
def test_lossy_push_schedules_converge_across_seeds(seed):
    """Deterministic sibling of the hypothesis convergence property (which
    needs the optional hypothesis dep): across seeds, any drop/dup/reorder
    schedule plus heal converges to the lossless two-host merge."""
    bps = [BasicParams.make(kernel="conv", n=n) for n in (1, 2, 3)]
    hosts = []
    for h in range(2):
        db = TuningDB()
        for i, bp in enumerate(bps):
            db.record_trial(bp, {"i": h}, 1.0 + h + i, "before_execution")
            if (h + i) % 2 == 0:
                db.record_best(bp, {"i": h}, 1.0 + h + i, "before_execution")
        hosts.append(db)
    lossless = TuningDB()
    for db in hosts:
        lossless.merge(db.export_entries())

    service = TuningService()
    injectors = []
    for h, db in enumerate(hosts):
        clock = VirtualClock()
        ft = FaultInjectionTransport(
            InProcessTransport(service), seed=seed + h,
            drop_request=0.3, drop_response=0.3, duplicate=0.3, reorder=0.2,
        )
        injectors.append(ft)
        client = ServiceClient(ft, retries=2, jitter_seed=h,
                               sleep=clock.sleep, now=clock.now)
        if seed % 2 == h:  # one host rides through a partition
            ft.partition()
        for fp in db.fingerprints():
            client.try_push(db, [fp])
        client.try_push(db)
        ft.heal()
        ft.drop_request = ft.drop_response = 0.0
        ft.duplicate = ft.reorder = 0.0
        client.push(db)  # lossless catch-up

    canon = lambda d: json.dumps(d._data, sort_keys=True, default=str)  # noqa: E731
    assert canon(service.db) == canon(lossless)


def test_degraded_service_never_fails_the_fleet_run():
    """Service fully down: the remote backend still returns the correct
    local winner, flagged service_synced=False."""
    space = demo_space()
    bp = BasicParams.make(kernel="degraded")
    clock = VirtualClock()
    ft = FaultInjectionTransport(InProcessTransport(TuningService()))
    ft.partition()  # never healed
    client = ServiceClient(ft, retries=1, sleep=clock.sleep, now=clock.now)
    fleet = FleetCoordinator(
        workers=2, backend="remote", service=client, sync_every=2,
    ).search(space, demo_cost, bp=bp)
    assert fleet.service_synced is False
    assert fleet.best.point == {"block": 64, "variant": "ij"}
    assert not client.available


# ---------------------------------------------------------------------------
# BackgroundTuner pull-before-tune / push-after-tune
# ---------------------------------------------------------------------------


def test_background_tuner_adopts_service_final_with_zero_evaluations():
    service = TuningService()
    costs = [3.0, 1.0, 2.0]
    calls = []

    # host A tunes locally and pushes
    db_a = TuningDB()
    op_a = AutotunedOp(_toy_spec(costs), db=db_a, warm=False)
    state_a = op_a.resolve(X)
    client_a, _ = make_client(service)
    tuned_fp = state_a.bp.fingerprint()
    client_a.push(db_a, [tuned_fp])

    # host B: same class arrives; the tuner adopts without measuring
    db_b = TuningDB()
    op_b = AutotunedOp(_toy_spec(costs, calls=calls), db=db_b, warm=False)
    client_b, _ = make_client(service)
    with BackgroundTuner(service=client_b) as tuner:
        state_b = tuner.submit(op_b, X)
        assert tuner.drain(timeout=60)
    assert calls == []  # ZERO cost evaluations on host B
    assert state_b.from_cache
    assert state_b.region.selected == {"i": 1}
    assert db_b.tuned_point(state_b.bp) == {"i": 1}
    assert tuner.pulled_labels == ["fleet_toy"]
    assert client_b.stats.pulled_finals == 1
    assert not tuner.errors


def test_background_tuner_pushes_fresh_winner_to_service():
    service = TuningService()
    costs = [4.0, 1.0, 3.0]
    db = TuningDB()
    op = AutotunedOp(_toy_spec(costs), db=db, warm=False)
    client, _ = make_client(service)
    with BackgroundTuner(service=client) as tuner:
        state = tuner.submit(op, X)
        assert tuner.drain(timeout=60)
    assert tuner.pulled_labels == []  # nothing to pull: it tuned locally
    assert service.db.tuned_point(state.bp) == {"i": 1}  # ...and published
    assert client.stats.pushed_entries == 1


# ---------------------------------------------------------------------------
# End-to-end over real HTTP (stdlib http.server + urllib)
# ---------------------------------------------------------------------------


def test_http_transport_end_to_end():
    service = TuningService()
    try:
        server = serve_http(service, port=0)
    except OSError as e:  # sandboxed CI without loopback bind
        pytest.skip(f"cannot bind a loopback port: {e}")
    host, port = server.server_address[:2]
    try:
        client = ServiceClient(HTTPTransport(f"http://{host}:{port}"),
                               retries=1)
        health = client.health()
        assert health["ok"] and health["protocol"] == 1
        client.push(db_with_final())
        resp = client.pull(BP)
        assert resp["found"] == "final"
        assert resp["entry"]["best"]["point"] == POINT
        # a malformed request must not kill the server
        with pytest.raises(ServiceUnavailable):
            ServiceClient(HTTPTransport(f"http://{host}:{port}"),
                          retries=0).__getattribute__("_call")("nope", {})
        assert client.health()["ok"]
    finally:
        server.shutdown()


def test_http_transport_connection_refused_is_transport_error():
    t = HTTPTransport("http://127.0.0.1:1", timeout_s=0.5)  # reserved port
    with pytest.raises(TransportError):
        t.request("health", {})
