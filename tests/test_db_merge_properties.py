"""TuningDB.merge lattice properties (ISSUE 5 satellite).

The fleet sync barrier (docs/fleet.md) merges worker scratch DBs in
whatever order workers finish, and periodic syncs mean the same scratch
state can land more than once.  Correctness therefore rests on merge being
a *join*: commutative, associative, and idempotent over arbitrary entry
sets — not just the disjoint-shape-class happy path the older tests cover.

DBs are generated as operation sequences (trials, bests — final and
interim, runtime observations, events) over small colliding domains, so
the generator actually exercises the conflict policies: min-cost trials,
finality-then-cost-then-canonical-JSON bests, sorted-union logs.
"""
import json

import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import BasicParams, TuningDB  # noqa: E402

# Small colliding domains: few shape classes, few points, few costs, so
# generated DBs overlap on entries, points, and exact costs (tie-breaks).
BPS = [BasicParams.make(kernel="k", n=n) for n in (1, 2)]
POINTS = [{"i": 0}, {"i": 1}, {"i": 2}]
COSTS = [0.5, 1.0, 2.0]
LAYERS = ["install", "before_execution"]

op_strategy = st.one_of(
    st.tuples(st.just("trial"), st.integers(0, 1), st.integers(0, 2),
              st.integers(0, 2), st.integers(0, 1)),
    st.tuples(st.just("best"), st.integers(0, 1), st.integers(0, 2),
              st.integers(0, 2), st.integers(0, 1)),
    st.tuples(st.just("obs"), st.integers(0, 1), st.integers(0, 2),
              st.integers(0, 2)),
    st.tuples(st.just("event"), st.integers(0, 1),
              st.sampled_from(["demoted", "promoted", "rolled_back"])),
)


def build_db(ops) -> TuningDB:
    db = TuningDB()
    for op in ops:
        kind = op[0]
        if kind == "trial":
            _, b, p, c, l = op
            db.record_trial(BPS[b], POINTS[p], COSTS[c], LAYERS[l])
        elif kind == "best":
            _, b, p, c, l = op
            db.record_best(BPS[b], POINTS[p], COSTS[c], LAYERS[l])
        elif kind == "obs":
            _, b, p, c = op
            db.record_runtime_observation(BPS[b], POINTS[p], COSTS[c])
        else:
            _, b, k = op
            db.record_event(BPS[b], k)
    return db


def canon(db: TuningDB) -> str:
    return json.dumps(db._data, sort_keys=True, default=str)


def copy_of(db: TuningDB) -> TuningDB:
    """An independent deep copy (merge mutates the receiver)."""
    out = TuningDB()
    out._data = json.loads(json.dumps(db._data, default=str))
    return out


dbs = st.lists(op_strategy, max_size=12).map(build_db)


@settings(max_examples=60, deadline=None)
@given(a=dbs, b=dbs)
def test_merge_commutative(a, b):
    ab = copy_of(a).merge(copy_of(b))
    ba = copy_of(b).merge(copy_of(a))
    assert canon(ab) == canon(ba)


@settings(max_examples=40, deadline=None)
@given(a=dbs, b=dbs, c=dbs)
def test_merge_associative(a, b, c):
    left = copy_of(a).merge(copy_of(b).merge(copy_of(c)))
    right = copy_of(a).merge(copy_of(b)).merge(copy_of(c))
    assert canon(left) == canon(right)


@settings(max_examples=60, deadline=None)
@given(a=dbs)
def test_merge_idempotent(a):
    """merge(A, A) is A up to canonical log order (a merged DB is a
    canonical form: its telemetry logs are deterministically sorted)."""
    normalized = TuningDB().merge(copy_of(a))
    merged = copy_of(a).merge(copy_of(a))
    assert canon(merged) == canon(normalized)
    # and a second self-merge is a strict fixpoint
    assert canon(copy_of(merged).merge(copy_of(merged))) == canon(merged)


@settings(max_examples=40, deadline=None)
@given(a=dbs, b=dbs)
def test_merge_absorbs_remerge(a, b):
    """Re-delivering a scratch DB after the barrier (a periodic sync racing
    the final merge) must be a no-op."""
    merged = copy_of(a).merge(copy_of(b))
    again = copy_of(merged).merge(copy_of(b)).merge(copy_of(a))
    assert canon(again) == canon(merged)


@settings(max_examples=40, deadline=None)
@given(a=dbs, b=dbs)
def test_merge_preserves_final_bests(a, b):
    """No merge order may lose a completed search: if either side has a
    final best for an entry, the merged DB has a final best for it."""
    merged = copy_of(a).merge(copy_of(b))
    for db in (a, b):
        for bp in BPS:
            if db.tuned_point(bp) is not None:
                assert merged.tuned_point(bp) is not None


# ---------------------------------------------------------------------------
# Convergence through a faulty network (ISSUE 7)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    a=dbs, b=dbs,
    seed=st.integers(0, 2 ** 16),
    drop=st.sampled_from([0.0, 0.2, 0.5]),
    dup=st.sampled_from([0.0, 0.3]),
    reorder=st.sampled_from([0.0, 0.3]),
    partition_host=st.sampled_from([None, 0, 1]),
    rounds=st.integers(1, 3),
)
def test_lossy_push_schedule_converges_to_lossless_merge(
    a, b, seed, drop, dup, reorder, partition_host, rounds
):
    """ANY seeded schedule of dropped / duplicated / reordered / retried
    pushes from two hosts — plus an optional mid-run partition — followed
    by a heal and lossless anti-entropy rounds, leaves the service and both
    hosts byte-identical to one lossless ``merge(a).merge(b)``.

    This is the property the whole remote protocol rests on: because every
    delivery is a lattice join, the *schedule* (which requests arrive, how
    many times, in what order) is irrelevant to the converged state.
    """
    from repro.fleet import (
        FaultInjectionTransport,
        InProcessTransport,
        ServiceClient,
        TuningService,
        VirtualClock,
    )

    service = TuningService()
    hosts = [copy_of(a), copy_of(b)]
    injectors, clients = [], []
    for i in range(2):
        clock = VirtualClock()
        ft = FaultInjectionTransport(
            InProcessTransport(service), seed=seed + i,
            drop_request=drop, drop_response=drop,
            duplicate=dup, reorder=reorder,
        )
        injectors.append(ft)
        clients.append(ServiceClient(
            ft, retries=2, jitter_seed=i,
            sleep=clock.sleep, now=clock.now,
        ))

    # the lossy phase: interleaved pushes, entry-at-a-time and whole-DB,
    # with one host optionally partitioned for part of the schedule
    if partition_host is not None:
        injectors[partition_host].partition()
    for _ in range(rounds):
        for i, host_db in enumerate(hosts):
            for fp in host_db.fingerprints():
                clients[i].try_push(host_db, [fp])  # may drop/dup/reorder
            clients[i].try_push(host_db)

    # heal (replays anything held) + lossless anti-entropy rounds
    for ft in injectors:
        ft.heal()
        ft.drop_request = ft.drop_response = 0.0
        ft.duplicate = ft.reorder = 0.0
    assert clients[0].sync(hosts[0])["ok"]
    assert clients[1].sync(hosts[1])["ok"]
    assert clients[0].sync(hosts[0])["ok"]  # A picks up B's entries

    expected = canon(TuningDB().merge(copy_of(a)).merge(copy_of(b)))
    assert canon(service.db) == expected
    assert canon(hosts[0]) == expected
    assert canon(hosts[1]) == expected
