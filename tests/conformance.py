"""Shared kernel-conformance harness.

One declarative case per registered Pallas kernel: a small shape class, an
input builder, the `ref.py` oracle, and per-dtype error thresholds.  The
suite in test_conformance.py sweeps *every feasible point* of the case's
region against the oracle — the semantic contract every ATRegion candidate
family must satisfy (all candidates are interchangeable), and the single
place to add a case when registering a new kernel (docs/registry.md).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.exb import ops as exb_ops, ref as exb_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rglru_scan import ops as rg_ops, ref as rg_ref
from repro.kernels.ssm_scan import ops as ssm_ops, ref as ssm_ref
from repro.kernels.stress import ops as st_ops, ref as st_ref

# (rtol, atol) per dtype name — bf16 kernels accumulate in f32 but round
# inputs/outputs, hence the looser bound.
DEFAULT_TOL: Dict[str, Tuple[float, float]] = {
    "float32": (2e-4, 1e-5),
    "bfloat16": (2e-2, 2e-2),
}


@dataclass
class ConformanceCase:
    """One kernel's small-shape conformance contract."""

    name: str
    region_factory: Callable[[], Any]          # () -> ATRegion (small shapes)
    make_args: Callable[[jax.Array], tuple]    # key -> kernel positional args
    oracle: Callable[..., Any]                 # ref.py ground truth
    dtypes: Tuple[str, ...] = ("float32",)
    tol: Dict[str, Tuple[float, float]] = field(default_factory=lambda: dict(DEFAULT_TOL))
    kernel: str = ""                           # registry name (default: name)

    @property
    def kernel_name(self) -> str:
        return self.kernel or self.name

    def cast_args(self, args: tuple, dtype: str) -> tuple:
        target = jnp.dtype(dtype)
        return tuple(
            jax.tree.map(
                lambda x: x.astype(target)
                if isinstance(x, jnp.ndarray) and jnp.issubdtype(x.dtype, jnp.floating)
                else x,
                a,
            )
            for a in args
        )


def assert_tree_allclose(out: Any, expected: Any, rtol: float, atol: float, label: str) -> None:
    """Structural allclose over arrays / tuples / dicts of arrays."""
    out_leaves, out_tree = jax.tree.flatten(out)
    exp_leaves, exp_tree = jax.tree.flatten(expected)
    assert out_tree == exp_tree, f"{label}: structure {out_tree} != {exp_tree}"
    for i, (o, e) in enumerate(zip(out_leaves, exp_leaves)):
        np.testing.assert_allclose(
            np.asarray(o, np.float32),
            np.asarray(e, np.float32),
            rtol=rtol,
            atol=atol,
            err_msg=f"{label}: leaf {i}",
        )


def _flash_args(key: jax.Array) -> tuple:
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 1, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 1, 16), jnp.float32)
    return q, k, v


def _flash_args_padded(key: jax.Array) -> tuple:
    # 200 is not a multiple of any pow2 block: every emitted candidate
    # except the full-extent one tiles past the edge and masks the tail
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 200, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 200, 1, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 200, 1, 16), jnp.float32)
    return q, k, v


CASES: Dict[str, ConformanceCase] = {
    case.name: case
    for case in (
        ConformanceCase(
            name="exb",
            region_factory=lambda: exb_ops.exb_region(dims=(4, 4, 16, 9)),
            make_args=lambda key: (exb_ref.make_inputs(key, dims=(4, 4, 16, 9)),),
            oracle=exb_ref.exb_ref,
        ),
        ConformanceCase(
            name="stress",
            region_factory=lambda: st_ops.stress_region(dims=(8, 8, 16)),
            make_args=lambda key: (st_ref.make_inputs(key, dims=(8, 8, 16)),),
            oracle=st_ref.stress_ref,
        ),
        ConformanceCase(
            name="flash_attention",
            region_factory=lambda: fa_ops.flash_region(seq_len=256, head_dim=16),
            make_args=_flash_args,
            oracle=lambda q, k, v: fa_ref.attention_ref(q, k, v, causal=True),
            dtypes=("float32", "bfloat16"),
        ),
        ConformanceCase(
            name="flash_attention_padded",
            kernel="flash_attention",
            region_factory=lambda: fa_ops.flash_region(seq_len=200, head_dim=16),
            make_args=_flash_args_padded,
            oracle=lambda q, k, v: fa_ref.attention_ref(q, k, v, causal=True),
        ),
        ConformanceCase(
            name="ssm_scan",
            region_factory=lambda: ssm_ops.ssm_region(
                d_inner=128, seq_len=64, n_state=4
            ),
            make_args=lambda key: ssm_ref.make_inputs(key, B=1, S=64, D=128, N=4),
            oracle=ssm_ref.ssm_scan_ref,
            tol={"float32": (1e-4, 1e-4)},
        ),
        ConformanceCase(
            name="rglru_scan",
            region_factory=lambda: rg_ops.rglru_region(width=128, seq_len=64),
            make_args=lambda key: rg_ref.make_inputs(key, B=1, S=64, W=128),
            oracle=rg_ref.rglru_scan_ref,
            tol={"float32": (1e-4, 1e-4)},
        ),
    )
}
