"""Documentation integrity: the cross-link suite required by ISSUE 2.

The same checks run standalone in CI via scripts/check_doc_links.py; keeping
them in the tier-1 suite means a PR cannot land a dangling ``design.md §N``
reference (the bug this suite was added to fix) or a broken relative link.
"""
import os
import sys

import pytest

SCRIPTS = os.path.join(os.path.dirname(__file__), "..", "scripts")
sys.path.insert(0, SCRIPTS)

import check_doc_links  # noqa: E402


def test_required_docs_exist():
    for rel in ("README.md", "docs/design.md", "docs/registry.md", "docs/serving.md"):
        assert (check_doc_links.ROOT / rel).exists(), f"missing {rel}"


def test_markdown_links_resolve():
    assert check_doc_links.check_markdown_links() == []


def test_design_section_references_resolve():
    """Every `design.md §N` citation in docs/ and src/ names a real section."""
    assert check_doc_links.check_design_section_refs() == []


def test_no_dangling_designmd_references():
    """The seed's dangling bare `DESIGN.md` references are gone for good."""
    offenders = []
    src = check_doc_links.ROOT / "src"
    for path in src.rglob("*.py"):
        if "DESIGN.md" in path.read_text():
            offenders.append(str(path))
    assert offenders == []
