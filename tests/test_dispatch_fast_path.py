"""Zero-overhead dispatch fast path (docs/program.md).

Acceptance properties:

* two threads racing an untuned op resolve ONE canonical state (one tune,
  one state object) — the fast path must not reintroduce the duplicate-state
  race the per-fingerprint build locks close;
* once a shape class is final, dispatch never re-enters the slow path (no
  shape-class extraction, no fingerprint, no lock) — counted via
  ``slow_resolutions``;
* a selection change (RuntimeSelector demotion, joint hot apply) rebinds the
  fast route in place instead of falling back to the slow path;
* value-dependent class extraction (traffic-class specs) and unkeyable
  arguments stay on the slow path — the fast key is structural only.
"""
import threading

import jax.numpy as jnp
import pytest

from repro.core import (
    ATRegion,
    AutotunedOp,
    BasicParams,
    KernelSpec,
    ParamSpace,
    PerfParam,
    TuningDB,
)
from repro.core.autotuned import _arg_sig, _fast_key
from repro.core.traffic import TrafficClass


def _toy_spec(costs, calls, name="fast_toy", tune_delay=0.0):
    space = ParamSpace([PerfParam("i", tuple(range(len(costs))))])

    def cost_factory(region, bp, args, kwargs):
        def cost(point):
            if tune_delay:
                import time

                time.sleep(tune_delay)  # widen the race window
            calls.append(point["i"])
            return float(costs[point["i"]])

        return cost

    return KernelSpec(
        name,
        make_region=lambda bp: ATRegion(
            name, space, lambda p: (lambda x: x * p["i"])
        ),
        shape_class=lambda x: BasicParams.make(kernel=name, n=int(x.shape[0])),
        cost_factory=cost_factory,
    )


X = jnp.ones(4)


# ---------------------------------------------------------------------------
# concurrency: one canonical state under racing resolvers
# ---------------------------------------------------------------------------


def test_two_threads_racing_untuned_op_resolve_one_canonical_state():
    calls = []
    # both threads release together at the starting line; the slow cost
    # widens the window so the loser really does race into _resolve while
    # the winner is still tuning
    barrier = threading.Barrier(2)
    op = AutotunedOp(_toy_spec([3.0, 1.0, 2.0], calls, tune_delay=0.05),
                     db=TuningDB())
    states, errors = [], []

    def worker():
        try:
            barrier.wait(timeout=5)
            op(X)
            states.append(op.resolve(X))
        except Exception as e:  # pragma: no cover - diagnostic
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not errors
    assert len(op.states()) == 1          # one canonical state
    assert len(calls) == 3                # tuned exactly once (3 candidates)
    assert states[0] is states[1]         # both threads share it
    assert states[0].region.selected == {"i": 1}


def test_racing_callers_after_finalization_all_hit_fast_path():
    calls = []
    op = AutotunedOp(_toy_spec([2.0, 1.0], calls), db=TuningDB())
    op(X)  # tune + finalize
    op(X)  # install/refresh the fast route
    before = op.slow_resolutions

    def worker():
        for _ in range(50):
            op(X)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert op.slow_resolutions == before
    assert len(calls) == 2  # no re-tune ever


# ---------------------------------------------------------------------------
# finalized classes never re-enter the slow path
# ---------------------------------------------------------------------------


def test_finalized_class_never_reenters_slow_path():
    calls = []
    op = AutotunedOp(_toy_spec([3.0, 1.0, 2.0], calls), db=TuningDB())
    op(X)                       # tune (slow), installs the fast route
    base = op.slow_resolutions
    for _ in range(200):
        op(X)
    assert op.slow_resolutions == base
    assert len(op._fast) == 1


def test_db_hit_installs_fast_route_in_fresh_process(tmp_path):
    path = str(tmp_path / "db.json")
    calls = []
    spec = _toy_spec([5.0, 4.0, 1.0], calls)
    AutotunedOp(spec, db=TuningDB(path))(X)
    op2 = AutotunedOp(spec, db=TuningDB(path))  # "fresh process"
    op2(X)                      # from_cache resolution finalizes immediately
    base = op2.slow_resolutions
    op2(X)
    assert op2.slow_resolutions == base
    assert len(calls) == 3      # the second op never evaluated anything


def test_untuned_op_stays_on_slow_path():
    calls = []
    op = AutotunedOp(_toy_spec([2.0, 1.0], calls), db=TuningDB(), tune=False)
    op(X)
    op(X)
    assert not op._fast          # nothing final: no fast route
    assert op.slow_resolutions >= 2


def test_interim_budget_capped_winner_does_not_finalize(tmp_path):
    calls = []
    op = AutotunedOp(
        _toy_spec([3.0, 1.0, 2.0], calls), db=TuningDB(), trial_budget=2
    )
    op(X)
    # budget hit mid-search: the DB best is not final, so dispatch must keep
    # resolving (the next run should resume the sweep, not freeze the interim)
    assert not op._fast


def test_distinct_shapes_get_distinct_fast_routes():
    calls = []
    op = AutotunedOp(_toy_spec([2.0, 1.0], calls), db=TuningDB())
    a, b = jnp.ones(4), jnp.ones(8)
    op(a), op(a)
    op(b), op(b)
    assert len(op._fast) == 2
    base = op.slow_resolutions
    op(a), op(b)
    assert op.slow_resolutions == base


# ---------------------------------------------------------------------------
# selection changes rebind in place
# ---------------------------------------------------------------------------


def test_select_after_finalization_rebinds_without_slow_path():
    calls = []
    op = AutotunedOp(_toy_spec([3.0, 1.0, 2.0], calls), db=TuningDB())
    op(X)
    state = op.resolve(X)
    base = op.slow_resolutions
    state.region.select({"i": 2})  # demotion / joint hot apply
    out = op(X)
    assert float(out[0]) == 2.0    # the new selection is live
    assert op.slow_resolutions == base
    state.region.select({"i": 0})
    assert float(op(X)[0]) == 0.0
    assert op.slow_resolutions == base


def test_region_invalidate_rebuilds_candidates_lazily():
    calls = []
    op = AutotunedOp(_toy_spec([2.0, 1.0], calls), db=TuningDB())
    op(X)
    state = op.resolve(X)
    state.region.invalidate()
    assert state.region.compiled_points() == 0
    assert float(op(X)[0]) == 1.0  # rebuilt from instantiate, same selection


# ---------------------------------------------------------------------------
# monitoring keeps a trickle of run-time observations
# ---------------------------------------------------------------------------


def test_fast_path_still_feeds_selector_periodically():
    calls = []
    op = AutotunedOp(_toy_spec([2.0, 1.0], calls), db=TuningDB(),
                     monitor_every=10)
    op(X)
    state = op.resolve(X)
    before = len(state.selector._recent) + len(op.db.history(state.bp))
    for _ in range(25):
        op(X)
    after = len(op.db.history(state.bp))
    assert after >= 2  # ~every 10th call observed, not zero and not 25
    assert after <= 4 + before


# ---------------------------------------------------------------------------
# structural keys: what can and cannot collapse
# ---------------------------------------------------------------------------


def test_traffic_class_specs_never_fast_dispatch():
    spec = KernelSpec(
        "traffic_toy",
        make_region=lambda bp: ATRegion(
            "traffic_toy", ParamSpace([PerfParam("i", (0, 1))]),
            lambda p: (lambda x: x),
        ),
        shape_class=lambda x: BasicParams.make(kernel="traffic_toy"),
        traffic_class=lambda x: TrafficClass.of("prefill", 1, int(x.shape[0])),
        cost_factory=lambda r, b, a, k: (lambda p: float(p["i"])),
    )
    op = AutotunedOp(spec, db=TuningDB())
    assert op.fast_dispatch is False
    op(X)
    op(X)
    assert not op._fast


def test_fast_key_structural_coverage():
    a = jnp.ones((2, 3), jnp.float32)
    b = jnp.ones((2, 3), jnp.bfloat16)
    assert _fast_key((a,), {}) != _fast_key((b,), {})          # dtype matters
    assert _fast_key((a,), {}) != _fast_key((a.T,), {})        # shape matters
    assert _fast_key((a,), {}) == _fast_key((jnp.zeros((2, 3)),), {})
    assert _fast_key((a,), {"causal": True}) != _fast_key((a,), {"causal": False})
    assert _fast_key(({"x": a, "n": 3},), {}) == _fast_key(({"n": 3, "x": b.astype(jnp.float32)},), {})
    assert _fast_key((object(),), {}) is None                  # unkeyable


def test_unkeyable_args_fall_back_to_slow_path():
    calls = []
    space = ParamSpace([PerfParam("i", (0, 1))])
    spec = KernelSpec(
        "unkeyable_toy",
        make_region=lambda bp: ATRegion(
            "unkeyable_toy", space, lambda p: (lambda x, fn: x)
        ),
        shape_class=lambda x, fn: BasicParams.make(kernel="unkeyable_toy"),
        cost_factory=lambda r, b, a, k: (lambda p: float(p["i"]) + 1),
    )
    op = AutotunedOp(spec, db=TuningDB())
    op(X, lambda: None)          # a callable arg cannot be keyed
    base = op.slow_resolutions
    op(X, lambda: None)
    assert not op._fast
    assert op.slow_resolutions == base + 1


def test_arg_sig_scalar_and_container_forms():
    assert _arg_sig(3) == 3 and _arg_sig("x") == "x" and _arg_sig(None) is None
    assert _arg_sig([1, 2]) == (1, 2)
    with pytest.raises(TypeError):
        _arg_sig(object())


def test_fast_table_is_bounded(monkeypatch):
    """Varying scalar args must not leak one route per value forever."""
    import importlib

    # repro.core re-exports the autotuned() *function* under the same name,
    # so attribute-style module access resolves to it; go via the module map
    at = importlib.import_module("repro.core.autotuned")
    monkeypatch.setattr(at, "FAST_TABLE_LIMIT", 4)
    calls = []
    space = ParamSpace([PerfParam("i", (0, 1))])
    spec = KernelSpec(
        "bounded_toy",
        make_region=lambda bp: ATRegion(
            "bounded_toy", space, lambda p: (lambda x, n: x)
        ),
        shape_class=lambda x, n: BasicParams.make(kernel="bounded_toy"),
        cost_factory=lambda r, b, a, k: (lambda p: float(p["i"]) + 1),
    )
    op = AutotunedOp(spec, db=TuningDB())
    for n in range(20):  # 20 distinct scalar values -> 20 distinct keys
        op(X, n)
    assert len(op._fast) <= 4
    # overflow keys still dispatch correctly via the slow path
    assert float(op(X, 99)[0]) == 1.0


def test_dispatch_returns_executable_candidate():
    calls = []
    op = AutotunedOp(_toy_spec([2.0, 1.0], calls), db=TuningDB())
    op(X)
    fn = op.dispatch(X)
    base = op.slow_resolutions
    assert float(fn(X)[0]) == 1.0
    assert op.dispatch(X) is fn  # stable binding while selection holds
    assert op.slow_resolutions == base
