"""Smoke coverage for scripts/gen_experiments_tables.py (ISSUE 5 satellite).

The table generator had zero test coverage: a schema drift in
results/*.jsonl (or in the configs it enriches rows with) would only
surface when someone regenerated EXPERIMENTS.md tables by hand.  This runs
the script against a canned results directory and checks the emitted
markdown tables actually parse.
"""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPT = os.path.join(ROOT, "scripts", "gen_experiments_tables.py")


def _canned_row(arch="tinyllama-1.1b", shape="train_4k", **extra):
    row = {
        "status": "ok",
        "arch": arch,
        "shape": shape,
        "mesh": "data2xmodel2",
        "chips": 4,
        "roofline": {
            "hlo_flops": 1.2e15,
            "compute_s": 1.0e-2,
            "memory_s": 2.0e-2,
            "collective_s": 5.0e-3,
            "total_s": 3.5e-2,
            "bottleneck": "memory",
        },
        "memory": {"per_device_total": 6 * 2**30},
    }
    row.update(extra)
    return row


def _write_jsonl(path, rows):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")


def _table_rows(stdout):
    """All markdown table lines, grouped as (header, rows) sanity pairs."""
    return [l for l in stdout.splitlines() if l.startswith("|")]


def test_gen_tables_smoke(tmp_path):
    results = tmp_path / "results"
    _write_jsonl(
        str(results / "dryrun_baseline.jsonl"),
        [
            _canned_row(),
            _canned_row(shape="prefill_32k"),
            {"status": "oom", "arch": "tinyllama-1.1b", "shape": "long_500k"},
        ],
    )
    _write_jsonl(
        str(results / "hillclimb.jsonl"),
        [_canned_row(label="cell1/step0", rule="tp", n_micro=2)],
    )
    proc = subprocess.run(
        [sys.executable, SCRIPT], cwd=str(tmp_path),
        capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "§Roofline" in proc.stdout
    assert "§Perf" in proc.stdout  # the hillclimb section rendered too

    lines = _table_rows(proc.stdout)
    # 2 tables x (header + separator) + 2 baseline rows + 1 hillclimb row
    assert len(lines) == 7, proc.stdout
    for header in (l for i, l in enumerate(lines) if "---" in lines[min(i + 1, len(lines) - 1)]):
        width = header.count("|")
        assert width >= 3
    # every data row has the same column count as its table header
    widths = [l.count("|") for l in lines]
    assert widths[0] == widths[1] == widths[2] == widths[3]  # baseline table
    assert widths[4] == widths[5] == widths[6]               # hillclimb table
    # the failed cell is excluded from the table, not rendered as garbage
    assert "long_500k" not in "".join(lines)


def test_gen_tables_empty_results_ok(tmp_path):
    """No results at all still renders the (empty) baseline section."""
    proc = subprocess.run(
        [sys.executable, SCRIPT], cwd=str(tmp_path),
        capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "§Roofline" in proc.stdout
