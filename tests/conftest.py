import os
import sys

# NOTE: deliberately NOT setting xla_force_host_platform_device_count here —
# smoke tests and benches must see the host's single real device; only the
# dry-run entry point (repro.launch.dryrun) forces 512 placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
