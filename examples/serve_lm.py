"""Batched serving example: prefill + greedy decode with KV/state caches.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b

Serves a batch of synthetic requests through the production Server (AOT
prefill/decode executables, per-family cache: KV ring buffers for the hybrid
arch, O(1) SSM state for falcon-mamba).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data import synthetic_requests
from repro.models import init_params, param_specs
from repro.runtime import Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--batch-size", type=int, default=3)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), param_specs(cfg))
    server = Server(cfg, params, batch_size=args.batch_size)

    reqs = synthetic_requests(
        cfg, n=args.requests, prompt_len=args.prompt_len,
        max_new_tokens=args.new_tokens,
    )
    out = server.run(reqs)
    for rid in sorted(out):
        toks = out[rid]
        print(f"req {rid}: {len(toks)} tokens -> {toks[:12]}{'...' if len(toks) > 12 else ''}")
    s = server.stats
    print(
        f"\nprefill {s.prefill_s * 1e3:.1f} ms total; decode {s.decode_s * 1e3:.1f} ms; "
        f"{s.decode_tok_per_s:.1f} tok/s (CPU host, reduced config)"
    )


if __name__ == "__main__":
    main()
