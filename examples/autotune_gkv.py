"""Paper reproduction driver: the full §III–§V GKV experiment.

    PYTHONPATH=src python examples/autotune_gkv.py [--fast]

Runs the joint (10 loop variants × thread degrees) before-execution AT on
the GKV exb_realspcal kernel at the paper's exact domain (iv=16, iz=16,
mx=128, my=65), prints the Fig-11/13/14 tables, and compares against the
paper's FX100 findings.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.apps import gkv
from repro.core import (
    BasicParams,
    GKV_FIGURE_OF_VARIANT,
    Tuner,
    TuningDB,
    WallClockCost,
    enumerate_exchange_variants,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--db", default="results/gkv_tuning.json")
    args = ap.parse_args()

    dims = (
        (("iv", 8), ("iz", 8), ("mx", 32), ("my", 17)) if args.fast else gkv.GKV_DIMS
    )
    degrees = (1, 32) if args.fast else (1, 2, 4, 8, 16, 32)
    inp = gkv.make_inputs(jax.random.PRNGKey(0), dims)
    region = gkv.exb_region(dims, degrees=degrees)

    print(f"domain {dict(dims)}, {region.space.size()} candidates")
    cost = WallClockCost(
        build=lambda p: (lambda f=jax.jit(region.instantiate(p)): f(inp)),
        warmup=1, repeats=3,
    )
    bp = BasicParams.make(arch="gkv_exb", dims=tuple(dims), degrees=degrees)
    result = Tuner(TuningDB(args.db)).tune(region, bp, cost)

    costs = {(tuple(t.point["variant"]), t.point["degree"]): t.cost
             for t in result.trials}
    t_orig = costs[((4, 2), max(degrees))]

    print(f"\n{'variant':34s}{'best ms':>9s}{'(deg)':>6s}{'vs orig':>9s}{'deg gain':>9s}")
    for v in enumerate_exchange_variants(4):
        fig = GKV_FIGURE_OF_VARIANT[(v.m, v.j)]
        per_d = {d: costs[((v.m, v.j), d)] for d in degrees}
        bd = min(per_d, key=per_d.get)
        print(
            f"{fig:34s}{per_d[bd] * 1e3:9.2f}{bd:6d}"
            f"{t_orig / per_d[bd]:9.3f}{per_d[max(degrees)] / per_d[bd]:9.3f}"
        )
    print(
        f"\ncombined best: {result.best.point} -> "
        f"{t_orig / result.best.cost:.3f}x vs original (paper FX100: 1.801x)"
    )
    print(f"evaluations: {result.evaluations}; tuning DB: {args.db}")


if __name__ == "__main__":
    main()
