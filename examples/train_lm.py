"""End-to-end training driver: train a reduced-config LM for a few hundred
steps on CPU with the full production loop (checkpointing, deterministic
data, run-time AT on the microbatch degree, straggler monitoring).

    PYTHONPATH=src python examples/train_lm.py --arch tinyllama-1.1b --steps 300

Loss must decrease on the synthetic-documents stream (structured bigrams);
the script asserts a ≥20 % drop and prints the trajectory.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data import SyntheticLMDataset
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="results/train_lm_ckpt")
    ap.add_argument("--resume", action="store_true",
                    help="resume from an existing checkpoint dir (default: fresh)")
    ap.add_argument("--scale", type=float, default=2.0,
                    help="widen the smoke config by this factor (~100M-class at 8)")
    args = ap.parse_args()

    base = get_config(args.arch, smoke=True)
    s = args.scale
    cfg = base.with_(
        d_model=int(base.d_model * s),
        d_ff=int(base.d_ff * s),
        n_layers=max(2, int(base.n_layers * min(s, 2))),
        vocab_size=base.vocab_size * 4,
    )
    from repro.models import analytic_param_count

    print(f"model: {cfg.name} scaled -> {analytic_param_count(cfg) / 1e6:.1f}M params")
    if not args.resume and args.ckpt_dir and os.path.isdir(args.ckpt_dir):
        import shutil

        shutil.rmtree(args.ckpt_dir)  # fresh run unless --resume

    trainer = Trainer(
        cfg,
        AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
        TrainLoopConfig(
            total_steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            save_every=max(50, args.steps // 4),
            n_microbatches=1,
            microbatch_candidates=(1, 2),
        ),
    )
    ds = SyntheticLMDataset(cfg, global_batch=args.batch, seq_len=args.seq)
    hist = trainer.run(ds)

    losses = hist["loss"]
    first = float(np.mean(losses[:20]))
    last = float(np.mean(losses[-20:]))
    print(f"\nsteps: {len(losses)}  loss {first:.3f} -> {last:.3f} "
          f"({(1 - last / first) * 100:.1f}% drop)")
    print(f"median step time: {np.median(hist['step_time']) * 1e3:.1f} ms; "
          f"stragglers flagged: {trainer.straggler_events}; restarts: {trainer.restarts}")
    for i in range(0, len(losses), max(1, len(losses) // 12)):
        print(f"  step {hist['step'][i]:4d}  loss {losses[i]:.4f}")
    assert last < first * 0.9, "loss did not drop >= 10%"
    print("convergence check passed ✓")


if __name__ == "__main__":
    main()
