"""Quickstart: call a Pallas kernel through the autotuned-op registry.

    PYTHONPATH=src python examples/quickstart.py

This is the 30-line version of the install-layer workflow: every kernel in
`repro.kernels` registers itself with the process-wide registry, so one call
to ``autotuned("flash_attention")`` performs the whole FIBER stack — shape
class → TuningDB lookup → (on miss) search over the block-shape candidates →
AOT-warm the top-k → dispatch.  The DB persists to disk, so the second run
of this script performs zero cost evaluations.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TuningDB, autotuned
from repro.kernels.flash_attention.ref import attention_ref

DB_PATH = os.path.join(tempfile.gettempdir(), "quickstart_registry_db.json")

# 1. Inputs: a small causal-GQA attention call (B, S, H, hd).
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (1, 256, 2, 16), jnp.float32)
k = jax.random.normal(ks[1], (1, 256, 1, 16), jnp.float32)
v = jax.random.normal(ks[2], (1, 256, 1, 16), jnp.float32)

# 2. The registry front door: look up / tune / warm / dispatch in one call.
op = autotuned("flash_attention", db=TuningDB(DB_PATH), top_k=2)
out = op(q, k, v)

state = op.resolve(q, k, v)
print(f"shape class: {state.bp}")
print(f"candidates:  {state.region.space.size()} "
      f"(cost evaluations this run: {state.cost_evaluations})")
print(f"selected:    {state.region.selected}  "
      f"(warmed {state.region.compiled_points()} candidates, db={DB_PATH})")

# 3. Verified against the pure-jnp oracle.
np.testing.assert_allclose(
    np.asarray(out), np.asarray(attention_ref(q, k, v)), rtol=2e-4, atol=2e-4
)
print("autotuned kernel output verified against oracle ✓")

# 4. Re-run this script: the DB hit makes tuning free (cost_evaluations=0).
