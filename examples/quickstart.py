"""Quickstart: bracket a loop nest as an AT region and tune it.

    PYTHONPATH=src python examples/quickstart.py

This is the 30-line version of the paper's workflow: define the nest
(the ``!oat$ install Exchange region start/end`` bracket), give the tuner a
cost function, get back the argmin (variant × degree) — then call the region
as an ordinary function.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import BasicParams, LoopNest, Tuner, TuningDB, WallClockCost

# 1. An elementwise 3-deep loop nest (a small stencil-free update).
nest = LoopNest(
    "demo",
    dims=[("i", 8), ("j", 32), ("k", 64)],
    body=lambda x: jnp.tanh(x) * 1.5 + 0.5,
)
region = nest.at_region(degrees=(1, 4, 16))

# 2. Inputs + oracle.
x = jax.random.normal(jax.random.PRNGKey(0), (8, 32, 64), jnp.float32)
print("candidates:", region.space.size())

# 3. FIBER before-execution AT: measure every candidate, persist, select.
cost = WallClockCost(build=lambda p: (lambda f=jax.jit(region.instantiate(p)): f(x)))
result = Tuner(TuningDB("/tmp/quickstart_db.json")).tune(
    region, BasicParams.make(arch="demo", shape=x.shape), cost
)
print(f"best point: {result.best.point}  ({result.best.cost * 1e6:.1f} us)")

# 4. The region now dispatches the tuned candidate.
out = region(x)
assert jnp.allclose(out, nest.reference(x), rtol=1e-4, atol=1e-6)
print("tuned region output verified against oracle ✓")
