"""Mixture-of-Experts block with sort-based, capacity-bounded dispatch.

Design constraints:

* **FLOPs honesty** — the roofline analysis reads HLO FLOPs, so dispatch must
  not inflate compute.  One-hot dispatch einsums cost O(T·E·C·d) — more FLOPs
  than the experts themselves — so we dispatch by *sorting* token→expert
  assignments (gathers/scatters are memory ops) into a dense ``(E, C, d)``
  buffer and run experts as grouped matmuls with exactly
  ``2·T·top_k·d·ff·3`` useful FLOPs (+ capacity slack).
* **EP shardability** — the ``(E, C, d)`` buffer carries the ``act_experts``
  logical axis; under the `tp`/EP rules the scatter/gather around it become
  the all-to-all traffic the roofline's collective term sees.
* Capacity overflow drops tokens (standard Switch behaviour); the residual
  path carries them unchanged.  Tests check the no-drop regime exactly
  against a dense per-token oracle.

Covers both assigned MoE archs: llama4-scout (16e top-1) and granite-moe
(32e top-8).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

from .config import ModelConfig
from .spec import ParamSpec


def moe_spec(cfg: ModelConfig, layers: Optional[int] = None) -> Dict[str, ParamSpec]:
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    L = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "router": ParamSpec(L + (d, E), la + ("embed", "experts"), init_scale=0.02),
        "w_gate": ParamSpec(L + (E, d, ff), la + ("experts", "embed", "ffn")),
        "w_up": ParamSpec(L + (E, d, ff), la + ("experts", "embed", "ffn")),
        "w_down": ParamSpec(L + (E, ff, d), la + ("experts", "ffn", "embed")),
    }


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 (TPU sublane alignment)


def _dispatch_one_group(
    xf: jnp.ndarray,  # (Tg, d) — one group's tokens
    router: jnp.ndarray,  # (d, E)
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,
    cfg: ModelConfig,
    C: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based dispatch + expert SwiGLU + combine for one token group;
    vmapped over groups by :func:`moe_block`."""
    T, d = xf.shape
    E, k = cfg.n_experts, cfg.top_k

    logits = jnp.einsum("td,de->te", xf, router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(logits, k)  # (Tg, k)
    gates = jax.nn.softmax(gate_vals, axis=-1)

    # Load-balancing auxiliary loss (Switch Transformer eq. 4), per group.
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(sel[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef

    N = T * k
    flat_e = sel.reshape(N)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = jnp.take(flat_e, sort_idx)
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(N) - jnp.take(starts, sorted_e)
    keep = pos_in_e < C
    buf_slot = jnp.where(keep, sorted_e * C + pos_in_e, N + E * C)  # OOB drop
    tok_of_sorted = sort_idx // k

    x_sorted = jnp.take(xf, tok_of_sorted, axis=0)  # (N, d) local gather
    buf = jnp.zeros((E * C, d), xf.dtype)
    buf = buf.at[buf_slot].set(x_sorted, mode="drop").reshape(E, C, d)

    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)
    y = y.reshape(E * C, d)

    y_sorted = jnp.take(y, jnp.clip(buf_slot, 0, E * C - 1), axis=0)
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    y_assign = jnp.zeros((N, d), xf.dtype).at[sort_idx].set(y_sorted)
    y_assign = y_assign.reshape(T, k, d)
    out = jnp.sum(gates[..., None].astype(xf.dtype) * y_assign, axis=1)
    return out, aux


def moe_block(
    x: jnp.ndarray,  # (B, S, d)
    p: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), aux_loss scalar fp32).

    ``cfg.moe_groups`` (G) splits tokens into independently-dispatched groups
    with per-group capacity (GShard semantics); at scale G = the data degree
    so group boundaries coincide with shards.  The group loop is vmapped and
    only the group axis is sharding-constrained — §Perf cell 1 measured three
    lowerings of the same math:

    * G=1 global dispatch:        X = 266 s (gathers replicate across data)
    * vmap + group constraint:    X = 20.8 s    <-- this implementation
    * explicit batched scatter +
      full internal constraints:  X = 187 s (2-D-sharded scatter replicates)
    """
    B, S, d = x.shape
    T = B * S
    G = max(1, cfg.moe_groups)
    if T % G:
        raise ValueError(f"tokens {T} must divide moe_groups {G}")
    Tg = T // G
    C = capacity(Tg, cfg)
    xg = constrain(x.reshape(G, Tg, d), ("moe_capacity", None, "act_embed"))

    out, aux = jax.vmap(
        lambda one: _dispatch_one_group(
            one, p["router"], p["w_gate"], p["w_up"], p["w_down"], cfg, C
        )
    )(xg)
    out = constrain(out, ("moe_capacity", None, "act_embed"))
    return out.reshape(B, S, d), jnp.mean(aux)


def moe_block_dense_oracle(
    x: jnp.ndarray, p: Dict[str, jnp.ndarray], cfg: ModelConfig
) -> jnp.ndarray:
    """O(T·E·d·ff) dense oracle: every expert on every token, combined by the
    same top-k gates.  Used by tests in the no-drop regime."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    gate_vals, sel = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    g = jnp.einsum("td,edf->tef", xf, p["w_gate"])
    u = jnp.einsum("td,edf->tef", xf, p["w_up"])
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, p["w_down"])
    mask = jax.nn.one_hot(sel, E, dtype=jnp.float32)  # (T, k, E)
    comb = jnp.einsum("tke,tk->te", mask, gates)
    out = jnp.einsum("te,ted->td", comb.astype(x.dtype), y_all)
    return out.reshape(B, S, d)
