"""Decoder-only LM assembly for dense / MoE / SSM / hybrid / VLM families.

Structure decisions that matter at scale:

* **scan-over-layers** — homogeneous layers are stacked on a leading
  ``layers`` axis and driven by ``lax.scan``; HLO size is O(1) in depth, so
  the 126-layer llama3-405b compiles in seconds on the dry-run host.  The
  hybrid family scans over period-groups of its block pattern and unrolls
  the remainder.
* **remat as a PP** — ``cfg.remat ∈ {none, full, dots}`` wraps the scan body
  in ``jax.checkpoint``; the tuner can trade the memory term against the
  compute term and the HLO-FLOPs ratio in §Roofline makes the recompute
  visible.
* Three entry points per family: full-sequence ``forward`` (training),
  ``prefill`` (returns a KV/state cache), ``decode_step`` (one token).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import constrain

from .attention import (
    attn_spec,
    blocked_causal_attention,
    decode_attention,
    flash_attention_xla,
    full_attention,
    local_window_attention,
    output_proj,
    project_qkv,
)
from .config import ModelConfig
from .layers import (
    embed,
    embed_spec,
    gelu_mlp,
    gelu_mlp_spec,
    rmsnorm,
    rmsnorm_spec,
    swiglu,
    swiglu_spec,
    unembed,
    unembed_spec,
)
from .moe import moe_block, moe_spec
from .rglru import rglru_block, rglru_decode_step, rglru_init_cache, rglru_spec
from .spec import ParamSpec
from .ssm import ssm_block, ssm_decode_step, ssm_init_cache, ssm_spec


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def decoder_specs(cfg: ModelConfig) -> Dict[str, Any]:
    L = cfg.n_layers
    specs: Dict[str, Any] = {
        "embed": embed_spec(cfg),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = unembed_spec(cfg)

    if cfg.family in ("dense", "vlm"):
        specs["layers"] = {
            "ln1": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="ones"),
            "attn": attn_spec(cfg, layers=L),
            "ln2": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="ones"),
            "mlp": swiglu_spec(cfg.d_model, cfg.d_ff, layers=L),
        }
    elif cfg.family == "moe":
        specs["layers"] = {
            "ln1": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="ones"),
            "attn": attn_spec(cfg, layers=L),
            "ln2": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="ones"),
            "moe": moe_spec(cfg, layers=L),
        }
    elif cfg.family == "ssm":
        specs["layers"] = {
            "ln": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="ones"),
            "ssm": ssm_spec(cfg, layers=L),
        }
    elif cfg.family == "hybrid":
        period = len(cfg.block_pattern)
        n_groups, n_tail = divmod(L, period)
        group: Dict[str, Any] = {}
        for idx, kind in enumerate(cfg.block_pattern):
            group[f"b{idx}_{kind}"] = _hybrid_block_spec(cfg, kind, layers=n_groups)
        specs["groups"] = group
        if n_tail:
            tail_kinds = cfg.block_pattern[:n_tail]
            if len(set(tail_kinds)) == 1:  # homogeneous tail -> small scan
                specs["tail"] = {
                    f"t_{tail_kinds[0]}": _hybrid_block_spec(
                        cfg, tail_kinds[0], layers=n_tail
                    )
                }
            else:  # unroll
                specs["tail"] = {
                    f"t{idx}_{kind}": _hybrid_block_spec(cfg, kind, layers=None)
                    for idx, kind in enumerate(tail_kinds)
                }
    else:
        raise ValueError(f"decoder_specs: unsupported family {cfg.family}")
    return specs


def _hybrid_block_spec(
    cfg: ModelConfig, kind: str, layers: Optional[int]
) -> Dict[str, Any]:
    L = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    base = {
        "ln1": ParamSpec(L + (cfg.d_model,), la + ("embed",), init="ones"),
        "ln2": ParamSpec(L + (cfg.d_model,), la + ("embed",), init="ones"),
        "mlp": swiglu_spec(cfg.d_model, cfg.d_ff, layers=layers),
    }
    if kind == "rec":
        base["rec"] = rglru_spec(cfg, layers=layers)
    elif kind == "attn":
        base["attn"] = attn_spec(cfg, layers=layers)
    else:
        raise ValueError(f"unknown hybrid block kind {kind!r}")
    return base


# ---------------------------------------------------------------------------
# Layer applications (single layer, unstacked params)
# ---------------------------------------------------------------------------


def _maybe_checkpoint(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {cfg.remat!r}")


def _attention_mix(
    x: jnp.ndarray,
    p: Dict[str, Any],
    cfg: ModelConfig,
    positions: Optional[jnp.ndarray],
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Pre-norm attention with residual.  Returns (x, (k, v)) for caching."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = project_qkv(h, p["attn"], cfg, positions)
    S = x.shape[1]
    if window is not None:
        if S % min(cfg.attn_block_q, S) == 0 and S > window:
            o = local_window_attention(q, k, v, window, cfg.attn_block_q)
        else:
            o = full_attention(q, k, v, causal=True)  # small-seq fallback
    elif S > 2048 and S % min(cfg.attn_block_q, S) == 0 and S % min(
        cfg.attn_block_kv, S
    ) == 0:
        o = flash_attention_xla(q, k, v, cfg.attn_block_q, cfg.attn_block_kv)
    else:
        o = full_attention(q, k, v, causal=True)
    x = x + output_proj(o, p["attn"])
    return x, (k, v)


def _dense_layer(x, p, cfg: ModelConfig, positions):
    x, kv = _attention_mix(x, p, cfg, positions)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu(h, p["mlp"])
    x = constrain(x, ("batch", "seq", "act_embed"))
    return x, kv, jnp.float32(0.0)


def _moe_layer(x, p, cfg: ModelConfig, positions):
    x, kv = _attention_mix(x, p, cfg, positions)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    delta, aux = moe_block(h, p["moe"], cfg)
    x = x + delta
    x = constrain(x, ("batch", "seq", "act_embed"))
    return x, kv, aux


def _ssm_layer(x, p, cfg: ModelConfig, positions):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    x = x + ssm_block(h, p["ssm"], cfg)
    x = constrain(x, ("batch", "seq", "act_embed"))
    return x, None, jnp.float32(0.0)


def _hybrid_layer(x, p, cfg: ModelConfig, positions, kind: str):
    if kind == "rec":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + rglru_block(h, p["rec"], cfg)
        kv = None
    else:
        x, kv = _attention_mix(x, p, cfg, positions, window=cfg.local_window)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu(h, p["mlp"])
    x = constrain(x, ("batch", "seq", "act_embed"))
    return x, kv, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Full-sequence forward (training) — logits over all positions
# ---------------------------------------------------------------------------


def forward(
    params: Dict[str, Any],
    tokens: jnp.ndarray,  # (B, S) int32
    cfg: ModelConfig,
    positions: Optional[jnp.ndarray] = None,
    vision_embeds: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B,S,V) fp32, aux_loss scalar)."""
    x, positions = _embed_inputs(params, tokens, cfg, positions, vision_embeds)
    x, aux = _apply_trunk(params, x, cfg, positions)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x, cfg)
    return logits, aux


def _embed_inputs(params, tokens, cfg, positions, vision_embeds):
    x = embed(tokens, params["embed"])
    if cfg.family == "vlm" and vision_embeds is not None:
        V = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, V:]], axis=1)
    if positions is None:
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        positions = jnp.broadcast_to(pos, (3, B, S)) if cfg.mrope else pos
    x = constrain(x, ("batch", "seq", "act_embed"))
    return x, positions


def _logits(params, x, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, w)
    return constrain(logits, ("batch", "seq", "act_vocab"))


def _apply_trunk(params, x, cfg: ModelConfig, positions):
    """Scan the layer stack in full-sequence mode."""
    layer_fn = {
        "dense": _dense_layer,
        "vlm": _dense_layer,
        "moe": _moe_layer,
        "ssm": _ssm_layer,
    }.get(cfg.family)

    if layer_fn is not None:
        def body(carry, lp):
            h, aux = carry
            h, _, a = layer_fn(h, lp, cfg, positions)
            return (h, aux + a), None

        body = _maybe_checkpoint(body, cfg)
        if cfg.scan_layers:
            (x, aux), _ = lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
        else:
            aux = jnp.float32(0.0)
            L = cfg.n_layers
            for i in range(L):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                (x, aux), _ = body((x, aux), lp)
        return x, aux

    if cfg.family == "hybrid":
        pattern = cfg.block_pattern

        def group_body(carry, gp):
            h, aux = carry
            for idx, kind in enumerate(pattern):
                h, _, a = _hybrid_layer(h, gp[f"b{idx}_{kind}"], cfg, positions, kind)
                aux = aux + a
            return (h, aux), None

        group_body = _maybe_checkpoint(group_body, cfg)
        (x, aux), _ = lax.scan(group_body, (x, jnp.float32(0.0)), params["groups"])
        x, aux = _apply_hybrid_tail(params, x, aux, cfg, positions)
        return x, aux

    raise ValueError(f"forward: unsupported family {cfg.family}")


def _apply_hybrid_tail(params, x, aux, cfg, positions):
    if "tail" not in params:
        return x, aux
    for key, tp in params["tail"].items():
        kind = key.split("_", 1)[1]
        if key.startswith("t_"):  # stacked homogeneous tail
            def tail_body(carry, lp, _kind=kind):
                h, a0 = carry
                h, _, a = _hybrid_layer(h, lp, cfg, positions, _kind)
                return (h, a0 + a), None

            (x, aux), _ = lax.scan(
                _maybe_checkpoint(tail_body, cfg), (x, aux), tp
            )
        else:  # unrolled single layer
            x, _, a = _hybrid_layer(x, tp, cfg, positions, kind)
            aux = aux + a
    return x, aux


# ---------------------------------------------------------------------------
# Prefill — full-sequence forward that also builds the decode cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> Dict[str, Any]:
    """Zeroed decode cache.  ``capacity`` counts KV slots for attention
    families (ring-buffer of ``local_window`` for hybrid attention blocks);
    SSM/RG-LRU states are O(1)."""
    L = cfg.n_layers
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    if cfg.family in ("dense", "vlm", "moe"):
        return {
            "k": jnp.zeros((L, batch, capacity, kv, hd), jnp.bfloat16),
            "v": jnp.zeros((L, batch, capacity, kv, hd), jnp.bfloat16),
            "len": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "ssm":
        base = ssm_init_cache(cfg, batch)
        return {
            "conv": jnp.zeros((L,) + base["conv"].shape, base["conv"].dtype),
            "h": jnp.zeros((L,) + base["h"].shape, base["h"].dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        period = len(cfg.block_pattern)
        n_groups, n_tail = divmod(L, period)
        W = min(cfg.local_window, capacity)
        rec = rglru_init_cache(cfg, batch)
        cache: Dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
        for idx, kind in enumerate(cfg.block_pattern):
            if kind == "rec":
                cache[f"b{idx}_conv"] = jnp.zeros(
                    (n_groups,) + rec["conv"].shape, rec["conv"].dtype
                )
                cache[f"b{idx}_h"] = jnp.zeros(
                    (n_groups,) + rec["h"].shape, rec["h"].dtype
                )
            else:
                cache[f"b{idx}_k"] = jnp.zeros(
                    (n_groups, batch, W, kv, hd), jnp.bfloat16
                )
                cache[f"b{idx}_v"] = jnp.zeros(
                    (n_groups, batch, W, kv, hd), jnp.bfloat16
                )
        for t in range(n_tail):
            kind = cfg.block_pattern[t]
            if kind == "rec":
                cache[f"t{t}_conv"] = jnp.zeros_like(rec["conv"])
                cache[f"t{t}_h"] = jnp.zeros_like(rec["h"])
            else:
                cache[f"t{t}_k"] = jnp.zeros((batch, W, kv, hd), jnp.bfloat16)
                cache[f"t{t}_v"] = jnp.zeros((batch, W, kv, hd), jnp.bfloat16)
        return cache
    raise ValueError(f"init_cache: unsupported family {cfg.family}")


def prefill(
    params: Dict[str, Any],
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    positions: Optional[jnp.ndarray] = None,
    vision_embeds: Optional[jnp.ndarray] = None,
    capacity: Optional[int] = None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Returns (last-token logits (B, V), populated cache with len=S)."""
    B, S = tokens.shape
    cap = capacity or S
    x, positions = _embed_inputs(params, tokens, cfg, positions, vision_embeds)

    if cfg.family in ("dense", "vlm", "moe"):
        layer_fn = _moe_layer if cfg.family == "moe" else _dense_layer

        def body(carry, lp):
            h, aux = carry
            h, (k, v), a = layer_fn(h, lp, cfg, positions)
            return (h, aux + a), (_pad_cap(k, cap), _pad_cap(v, cap))

        (x, _), (ks, vs) = lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
        cache = {
            "k": ks.astype(jnp.bfloat16),
            "v": vs.astype(jnp.bfloat16),
            "len": jnp.asarray(S, jnp.int32),
        }
    elif cfg.family == "ssm":
        # Run the full-sequence path for logits, then rebuild final state by
        # replaying the last d_conv window + final h via a stateful pass.
        # Cheap honest alternative: scan returning final (conv, h) per layer.
        def body(carry, lp):
            h_x, _ = carry
            hh = rmsnorm(h_x, lp["ln"], cfg.norm_eps)
            y, final = _ssm_block_with_state(hh, lp["ssm"], cfg)
            return (h_x + y, jnp.float32(0.0)), final

        (x, _), finals = lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
        cache = {
            "conv": finals["conv"],
            "h": finals["h"],
            "len": jnp.asarray(S, jnp.int32),
        }
    elif cfg.family == "hybrid":
        cache = init_cache(cfg, B, cap)
        x, cache = _hybrid_prefill(params, x, cfg, positions, cache, S)
        cache["len"] = jnp.asarray(S, jnp.int32)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
    return logits, cache


def _conv_tail(xs_raw: jnp.ndarray, K: int) -> jnp.ndarray:
    """Last K-1 pre-conv inputs as the decode conv state, zero-left-padded
    when the prompt is shorter than K-1 (the causal conv's implicit zeros);
    without the pad a short prefill hands decode a truncated window."""
    tail = xs_raw[:, max(0, xs_raw.shape[1] - (K - 1)):, :]
    short = (K - 1) - tail.shape[1]
    if short > 0:
        tail = jnp.pad(tail, ((0, 0), (short, 0), (0, 0)))
    return tail.astype(jnp.bfloat16)


def _pad_cap(k: jnp.ndarray, cap: int) -> jnp.ndarray:
    S = k.shape[1]
    if S == cap:
        return k
    if S > cap:
        return k[:, S - cap :]
    return jnp.pad(k, ((0, 0), (0, cap - S), (0, 0), (0, 0)))


def _ssm_block_with_state(x, p, cfg):
    """ssm_block that also returns the final (conv window, h) state."""
    from .ssm import _causal_conv1d

    B, S, _ = x.shape
    di, N, R, K = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_, cfg.d_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs_raw, z = jnp.split(xz, 2, axis=-1)
    xs = jax.nn.silu(_causal_conv1d(xs_raw, p["conv_w"], p["conv_b"]))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    raw_all = jnp.einsum("bsd,dr->bsr", xs, p["x_proj"])

    def step(h, inputs):
        x_t, raw = inputs
        dt = jax.nn.softplus(
            jnp.einsum("br,rd->bd", raw[:, :R], p["dt_w"]).astype(jnp.float32)
            + p["dt_b"].astype(jnp.float32)
        )
        B_t = raw[:, R : R + N].astype(jnp.float32)
        C_t = raw[:, R + N :].astype(jnp.float32)
        decay = jnp.exp(dt[..., None] * A)
        h = decay * h + (dt * x_t.astype(jnp.float32))[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y.astype(x.dtype)

    h0 = jnp.zeros((B, di, N), jnp.float32)
    h_final, ys = lax.scan(
        step, h0, (xs.transpose(1, 0, 2), raw_all.transpose(1, 0, 2))
    )
    y = ys.transpose(1, 0, 2) + xs * p["D"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    conv_state = _conv_tail(xs_raw, K)
    return out, {"conv": conv_state, "h": h_final}


def _hybrid_prefill(params, x, cfg, positions, cache, S):
    period = len(cfg.block_pattern)
    W = cache[f"b{_first_attn_idx(cfg)}_k"].shape[2] if _first_attn_idx(cfg) is not None else cfg.local_window

    def group_body(carry, gp):
        h = carry
        outs = {}
        for idx, kind in enumerate(cfg.block_pattern):
            lp = gp[f"b{idx}_{kind}"]
            if kind == "rec":
                hh = rmsnorm(h, lp["ln1"], cfg.norm_eps)
                y, final = _rglru_block_with_state(hh, lp["rec"], cfg)
                h = h + y
                outs[f"b{idx}_conv"] = final["conv"]
                outs[f"b{idx}_h"] = final["h"]
            else:
                h, (k, v) = _attention_mix(h, lp, cfg, positions, window=cfg.local_window)
                outs[f"b{idx}_k"] = _pad_cap(k, W).astype(jnp.bfloat16)
                outs[f"b{idx}_v"] = _pad_cap(v, W).astype(jnp.bfloat16)
            hh = rmsnorm(h, lp["ln2"], cfg.norm_eps)
            h = h + swiglu(hh, lp["mlp"])
        return h, outs

    x, group_caches = lax.scan(group_body, x, params["groups"])
    for key, val in group_caches.items():
        cache[key] = val

    if "tail" in params:
        t = 0
        for key, tp in params["tail"].items():
            kind = key.split("_", 1)[1]
            if key.startswith("t_"):  # stacked homogeneous tail (rec only)
                def tail_body(carry, lp):
                    h = carry
                    hh = rmsnorm(h, lp["ln1"], cfg.norm_eps)
                    y, final = _rglru_block_with_state(hh, lp["rec"], cfg)
                    h = h + y
                    hh = rmsnorm(h, lp["ln2"], cfg.norm_eps)
                    h = h + swiglu(hh, lp["mlp"])
                    return h, final

                x, finals = lax.scan(tail_body, x, tp)
                n_tail = finals["h"].shape[0]
                for i in range(n_tail):
                    cache[f"t{i}_conv"] = finals["conv"][i]
                    cache[f"t{i}_h"] = finals["h"][i]
            else:
                raise NotImplementedError("heterogeneous hybrid tail")
            t += 1
    return x, cache


def _rglru_block_with_state(x, p, cfg):
    from .rglru import C_FACTOR, _rglru_gates
    from .ssm import _causal_conv1d

    B, S, _ = x.shape
    K = cfg.d_conv
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["in_gate"]).astype(jnp.float32)
    ).astype(x.dtype)
    xs_raw = jnp.einsum("bsd,dw->bsw", x, p["in_x"])
    xs = _causal_conv1d(xs_raw, p["conv_w"], p["conv_b"])
    softplus_neg_lam = jax.nn.softplus(-p["lam"].astype(jnp.float32))
    r, i = _rglru_gates(xs, p)

    def step(h, inputs):
        x_t, r_t, i_t = inputs
        a = jnp.exp(-C_FACTOR * r_t * softplus_neg_lam)
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
            i_t * x_t.astype(jnp.float32)
        )
        return h, h.astype(x_t.dtype)

    h0 = jnp.zeros((B, cfg.lru_width_), jnp.float32)
    h_final, hs = lax.scan(
        step, h0, (xs.transpose(1, 0, 2), r.transpose(1, 0, 2), i.transpose(1, 0, 2))
    )
    y = hs.transpose(1, 0, 2) * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["out"])
    conv_state = _conv_tail(xs_raw, K)
    return out, {"conv": conv_state, "h": h_final}


def _first_attn_idx(cfg: ModelConfig) -> Optional[int]:
    for idx, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            return idx
    return None


# ---------------------------------------------------------------------------
# Decode — one token through the stack with cache update
# ---------------------------------------------------------------------------


def decode_step(
    params: Dict[str, Any],
    tokens: jnp.ndarray,  # (B, 1)
    cache: Dict[str, Any],
    cfg: ModelConfig,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Returns (logits (B, V) fp32, updated cache)."""
    B = tokens.shape[0]
    pos_now = cache["len"]  # scalar int32 — position of the incoming token
    if positions is None:
        pos = jnp.broadcast_to(pos_now, (B, 1)).astype(jnp.int32)
        positions = jnp.broadcast_to(pos, (3, B, 1)) if cfg.mrope else pos
    x = embed(tokens, params["embed"])

    if cfg.family in ("dense", "vlm", "moe"):
        cap = cache["k"].shape[2]

        def body(h, inputs):
            lp, ck, cv = inputs
            hh = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = project_qkv(hh, lp["attn"], cfg, positions)
            ck = lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), pos_now, axis=1
            )
            cv = lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), pos_now, axis=1
            )
            o = decode_attention(q, ck, cv, pos_now + 1)
            h = h + output_proj(o, lp["attn"])
            hh = rmsnorm(h, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                delta, _ = moe_block(hh, lp["moe"], cfg)
            else:
                delta = swiglu(hh, lp["mlp"])
            return h + delta, (ck, cv)

        x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "len": pos_now + 1}
    elif cfg.family == "ssm":
        def body(h, inputs):
            lp, conv, hstate = inputs
            hh = rmsnorm(h, lp["ln"], cfg.norm_eps)
            y, nc = ssm_decode_step(hh, {"conv": conv, "h": hstate}, lp["ssm"], cfg)
            return h + y, (nc["conv"], nc["h"])

        x, (convs, hs) = lax.scan(body, x, (params["layers"], cache["conv"], cache["h"]))
        new_cache = {"conv": convs, "h": hs, "len": pos_now + 1}
    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(params, x, cache, cfg, positions, pos_now)
        new_cache["len"] = pos_now + 1
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x, cfg)[:, 0]
    return logits, new_cache


def _hybrid_decode(params, x, cache, cfg, positions, pos_now):
    period = len(cfg.block_pattern)
    new_cache: Dict[str, Any] = {}

    def one_layer(h, kind, lp, lcache):
        out_cache = {}
        if kind == "rec":
            hh = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            y, nc = rglru_decode_step(
                hh, {"conv": lcache["conv"], "h": lcache["h"]}, lp["rec"], cfg
            )
            h = h + y
            out_cache["conv"], out_cache["h"] = nc["conv"], nc["h"]
        else:
            hh = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = project_qkv(hh, lp["attn"], cfg, positions)
            W = lcache["k"].shape[1]
            slot = jnp.mod(pos_now, W)
            ck = lax.dynamic_update_slice_in_dim(
                lcache["k"], k.astype(lcache["k"].dtype), slot, axis=1
            )
            cv = lax.dynamic_update_slice_in_dim(
                lcache["v"], v.astype(lcache["v"].dtype), slot, axis=1
            )
            n_valid = jnp.minimum(pos_now + 1, W)
            o = decode_attention(q, ck, cv, n_valid)
            h = h + output_proj(o, lp["attn"])
            out_cache["k"], out_cache["v"] = ck, cv
        hh = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + swiglu(hh, lp["mlp"])
        return h, out_cache

    def group_body(h, inputs):
        gp = inputs["params"]
        outs = {}
        for idx, kind in enumerate(cfg.block_pattern):
            lp = gp[f"b{idx}_{kind}"]
            if kind == "rec":
                lc = {"conv": inputs[f"b{idx}_conv"], "h": inputs[f"b{idx}_h"]}
            else:
                lc = {"k": inputs[f"b{idx}_k"], "v": inputs[f"b{idx}_v"]}
            h, oc = one_layer(h, kind, lp, lc)
            for kk, vv in oc.items():
                outs[f"b{idx}_{kk}"] = vv
        return h, outs

    xs_tree = {"params": params["groups"]}
    for key in cache:
        if key.startswith("b"):
            xs_tree[key] = cache[key]
    x, group_out = lax.scan(group_body, x, xs_tree)
    new_cache.update(group_out)

    if "tail" in params:
        for key, tp in params["tail"].items():
            if key.startswith("t_"):  # stacked rec tail
                def tail_body(h, inputs):
                    lp, conv, hstate = inputs
                    hh = rmsnorm(h, lp["ln1"], cfg.norm_eps)
                    y, nc = rglru_decode_step(
                        hh, {"conv": conv, "h": hstate}, lp["rec"], cfg
                    )
                    h = h + y
                    hh = rmsnorm(h, lp["ln2"], cfg.norm_eps)
                    h = h + swiglu(hh, lp["mlp"])
                    return h, (nc["conv"], nc["h"])

                n_tail = jax.tree.leaves(tp)[0].shape[0]
                convs = jnp.stack([cache[f"t{i}_conv"] for i in range(n_tail)])
                hs = jnp.stack([cache[f"t{i}_h"] for i in range(n_tail)])
                x, (nconvs, nhs) = lax.scan(tail_body, x, (tp, convs, hs))
                for i in range(n_tail):
                    new_cache[f"t{i}_conv"] = nconvs[i]
                    new_cache[f"t{i}_h"] = nhs[i]
            else:
                raise NotImplementedError("heterogeneous hybrid tail")
    return x, new_cache
