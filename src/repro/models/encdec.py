"""Whisper-style encoder–decoder (whisper-large-v3 backbone).

The conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed log-mel frame embeddings (B, encoder_len, d_model) directly; the
encoder is the 32-layer bidirectional transformer over those frames with a
learned positional table.  The decoder is a causal transformer with
cross-attention; decoder positions are sinusoidal (deviation from Whisper's
learned table so that parameter shapes stay independent of the assigned
sequence lengths — recorded in docs/design.md §7).  Embeddings are tied (as Whisper).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import constrain

from .attention import (
    attn_spec,
    blocked_causal_attention,
    cross_attention,
    flash_attention_xla,
    decode_attention,
    full_attention,
    output_proj,
    project_qkv,
)
from .config import ModelConfig
from .layers import embed, embed_spec, gelu_mlp, gelu_mlp_spec, layernorm, unembed
from .spec import ParamSpec


def _ln_spec(d: int, layers: Optional[int] = None) -> Dict[str, ParamSpec]:
    L = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "scale": ParamSpec(L + (d,), la + ("embed",), init="ones"),
        "bias": ParamSpec(L + (d,), la + ("embed",), init="zeros"),
    }


def encdec_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    Le, Ld = cfg.n_encoder_layers, cfg.n_layers
    return {
        "embed": embed_spec(cfg),
        "enc_pos": ParamSpec((cfg.encoder_len, d), ("frames", "embed"), init_scale=0.02),
        "enc_layers": {
            "ln1": _ln_spec(d, Le),
            "attn": attn_spec(cfg, layers=Le),
            "ln2": _ln_spec(d, Le),
            "mlp": gelu_mlp_spec(d, cfg.d_ff, layers=Le),
        },
        "enc_final_ln": _ln_spec(d),
        "dec_layers": {
            "ln1": _ln_spec(d, Ld),
            "self_attn": attn_spec(cfg, layers=Ld),
            "lnx": _ln_spec(d, Ld),
            "cross_attn": attn_spec(cfg, layers=Ld, cross=True),
            "ln2": _ln_spec(d, Ld),
            "mlp": gelu_mlp_spec(d, cfg.d_ff, layers=Ld),
        },
        "dec_final_ln": _ln_spec(d),
    }


def _sinusoid(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(1, half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(params: Dict[str, Any], frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, enc_len, d) stubbed embeddings -> encoder states."""
    x = frames.astype(jnp.bfloat16) + params["enc_pos"].astype(jnp.bfloat16)
    x = constrain(x, ("batch", "seq", "act_embed"))

    def body(h, lp):
        hh = layernorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = project_qkv(hh, lp["attn"], cfg, positions=None)  # no RoPE
        o = full_attention(q, k, v, causal=False)
        h = h + output_proj(o, lp["attn"])
        hh = layernorm(h, lp["ln2"], cfg.norm_eps)
        h = h + gelu_mlp(hh, lp["mlp"])
        h = constrain(h, ("batch", "seq", "act_embed"))
        return h, None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = lax.scan(body, x, params["enc_layers"])
    return layernorm(x, params["enc_final_ln"], cfg.norm_eps)


def _cross_kv(enc_out: jnp.ndarray, lp_cross: Dict[str, jnp.ndarray]):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, lp_cross["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, lp_cross["wv"])
    return k, v


# ---------------------------------------------------------------------------
# Decoder (train / prefill / decode)
# ---------------------------------------------------------------------------


def _decoder_layer(h, lp, cfg, positions, enc_out, self_attn_fn):
    hh = layernorm(h, lp["ln1"], cfg.norm_eps)
    q, k, v = project_qkv(hh, lp["self_attn"], cfg, positions=None)
    o, kv_out = self_attn_fn(q, k, v)
    h = h + output_proj(o, lp["self_attn"])
    hh = layernorm(h, lp["lnx"], cfg.norm_eps)
    qx = jnp.einsum("bsd,dhk->bshk", hh, lp["cross_attn"]["wq"])
    kx, vx = _cross_kv(enc_out, lp["cross_attn"])
    ox = cross_attention(qx, kx, vx)
    h = h + output_proj(ox, lp["cross_attn"])
    hh = layernorm(h, lp["ln2"], cfg.norm_eps)
    h = h + gelu_mlp(hh, lp["mlp"])
    h = constrain(h, ("batch", "seq", "act_embed"))
    return h, kv_out


def forward(
    params: Dict[str, Any],
    frames: jnp.ndarray,  # (B, enc_len, d)
    tokens: jnp.ndarray,  # (B, S)
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training forward: (logits (B,S,V) fp32, aux=0)."""
    enc_out = encode(params, frames, cfg)
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = embed(tokens, params["embed"]) + _sinusoid(pos, cfg.d_model).astype(jnp.bfloat16)

    def self_attn(q, k, v):
        Sq = q.shape[1]
        if Sq > 2048 and Sq % min(cfg.attn_block_q, Sq) == 0 and Sq % min(
            cfg.attn_block_kv, Sq
        ) == 0:
            return flash_attention_xla(q, k, v, cfg.attn_block_q, cfg.attn_block_kv), None
        return full_attention(q, k, v, causal=True), None

    def body(h, lp):
        h, _ = _decoder_layer(h, lp, cfg, pos, enc_out, self_attn)
        return h, None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = lax.scan(body, x, params["dec_layers"])
    x = layernorm(x, params["dec_final_ln"], cfg.norm_eps)
    logits = unembed(x, params["embed"].T)
    return logits, jnp.float32(0.0)


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> Dict[str, Any]:
    L, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
    return {
        "self_k": jnp.zeros((L, batch, capacity, kv, hd), jnp.bfloat16),
        "self_v": jnp.zeros((L, batch, capacity, kv, hd), jnp.bfloat16),
        "cross_k": jnp.zeros((L, batch, cfg.encoder_len, kv, hd), jnp.bfloat16),
        "cross_v": jnp.zeros((L, batch, cfg.encoder_len, kv, hd), jnp.bfloat16),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(
    params: Dict[str, Any],
    frames: jnp.ndarray,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    capacity: Optional[int] = None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    enc_out = encode(params, frames, cfg)
    B, S = tokens.shape
    cap = capacity or S
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = embed(tokens, params["embed"]) + _sinusoid(pos, cfg.d_model).astype(jnp.bfloat16)

    def self_attn(q, k, v):
        Sq = q.shape[1]
        if Sq > 2048 and Sq % min(cfg.attn_block_q, Sq) == 0 and Sq % min(
            cfg.attn_block_kv, Sq
        ) == 0:
            o = flash_attention_xla(q, k, v, cfg.attn_block_q, cfg.attn_block_kv)
        else:
            o = full_attention(q, k, v, causal=True)
        return o, (k, v)

    def body(h, lp):
        h, kv_out = _decoder_layer(h, lp, cfg, pos, enc_out, self_attn)
        k, v = kv_out
        kx, vx = _cross_kv(enc_out, lp["cross_attn"])
        return h, {
            "self_k": _pad(k, cap),
            "self_v": _pad(v, cap),
            "cross_k": kx.astype(jnp.bfloat16),
            "cross_v": vx.astype(jnp.bfloat16),
        }

    x, caches = lax.scan(body, x, params["dec_layers"])
    x = layernorm(x, params["dec_final_ln"], cfg.norm_eps)
    logits = unembed(x[:, -1:, :], params["embed"].T)[:, 0]
    caches["len"] = jnp.asarray(S, jnp.int32)
    return logits, caches


def _pad(k: jnp.ndarray, cap: int) -> jnp.ndarray:
    S = k.shape[1]
    if S == cap:
        return k.astype(jnp.bfloat16)
    if S > cap:
        return k[:, S - cap :].astype(jnp.bfloat16)
    return jnp.pad(k, ((0, 0), (0, cap - S), (0, 0), (0, 0))).astype(jnp.bfloat16)


def decode_step(
    params: Dict[str, Any],
    tokens: jnp.ndarray,  # (B, 1)
    cache: Dict[str, Any],
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    B = tokens.shape[0]
    pos_now = cache["len"]
    pos = jnp.broadcast_to(pos_now, (B, 1)).astype(jnp.int32)
    x = embed(tokens, params["embed"]) + _sinusoid(pos, cfg.d_model).astype(jnp.bfloat16)

    def body(h, inputs):
        lp, sk, sv, ck, cv = inputs
        hh = layernorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = project_qkv(hh, lp["self_attn"], cfg, positions=None)
        sk = lax.dynamic_update_slice_in_dim(sk, k.astype(sk.dtype), pos_now, axis=1)
        sv = lax.dynamic_update_slice_in_dim(sv, v.astype(sv.dtype), pos_now, axis=1)
        o = decode_attention(q, sk, sv, pos_now + 1)
        h = h + output_proj(o, lp["self_attn"])
        hh = layernorm(h, lp["lnx"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", hh, lp["cross_attn"]["wq"])
        ox = decode_attention(qx, ck, cv, jnp.asarray(cfg.encoder_len, jnp.int32))
        h = h + output_proj(ox, lp["cross_attn"])
        hh = layernorm(h, lp["ln2"], cfg.norm_eps)
        h = h + gelu_mlp(hh, lp["mlp"])
        return h, (sk, sv)

    x, (sks, svs) = lax.scan(
        body,
        x,
        (params["dec_layers"], cache["self_k"], cache["self_v"], cache["cross_k"], cache["cross_v"]),
    )
    x = layernorm(x, params["dec_final_ln"], cfg.norm_eps)
    logits = unembed(x, params["embed"].T)[:, 0]
    new_cache = {
        "self_k": sks,
        "self_v": svs,
        "cross_k": cache["cross_k"],
        "cross_v": cache["cross_v"],
        "len": pos_now + 1,
    }
    return logits, new_cache
