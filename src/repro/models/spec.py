"""Parameter-spec plumbing: shapes + logical axis names, no allocation.

Every model in the zoo describes its parameters as a pytree of
:class:`ParamSpec` — shape, dtype, and **logical axis names**.  Three
consumers:

* smoke tests materialize real arrays (:func:`init_params`),
* the dry-run converts specs to ``jax.ShapeDtypeStruct`` + shardings
  (:func:`as_shape_dtype_structs`) so a 405B model "exists" without a byte
  allocated,
* the sharding layer maps logical names to mesh axes
  (:mod:`repro.distributed.sharding`) — the mapping itself is a tunable PP.

Logical axis vocabulary (shared across all 10 architectures):
    ``layers``    stacked scan-over-layers axis (never sharded)
    ``vocab``     vocabulary
    ``embed``     d_model
    ``q_heads``   query heads
    ``kv_heads``  KV heads (GQA)
    ``head_dim``  per-head dim
    ``ffn``       MLP hidden
    ``experts``   MoE expert axis
    ``rnn``       recurrent width (RG-LRU / Mamba d_inner)
    ``state``     SSM state dim
    ``conv``      conv kernel taps
    ``frames``    audio/vision frontend positions
    ``None``      never sharded
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # "normal" | "zeros" | "ones" | "rglru_lambda"
    init_scale: Optional[float] = None  # overrides fan-in scaling

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.logical_axes):
            raise ValueError(
                f"shape {self.shape} vs logical_axes {self.logical_axes} length mismatch"
            )

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def is_spec_leaf(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree: Any) -> Any:
    return jax.tree.map(fn, tree, is_leaf=is_spec_leaf)


def count_params(tree: Any, exclude: Sequence[str] = ()) -> int:
    total = 0
    for spec in jax.tree.leaves(tree, is_leaf=is_spec_leaf):
        if isinstance(spec, ParamSpec):
            total += spec.size
    return total


def as_shape_dtype_structs(tree: Any) -> Any:
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def init_params(key: jax.Array, tree: Any) -> Any:
    """Materialize concrete parameters (smoke tests / examples only)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec_leaf)
    keys = jax.random.split(key, max(1, len(leaves)))
    out = []
    for spec, k in zip(leaves, keys):
        out.append(_init_leaf(k, spec))
    return jax.tree.unflatten(treedef, out)


def _init_leaf(key: jax.Array, spec: ParamSpec) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "rglru_lambda":
        # RG-LRU Λ init: a = sigmoid(Λ) uniform in [0.9, 0.999] (Griffin §2.4)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        return jnp.log(u / (1.0 - u)).astype(spec.dtype)
    # fan-in scaled normal; fan-in = second-to-last dim for matrices
    if spec.init_scale is not None:
        scale = spec.init_scale
    elif len(spec.shape) >= 2:
        fan_in = spec.shape[-2]
        scale = 1.0 / math.sqrt(max(1, fan_in))
    else:
        scale = 0.02
    x = jax.random.normal(key, spec.shape, jnp.float32) * scale
    return x.astype(spec.dtype)
