"""GQA attention: full, blocked-causal (flash-style), local-window, decode.

The blocked-causal path is the pure-XLA flash algorithm (online softmax over
KV blocks under a double ``lax.scan``) and doubles as the reference semantics
for the Pallas kernel in :mod:`repro.kernels.flash_attention`.  Block sizes
``attn_block_q`` / ``attn_block_kv`` are performance parameters surfaced to
the tuner.

Note on causal waste: the baseline blocked path computes *all* (q, kv) block
pairs and masks the upper triangle, costing ~2× the useful attention FLOPs.
``skip_noncausal_blocks=True`` enumerates only the ~n²/2 visible block pairs
(a §Perf hillclimb item; see EXPERIMENTS.md).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import mrope_apply, rmsnorm, rope_apply
from .spec import ParamSpec

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attn_spec(
    cfg: ModelConfig, layers: Optional[int] = None, cross: bool = False
) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    L = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    spec: Dict[str, ParamSpec] = {
        "wq": ParamSpec(L + (d, h, hd), la + ("embed", "q_heads", "head_dim")),
        "wk": ParamSpec(L + (d, kv, hd), la + ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec(L + (d, kv, hd), la + ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec(L + (h, hd, d), la + ("q_heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        spec["bq"] = ParamSpec(L + (h, hd), la + ("q_heads", "head_dim"), init="zeros")
        spec["bk"] = ParamSpec(L + (kv, hd), la + ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = ParamSpec(L + (kv, hd), la + ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm and not cross:
        spec["q_norm"] = ParamSpec(L + (hd,), la + ("head_dim",), init="ones")
        spec["k_norm"] = ParamSpec(L + (hd,), la + ("head_dim",), init="ones")
    return spec


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------


def project_qkv(
    x: jnp.ndarray,
    p: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    positions: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,KV,hd), with bias/qk_norm/RoPE."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if "q_norm" in p:
        q = _headwise_rms(q, p["q_norm"], cfg.norm_eps)
        k = _headwise_rms(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:
        if cfg.mrope:
            q = mrope_apply(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = mrope_apply(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = rope_apply(q, positions, cfg.rope_theta)
            k = rope_apply(k, positions, cfg.rope_theta)
    return q, k, v


def _headwise_rms(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def output_proj(o: jnp.ndarray, p: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------


def full_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Materialized-scores attention (small seq / encoder / oracle)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if causal:
        Sk = k.shape[1]
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return o.reshape(B, Sq, H, hd)


def blocked_causal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    block_q: int,
    block_kv: int,
    skip_noncausal_blocks: bool = False,
) -> jnp.ndarray:
    """Flash-style online-softmax attention under lax.scan (pure XLA).

    Memory: O(block_q × block_kv) scores per step instead of O(S²).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bkv = min(block_kv, S)
    if S % bq or S % bkv:
        raise ValueError(f"seq {S} must divide block sizes ({bq}, {bkv})")
    nq, nkv = S // bq, S // bkv
    scale = 1.0 / math.sqrt(hd)

    qb = q.reshape(B, nq, bq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 2, 3, 4)

    def one_q_block(qi, q_blk):
        # q_blk: (B, bq, KV, G, hd)
        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)

        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, k_blk, v_blk = inputs
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk).astype(jnp.float32)
            s = s * scale
            off = qi * bq - kj * bkv
            mask = jnp.arange(bq)[:, None] + off >= jnp.arange(bkv)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        if skip_noncausal_blocks:
            # Only kv blocks whose start <= q block end are visible.  The
            # count is dynamic per q block, so slice a static prefix when nq
            # == nkv-aligned; here we use lax.fori_loop with dynamic bound.
            n_vis = (qi * bq + bq - 1) // bkv + 1

            def body(j, carry):
                k_blk = lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
                v_blk = lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
                carry, _ = kv_step(carry, (j, k_blk, v_blk))
                return carry

            m, l, acc = lax.fori_loop(0, n_vis, body, (m0, l0, a0))
        else:
            (m, l, acc), _ = lax.scan(
                kv_step, (m0, l0, a0), (jnp.arange(nkv), kb, vb)
            )
        out = acc / l[..., None]
        return out.astype(q.dtype)  # (B, KV, G, bq, hd)

    outs = lax.map(lambda args: one_q_block(*args), (jnp.arange(nq), qb))
    # (nq, B, KV, G, bq, hd) -> (B, S, H, hd)
    o = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, KV * G, hd)
    return o


def _flash_forward_blocks(q, k, v, block_q, block_kv):
    """Shared forward core: returns (o, lse) with lse = m + log l, fp32
    (B, KV, G, S).  Shapes as in :func:`blocked_causal_attention`."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq, bkv = min(block_q, S), min(block_kv, S)
    nq, nkv = S // bq, S // bkv
    scale = 1.0 / math.sqrt(hd)

    qb = q.reshape(B, nq, bq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 2, 3, 4)

    def one_q_block(qi, q_blk):
        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)

        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, k_blk, v_blk = inputs
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk).astype(jnp.float32)
            s = s * scale
            # scalar-offset causal mask: i + (qi*bq - kj*bkv) >= j.  Keeping
            # the block indices inside a scalar stops XLA from hoisting a
            # stacked (nq, nkv, bq, bkv) mask buffer out of the loops.
            off = qi * bq - kj * bkv
            mask = jnp.arange(bq)[:, None] + off >= jnp.arange(bkv)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nkv), kb, vb))
        out = (acc / l[..., None]).astype(q.dtype)
        lse = m + jnp.log(l)
        return out, lse  # (B,KV,G,bq,hd), (B,KV,G,bq)

    outs, lses = lax.map(lambda args: one_q_block(*args), (jnp.arange(nq), qb))
    o = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, KV * G, hd)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, S)
    return o, lse


def _flash_fwd(q, k, v, block_q, block_kv):
    o, lse = _flash_forward_blocks(q, k, v, block_q, block_kv)
    return o, (q, k, v, o, lse)


def _flash_bwd(block_q, block_kv, res, do):
    """Flash backward: recompute scores per block pair from (q,k,lse);
    saved residuals are only (q, k, v, o, lse) — O(S·d), not O(S²)."""
    q, k, v, o, lse = res
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq, bkv = min(block_q, S), min(block_kv, S)
    nq, nkv = S // bq, S // bkv
    scale = 1.0 / math.sqrt(hd)

    # delta_i = rowsum(do ⊙ o) per query position
    delta = jnp.sum(
        do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1
    )  # (B, S, H)
    delta = delta.reshape(B, S, KV, G).transpose(0, 2, 3, 1)  # (B,KV,G,S)

    qb = q.reshape(B, nq, bq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    dob = do.reshape(B, nq, bq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 2, 3, 4)
    lse_b = lse.reshape(B, KV, G, nq, bq).transpose(3, 0, 1, 2, 4)  # (nq,B,KV,G,bq)
    delta_b = delta.reshape(B, KV, G, nq, bq).transpose(3, 0, 1, 2, 4)

    def p_block(qi, kj, q_blk, k_blk, lse_blk):
        s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk).astype(jnp.float32)
        s = s * scale
        off = qi * bq - kj * bkv
        mask = (jnp.arange(bq)[:, None] + off >= jnp.arange(bkv)[None, :])[
            None, None, None
        ]
        return jnp.where(mask, jnp.exp(s - lse_blk[..., None]), 0.0)

    # pass A: dq — map over q blocks, scan kv blocks
    def dq_block(args):
        qi, q_blk, do_blk, lse_blk, delta_blk = args

        def kv_step(dq_acc, inputs):
            kj, k_blk, v_blk = inputs
            p = p_block(qi, kj, q_blk, k_blk, lse_blk)  # (B,KV,G,bq,bkv)
            dp = jnp.einsum(
                "bqkgd,bskd->bkgqs", do_blk, v_blk
            ).astype(jnp.float32)
            ds = p * (dp - delta_blk[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum(
                "bkgqs,bskd->bqkgd", ds.astype(k_blk.dtype), k_blk
            ).astype(jnp.float32)
            return dq_acc, None

        dq0 = jnp.zeros((B, bq, KV, G, hd), jnp.float32)
        dq_acc, _ = lax.scan(kv_step, dq0, (jnp.arange(nkv), kb, vb))
        return dq_acc

    dqs = lax.map(dq_block, (jnp.arange(nq), qb, dob, lse_b, delta_b))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd).astype(q.dtype)

    # pass B: dk, dv — map over kv blocks, scan q blocks
    def dkv_block(args):
        kj, k_blk, v_blk = args

        def q_step(carry, inputs):
            dk_acc, dv_acc = carry
            qi, q_blk, do_blk, lse_blk, delta_blk = inputs
            p = p_block(qi, kj, q_blk, k_blk, lse_blk)
            dv_acc = dv_acc + jnp.einsum(
                "bkgqs,bqkgd->bskd", p.astype(do_blk.dtype), do_blk
            ).astype(jnp.float32)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", do_blk, v_blk).astype(jnp.float32)
            ds = p * (dp - delta_blk[..., None]) * scale
            dk_acc = dk_acc + jnp.einsum(
                "bkgqs,bqkgd->bskd", ds.astype(q_blk.dtype), q_blk
            ).astype(jnp.float32)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, bkv, KV, hd), jnp.float32)
        (dk_acc, dv_acc), _ = lax.scan(
            q_step, (z, z), (jnp.arange(nq), qb, dob, lse_b, delta_b)
        )
        return dk_acc, dv_acc

    dks, dvs = lax.map(dkv_block, (jnp.arange(nkv), kb, vb))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, S, KV, hd).astype(k.dtype)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, S, KV, hd).astype(v.dtype)
    return dq, dk, dv


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_xla(q, k, v, block_q, block_kv):
    """Causal flash attention with flash *backward* (pure XLA).

    Identical math to :func:`blocked_causal_attention`; the custom VJP
    recomputes block scores in the backward pass so the residuals are
    O(B·S·H·hd) instead of the O(B·H·S²) that autodiff-through-scan saves.
    On the tinyllama train_4k dry-run this is the difference between
    21.4 GiB and < 2 GiB of temps per device (EXPERIMENTS.md §Dry-run).
    """
    o, _ = _flash_forward_blocks(q, k, v, block_q, block_kv)
    return o


flash_attention_xla.defvjp(_flash_fwd, _flash_bwd)


def local_window_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    window: int,
    block_q: int,
) -> jnp.ndarray:
    """Sliding-window causal attention (RecurrentGemma's attention blocks).

    Each q block attends to the ``window`` positions preceding it (inclusive
    of self), via a static-size dynamic slice of front-padded K/V — FLOPs are
    O(S × window), which is what makes the hybrid arch long_500k-eligible.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    if S % bq:
        raise ValueError(f"seq {S} must divide block_q {bq}")
    nq = S // bq
    scale = 1.0 / math.sqrt(hd)
    W = window

    pad = [(0, 0), (W, 0), (0, 0), (0, 0)]
    kp = jnp.pad(k, pad)
    vp = jnp.pad(v, pad)
    qb = q.reshape(B, nq, bq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def one_q_block(qi, q_blk):
        # visible kv span: [qi*bq - W, qi*bq + bq) in unpadded coords
        start = qi * bq  # in padded coords this is (qi*bq - W) + W
        k_blk = lax.dynamic_slice_in_dim(kp, start, W + bq, axis=1)
        v_blk = lax.dynamic_slice_in_dim(vp, start, W + bq, axis=1)
        s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk).astype(jnp.float32)
        s = s * scale
        iq = jnp.arange(bq)[:, None]
        ik = jnp.arange(W + bq)[None, :]
        # static band + one scalar-offset validity term (see flash mask note)
        mask = (ik - W <= iq) & (iq - (ik - W) < W) & (ik + (qi * bq - W) >= 0)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", w, v_blk)
        return o  # (B, bq, KV, G, hd)

    outs = lax.map(lambda args: one_q_block(*args), (jnp.arange(nq), qb))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, L, KV, hd)
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,  # (B,) or scalar int32 — valid prefix length
    window: Optional[int] = None,
) -> jnp.ndarray:
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    L = k_cache.shape[1]
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    s = s / math.sqrt(hd)
    pos = jnp.arange(L)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    if window is not None:
        valid = valid & (pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v_cache)
    return o.reshape(B, 1, H, hd)


def cross_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Senc, KV, hd)
    v: jnp.ndarray,
) -> jnp.ndarray:
    return full_attention(q, k, v, causal=False)
