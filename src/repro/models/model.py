"""Unified model API over the 10-arch zoo.

Entry points used by runtime / launch / tests:

* :func:`param_specs`  — pytree of ParamSpec (no allocation).
* :func:`train_loss`   — CE loss (+ MoE aux) for one batch.
* :func:`prefill_fn` / :func:`decode_fn` — serving paths.
* :func:`input_specs`  — ShapeDtypeStruct stand-ins per (arch × shape cell),
  the dry-run's data contract.
* :func:`analytic_param_count` — N for MODEL_FLOPS = 6·N·D (active-N for MoE).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, transformer
from .config import ModelConfig
from .spec import ParamSpec, as_shape_dtype_structs, count_params


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.is_encoder_decoder:
        return encdec.encdec_specs(cfg)
    return transformer.decoder_specs(cfg)


def analytic_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    attn = d * h * hd + 2 * d * kv * hd + h * hd * d
    if cfg.qkv_bias:
        attn += h * hd + 2 * kv * hd
    if cfg.qk_norm:
        attn += 2 * hd
    embed = V * d if cfg.tie_embeddings else 2 * V * d

    if cfg.family in ("dense", "vlm"):
        per_layer = attn + 3 * d * ff + 2 * d
        return embed + cfg.n_layers * per_layer + d
    if cfg.family == "moe":
        n_e = cfg.top_k if active_only else cfg.n_experts
        per_layer = attn + d * cfg.n_experts + 3 * n_e * d * ff + 2 * d
        return embed + cfg.n_layers * per_layer + d
    if cfg.family == "ssm":
        di, N, R, K = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_, cfg.d_conv
        per_layer = (
            2 * d * di + K * di + di + di * (R + 2 * N) + R * di + di
            + di * N + di + di * d + d
        )
        return embed + cfg.n_layers * per_layer + d
    if cfg.family == "hybrid":
        w, K = cfg.lru_width_, cfg.d_conv
        rec = 2 * d * w + K * w + w + 2 * (w * w + w) + w + w * d
        mlp = 3 * d * ff
        per_rec = rec + mlp + 2 * d
        per_attn = attn + mlp + 2 * d
        n_attn = sum(
            1
            for i in range(cfg.n_layers)
            if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn"
        )
        n_rec = cfg.n_layers - n_attn
        return embed + n_rec * per_rec + n_attn * per_attn + d
    if cfg.family == "audio":
        enc_layer = attn + 2 * d * ff + ff + 2 * d + 4 * d
        dec_layer = 2 * attn + 2 * d * ff + ff + 2 * d + 6 * d
        return (
            V * d
            + cfg.encoder_len * d
            + cfg.n_encoder_layers * enc_layer
            + cfg.n_layers * dec_layer
            + 4 * d
        )
    raise ValueError(cfg.family)


def analytic_step_flops(cfg: ModelConfig, kind: str, batch: int, seq: int) -> float:
    """Useful FLOPs of one step: weight matmuls (6·N·D train / 2·N·D fwd,
    N active) **plus** the sequence-interaction terms 6·N·D ignores —
    attention score/value flops (dominant at 32k+), SSM/RG-LRU scan flops.

    This is the MODEL_FLOPS numerator for §Roofline's useful-compute ratio;
    causal masking is counted at 1/2 (only the lower triangle is useful).
    """
    n_active = analytic_param_count(cfg, active_only=True)
    train = kind == "train"
    fwd_mult = 3.0 if train else 1.0  # bwd ≈ 2× fwd
    D = batch * (1 if kind == "decode" else seq)
    total = (6.0 if train else 2.0) * n_active * D

    h, hd = cfg.n_heads, cfg.head_dim_
    L_attn = 0
    window = None
    if cfg.family in ("dense", "moe", "vlm"):
        L_attn = cfg.n_layers
    elif cfg.family == "hybrid":
        L_attn = sum(
            1 for i in range(cfg.n_layers)
            if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn"
        )
        window = cfg.local_window

    if L_attn:
        if kind == "decode":
            ctx = min(seq, window) if window else seq
            attn = L_attn * batch * ctx * h * hd * 4.0
        else:
            if window and seq > window:
                attn = L_attn * batch * seq * window * h * hd * 4.0 * fwd_mult
            else:
                attn = L_attn * batch * seq * seq * h * hd * 4.0 * 0.5 * fwd_mult
        total += attn

    if cfg.is_encoder_decoder:
        E = cfg.encoder_len
        enc = cfg.n_encoder_layers * batch * E * E * h * hd * 4.0 * fwd_mult
        dec_self = cfg.n_layers * batch * (
            seq * hd * h * 4.0 if kind == "decode" else seq * seq * hd * h * 2.0
        ) * (fwd_mult if kind != "decode" else 1.0)
        cross = cfg.n_layers * batch * (
            E * hd * h * 4.0 if kind == "decode" else seq * E * hd * h * 4.0
        ) * (fwd_mult if kind != "decode" else 1.0)
        total += (0.0 if kind == "decode" else enc) + dec_self + cross

    if cfg.family == "ssm":
        steps = 1 if kind == "decode" else seq
        total += cfg.n_layers * batch * steps * cfg.d_inner * cfg.ssm_state * 6.0 * fwd_mult
    if cfg.family == "hybrid":
        L_rec = cfg.n_layers - L_attn
        steps = 1 if kind == "decode" else seq
        total += L_rec * batch * steps * cfg.lru_width_ * 8.0 * fwd_mult

    return float(total)


# ---------------------------------------------------------------------------
# Losses & serving
# ---------------------------------------------------------------------------


def train_loss(
    params: Dict[str, Any], batch: Dict[str, jnp.ndarray], cfg: ModelConfig
) -> jnp.ndarray:
    """Mean next-token CE (+ MoE aux).  ``batch`` comes from input_specs."""
    if cfg.is_encoder_decoder:
        logits, aux = encdec.forward(params, batch["frames"], batch["tokens"], cfg)
    else:
        logits, aux = transformer.forward(
            params,
            batch["tokens"],
            cfg,
            positions=batch.get("positions"),
            vision_embeds=batch.get("vision_embeds"),
        )
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    ce = _ce(logits, targets, mask)
    return ce + aux


def _ce(logits: jnp.ndarray, targets: jnp.ndarray, mask: Optional[jnp.ndarray]):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


def prefill_fn(params, batch, cfg: ModelConfig, capacity: Optional[int] = None):
    """Prefill; ``capacity`` (>= prompt len) sizes the returned KV cache so a
    request can decode in place without a cache reallocation."""
    if cfg.is_encoder_decoder:
        return encdec.prefill(
            params, batch["frames"], batch["tokens"], cfg, capacity=capacity
        )
    return transformer.prefill(
        params,
        batch["tokens"],
        cfg,
        positions=batch.get("positions"),
        vision_embeds=batch.get("vision_embeds"),
        capacity=capacity,
    )


def decode_fn(params, batch, cache, cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return encdec.decode_step(params, batch["tokens"], cache, cfg)
    return transformer.decode_step(
        params, batch["tokens"], cache, cfg, positions=batch.get("positions")
    )


def init_cache(cfg: ModelConfig, batch: int, capacity: int):
    if cfg.is_encoder_decoder:
        return encdec.init_cache(cfg, batch, capacity)
    return transformer.init_cache(cfg, batch, capacity)


# ---------------------------------------------------------------------------
# Input specs (dry-run data contract)
# ---------------------------------------------------------------------------


def input_specs(
    cfg: ModelConfig, kind: str, global_batch: int, seq_len: int
) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    kind: "train" | "prefill" | "decode".
    For decode, ``seq_len`` is the KV-cache length; the step consumes one new
    token (written at slot seq_len-1, attending over all seq_len slots).
    """
    B, S = global_batch, seq_len
    f32, bf16, i32 = jnp.float32, jnp.bfloat16, jnp.int32
    sds = jax.ShapeDtypeStruct

    def token_batch(seq: int) -> Dict[str, Any]:
        d: Dict[str, Any] = {"tokens": sds((B, seq), i32)}
        if cfg.family == "vlm":
            d["vision_embeds"] = sds((B, cfg.n_vision_tokens, cfg.d_model), bf16)
            d["positions"] = sds((3, B, seq), i32)
        if cfg.is_encoder_decoder:
            d["frames"] = sds((B, cfg.encoder_len, cfg.d_model), bf16)
        return d

    if kind == "train":
        batch = token_batch(S)
        batch["targets"] = sds((B, S), i32)
        batch["loss_mask"] = sds((B, S), f32)
        return {"batch": batch}
    if kind == "prefill":
        return {"batch": token_batch(S)}
    if kind == "decode":
        batch = token_batch(1)
        if cfg.family == "vlm":
            batch["positions"] = sds((3, B, 1), i32)
        cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
        # eval_shape of a closure over nothing: returns ShapeDtypeStruct tree
        return {"batch": batch, "cache": cache}
    raise ValueError(f"unknown kind {kind!r}")


_BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "targets": ("batch", "seq"),
    "loss_mask": ("batch", "seq"),
    "vision_embeds": ("batch", None, None),
    "positions": (None, "batch", "seq"),
    "frames": ("batch", None, None),
}

_CACHE_AXES_BY_NAME = {
    # attention KV caches: (layers, batch, slots, kv_heads, head_dim).
    # "kv_slots" enables flash-decoding-style KV-length sharding: none of the
    # assigned archs has kv_heads divisible by the 16-way model axis, so the
    # cache length dim is the shardable one at decode time.
    "k": (None, "batch", "kv_slots", "act_kv", None),
    "v": (None, "batch", "kv_slots", "act_kv", None),
    "self_k": (None, "batch", "kv_slots", "act_kv", None),
    "self_v": (None, "batch", "kv_slots", "act_kv", None),
    "cross_k": (None, "batch", "kv_slots", "act_kv", None),
    "cross_v": (None, "batch", "kv_slots", "act_kv", None),
    # ssm state: conv (L, B, K-1, d_inner), h (L, B, d_inner, N)
    "conv": (None, "batch", None, "act_rnn"),
    "h": (None, "batch", "act_rnn", None),
    "len": (),
}


def _cache_leaf_axes(key: str, rank: int):
    """Logical axes for one cache leaf, keyed by name suffix + rank."""
    if key in _CACHE_AXES_BY_NAME and len(_CACHE_AXES_BY_NAME[key]) == rank:
        return _CACHE_AXES_BY_NAME[key]
    suffix = key.split("_")[-1]
    if suffix in ("k", "v"):
        return ((None,) * (rank - 4)) + ("batch", "kv_slots", "act_kv", None)
    if suffix == "conv":
        return ((None,) * (rank - 3)) + ("batch", None, "act_rnn")
    if suffix == "h":
        if rank == 4:  # (G, B, d_inner, N)
            return (None, "batch", "act_rnn", None)
        return ((None,) * (rank - 2)) + ("batch", "act_rnn")
    if key == "len" or rank == 0:
        return ()
    return (None,) * rank


def cache_batch_axis(key: str, rank: int) -> Optional[int]:
    """Index of the batch axis in one decode-cache leaf, or None for shared
    scalars ("len").  Batch position varies by leaf — stacked per-layer
    leaves are (layers, B, ...), hybrid tail-layer leaves are (B, ...) — and
    this is the authority serving's chunked-degree candidates use to
    split/concat the cache (repro.runtime.serve)."""
    axes = _cache_leaf_axes(key, rank)
    return axes.index("batch") if "batch" in axes else None


def input_logical_axes(cfg: ModelConfig, kind: str, specs: Dict[str, Any]):
    """Logical axis names for every leaf of :func:`input_specs` output —
    the dry-run turns these into NamedShardings via the active rule."""
    out: Dict[str, Any] = {}
    out["batch"] = {
        k: _BATCH_AXES.get(k, (None,) * len(v.shape))
        for k, v in specs["batch"].items()
    }
    if "cache" in specs:
        out["cache"] = {
            k: _cache_leaf_axes(k, len(v.shape)) for k, v in specs["cache"].items()
        }
    return out


def make_concrete_batch(
    key: jax.Array, cfg: ModelConfig, kind: str, global_batch: int, seq_len: int
) -> Dict[str, Any]:
    """Random concrete inputs matching input_specs (smoke tests/examples)."""
    specs = input_specs(cfg, kind, global_batch, seq_len)

    def materialize(path_leaf):
        sds, k = path_leaf
        if sds.dtype == jnp.int32:
            return jax.random.randint(k, sds.shape, 0, max(2, cfg.vocab_size - 1), jnp.int32)
        return jax.random.normal(k, sds.shape, jnp.float32).astype(sds.dtype)

    leaves, treedef = jax.tree.flatten(specs)
    keys = jax.random.split(key, max(1, len(leaves)))
    out = [materialize((l, k)) for l, k in zip(leaves, keys)]
    tree = jax.tree.unflatten(treedef, out)
    if kind == "train" and "loss_mask" in tree["batch"]:
        mask = jnp.ones_like(tree["batch"]["loss_mask"])
        if cfg.family == "vlm":
            mask = mask.at[:, : cfg.n_vision_tokens].set(0.0)
        tree["batch"]["loss_mask"] = mask
    if kind == "decode":
        # a plausible populated cache: len = capacity - 1
        tree["cache"]["len"] = jnp.asarray(seq_len - 1, jnp.int32)
    return tree
