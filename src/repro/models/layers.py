"""Shared layer primitives: norms, RoPE (incl. M-RoPE), MLPs, embeddings.

Numerics policy (uniform across the zoo): parameters bf16, activations bf16,
norm statistics and RoPE tables fp32, logits and losses fp32.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .spec import ParamSpec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm_spec(d: int) -> Dict[str, ParamSpec]:
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones"),
        "bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def layernorm(x: jnp.ndarray, p: Dict[str, jnp.ndarray], eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# RoPE — standard and multimodal (M-RoPE, Qwen2-VL §3.1)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies, fp32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_apply(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """Rotate ``x`` (..., seq, heads, head_dim) by ``positions`` (..., seq)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_apply(
    x: jnp.ndarray,
    positions: jnp.ndarray,  # (3, ..., seq) — temporal / height / width ids
    theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    """Multimodal RoPE: head_dim/2 frequency slots split across t/h/w position
    streams (Qwen2-VL).  For pure-text tokens the three ids coincide and
    M-RoPE degenerates to standard RoPE — the property tests assert this.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # Select which position stream drives each frequency slot.
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )  # (half,) in {0,1,2}
    pos = positions.astype(jnp.float32)  # (3, ..., seq)
    # ang[..., seq, half] = pos[sec_id[h]][..., seq] * freqs[h]
    pos_per_slot = jnp.take(pos, sec_id, axis=0)  # (half, ..., seq)
    pos_per_slot = jnp.moveaxis(pos_per_slot, 0, -1)  # (..., seq, half)
    ang = pos_per_slot * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_spec(d: int, ff: int, layers: Optional[int] = None) -> Dict[str, ParamSpec]:
    L = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    return {
        "w_gate": ParamSpec(L + (d, ff), lax_ + ("embed", "ffn")),
        "w_up": ParamSpec(L + (d, ff), lax_ + ("embed", "ffn")),
        "w_down": ParamSpec(L + (ff, d), lax_ + ("ffn", "embed")),
    }


def swiglu(x: jnp.ndarray, p: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, p["w_down"])


def gelu_mlp_spec(d: int, ff: int, layers: Optional[int] = None) -> Dict[str, ParamSpec]:
    L = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    return {
        "w_in": ParamSpec(L + (d, ff), lax_ + ("embed", "ffn")),
        "b_in": ParamSpec(L + (ff,), lax_ + ("ffn",), init="zeros"),
        "w_out": ParamSpec(L + (ff, d), lax_ + ("ffn", "embed")),
        "b_out": ParamSpec(L + (d,), lax_ + ("embed",), init="zeros"),
    }


def gelu_mlp(x: jnp.ndarray, p: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, p["w_in"]) + p["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["w_out"]) + p["b_out"]


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_spec(cfg: ModelConfig) -> ParamSpec:
    # "embed_table" (not "embed"): FSDP rules shard weight d_model dims over
    # data, but a (vocab/model, d_model/data) 2-D-sharded lookup table makes
    # XLA SPMD replicate the whole gather ("involuntary full
    # rematerialization") — measured +50 s collective on llama3 (§Perf).
    return ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed_table"), init_scale=0.02)


def unembed_spec(cfg: ModelConfig) -> ParamSpec:
    return ParamSpec((cfg.d_model, cfg.vocab_size), ("embed_table", "vocab"))


def embed(tokens: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Logits in fp32 (loss numerics)."""
    return jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)
