"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence (Griffin §2.4, c = 8)::

    r_t = σ(W_a x_t + b_a)                 recurrence gate
    i_t = σ(W_x x_t + b_x)                 input gate
    log a_t = -c · r_t · softplus(-Λ)      (a = σ(Λ)^(c·r_t), σ(Λ)∈[0.9,0.999])
    h_t = a_t ⊙ h_{t-1} + √(1 - a_t²) ⊙ (i_t ⊙ x_t)

The residual block is: RMSNorm → {conv1d(4) → RG-LRU} ⊙ GeLU(gate branch) →
out-proj, as in RecurrentGemma.  O(1) decode state ⇒ long_500k-eligible.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .spec import ParamSpec

C_FACTOR = 8.0


def rglru_spec(cfg: ModelConfig, layers: Optional[int] = None) -> Dict[str, ParamSpec]:
    d, w, K = cfg.d_model, cfg.lru_width_, cfg.d_conv
    L = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "in_x": ParamSpec(L + (d, w), la + ("embed", "rnn")),
        "in_gate": ParamSpec(L + (d, w), la + ("embed", "rnn")),
        "conv_w": ParamSpec(L + (K, w), la + ("conv", "rnn")),
        "conv_b": ParamSpec(L + (w,), la + ("rnn",), init="zeros"),
        "wa": ParamSpec(L + (w, w), la + ("rnn", "rnn")),
        "ba": ParamSpec(L + (w,), la + ("rnn",), init="zeros"),
        "wx": ParamSpec(L + (w, w), la + ("rnn", "rnn")),
        "bx": ParamSpec(L + (w,), la + ("rnn",), init="zeros"),
        "lam": ParamSpec(L + (w,), la + ("rnn",), init="rglru_lambda"),
        "out": ParamSpec(L + (w, d), la + ("rnn", "embed")),
    }


def _rglru_gates(
    x: jnp.ndarray, p: Dict[str, jnp.ndarray]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    r = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", x, p["wa"]).astype(jnp.float32) + p["ba"]
    )
    i = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", x, p["wx"]).astype(jnp.float32) + p["bx"]
    )
    return r, i


def rglru_block(
    x: jnp.ndarray,  # (B, S, d)
    p: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> jnp.ndarray:
    from .ssm import _causal_conv1d  # same depthwise causal conv

    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["in_gate"]).astype(jnp.float32)
    ).astype(x.dtype)
    xs = jnp.einsum("bsd,dw->bsw", x, p["in_x"])
    xs = _causal_conv1d(xs, p["conv_w"], p["conv_b"])

    softplus_neg_lam = jax.nn.softplus(-p["lam"].astype(jnp.float32))  # (w,)

    def step(h, inputs):
        x_t, r_t, i_t = inputs  # (B, w) each
        log_a = -C_FACTOR * r_t * softplus_neg_lam
        a = jnp.exp(log_a)
        gated = i_t * x_t.astype(jnp.float32)
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
        return h, h.astype(x_t.dtype)

    r, i = _rglru_gates(xs, p)  # (B,S,w) fp32
    h0 = jnp.zeros((x.shape[0], cfg.lru_width_), jnp.float32)
    _, hs = lax.scan(
        step,
        h0,
        (xs.transpose(1, 0, 2), r.transpose(1, 0, 2), i.transpose(1, 0, 2)),
    )
    y = hs.transpose(1, 0, 2)  # (B,S,w)
    y = y * gate
    return jnp.einsum("bsw,wd->bsd", y, p["out"])


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def rglru_init_cache(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.lru_width_), jnp.bfloat16),
        "h": jnp.zeros((batch, cfg.lru_width_), jnp.float32),
    }


def rglru_decode_step(
    x: jnp.ndarray,  # (B, 1, d)
    cache: Dict[str, jnp.ndarray],
    p: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["in_gate"]).astype(jnp.float32)
    ).astype(x.dtype)[:, 0]
    xs = jnp.einsum("bsd,dw->bsw", x, p["in_x"])[:, 0]  # (B, w)
    window = jnp.concatenate([cache["conv"].astype(xs.dtype), xs[:, None, :]], axis=1)
    xc = jnp.einsum("bkw,kw->bw", window, p["conv_w"]) + p["conv_b"]

    r, i = _rglru_gates(xc, p)
    log_a = -C_FACTOR * r * jax.nn.softplus(-p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    h = a * cache["h"] + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * xc.astype(jnp.float32)
    )
    y = h.astype(x.dtype) * gate
    out = jnp.einsum("bw,wd->bd", y, p["out"])[:, None, :]
    return out, {"conv": window[:, 1:, :].astype(jnp.bfloat16), "h": h}
