"""ModelConfig — one dataclass describing every architecture in the pool.

The 10 assigned architectures span dense GQA, MoE, SSM (Mamba-1), hybrid
(RG-LRU + local attention), encoder-decoder (Whisper), and VLM (M-RoPE)
families; this config is the superset of their knobs.  Concrete instances
live in ``repro/configs/<arch>.py`` (full + smoke-reduced pairs).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # defaults to d_model // n_heads
    qkv_bias: bool = False                  # qwen2.5
    qk_norm: bool = False                   # qwen3
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_groups: int = 1  # GShard-style dispatch groups (set = data degree at scale)

    # SSM (Mamba-1)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None           # defaults to ceil(d_model / 16)

    # Hybrid (RecurrentGemma): repeating block pattern, e.g. ("rglru","rglru","attn")
    block_pattern: Tuple[str, ...] = ()
    lru_width: Optional[int] = None         # defaults to d_model
    local_window: int = 2048

    # Encoder-decoder (Whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500                 # 30 s of audio at 50 Hz after conv stub

    # VLM (Qwen2-VL)
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2
    n_vision_tokens: int = 256              # stubbed patch embeddings per sample

    # numerics / structure
    dtype: str = "bfloat16"
    remat: str = "full"                     # none | full  (PP at train time)
    scan_layers: bool = True                # scan-over-layers (compile economy)
    attn_block_q: int = 512                 # XLA blocked-attention tile (PP)
    attn_block_kv: int = 1024

    def __post_init__(self) -> None:
        if self.family not in ("dense", "moe", "ssm", "hybrid", "audio", "vlm"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm":
            if self.n_heads % max(1, self.n_kv_heads):
                raise ValueError("n_heads must be divisible by n_kv_heads")
        if self.family == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError("moe family needs n_experts and top_k")
        if self.family == "hybrid" and not self.block_pattern:
            raise ValueError("hybrid family needs a block_pattern")

    # -- derived ---------------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports O(seq) decode state (long_500k eligible)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing

    def with_(self, **kwargs) -> "ModelConfig":
        return dataclasses.replace(self, **kwargs)

    # -- parameter counting (for 6ND MODEL_FLOPS) --------------------------------

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count; active_only counts top_k experts (MoE)."""
        from . import model as _model  # late import to avoid cycle

        return _model.analytic_param_count(self, active_only=active_only)
