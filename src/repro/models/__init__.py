"""Model zoo substrate: 10 LM-family architectures in pure JAX."""
from .config import ModelConfig
from .model import (
    analytic_param_count,
    analytic_step_flops,
    cache_batch_axis,
    decode_fn,
    init_cache,
    input_logical_axes,
    input_specs,
    make_concrete_batch,
    param_specs,
    prefill_fn,
    train_loss,
)
from .spec import (
    ParamSpec,
    as_shape_dtype_structs,
    count_params,
    init_params,
    is_spec_leaf,
)

__all__ = [
    "ModelConfig",
    "ParamSpec",
    "param_specs",
    "train_loss",
    "prefill_fn",
    "decode_fn",
    "init_cache",
    "input_logical_axes",
    "input_specs",
    "make_concrete_batch",
    "analytic_param_count",
    "analytic_step_flops",
    "cache_batch_axis",
    "as_shape_dtype_structs",
    "count_params",
    "init_params",
    "is_spec_leaf",
]
