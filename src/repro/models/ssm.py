"""Mamba-1 selective SSM block (falcon-mamba-7b).

Recurrence (per channel c, state n)::

    h_t = exp(Δ_t A) ⊙ h_{t-1} + Δ_t B_t x_t
    y_t = C_t · h_t + D x_t

with input-dependent Δ (softplus), B, C — the "selective" part.  The scan is
O(S·B·d_inner·N) FLOPs and O(1)-state in sequence length, which is what makes
falcon-mamba long_500k-eligible.

Implementation notes:
* The (B, S, d_inner, N) decay tensor must NEVER be materialized (17 TB for
  the falcon train cell); Δ/B/C projections happen per-timestep inside
  ``lax.scan``.
* State carried in fp32; activations bf16.
* The Pallas kernel (:mod:`repro.kernels.ssm_scan`) implements the
  chunked-parallel form of the same recurrence; this module is the XLA
  reference path used by the dry-run.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .spec import ParamSpec


def ssm_spec(cfg: ModelConfig, layers: Optional[int] = None) -> Dict[str, ParamSpec]:
    d, di, N, R, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_, cfg.d_conv
    L = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "in_proj": ParamSpec(L + (d, 2 * di), la + ("embed", "rnn")),
        "conv_w": ParamSpec(L + (K, di), la + ("conv", "rnn")),
        "conv_b": ParamSpec(L + (di,), la + ("rnn",), init="zeros"),
        "x_proj": ParamSpec(L + (di, R + 2 * N), la + ("rnn", None)),
        "dt_w": ParamSpec(L + (R, di), la + (None, "rnn")),
        "dt_b": ParamSpec(L + (di,), la + ("rnn",), init_scale=0.02),
        "A_log": ParamSpec(L + (di, N), la + ("rnn", "state"), init_scale=0.5),
        "D": ParamSpec(L + (di,), la + ("rnn",), init="ones"),
        "out_proj": ParamSpec(L + (di, d), la + ("rnn", "embed")),
    }


def _causal_conv1d(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Depthwise causal conv over seq.  x: (B,S,di), w: (K,di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # sum_k x[:, s+k, :] * w[k, :]
    out = jnp.zeros_like(x)
    for k in range(K):  # K=4 static taps; unrolled adds, no conv op needed
        out = out + xp[:, k : k + x.shape[1], :] * w[k]
    return out + b


def ssm_block(
    x: jnp.ndarray,  # (B, S, d)
    p: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> jnp.ndarray:
    B, S, d = x.shape
    di, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)  # (B,S,di) each
    xs = _causal_conv1d(xs, p["conv_w"], p["conv_b"])
    xs = jax.nn.silu(xs)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, N)

    def step(h, inputs):
        x_t, raw = inputs  # (B, di), (B, R+2N)
        dt_r = raw[:, :R]
        B_t = raw[:, R : R + N].astype(jnp.float32)  # (B, N)
        C_t = raw[:, R + N :].astype(jnp.float32)  # (B, N)
        dt = jax.nn.softplus(
            jnp.einsum("br,rd->bd", dt_r, p["dt_w"]).astype(jnp.float32)
            + p["dt_b"].astype(jnp.float32)
        )  # (B, di)
        decay = jnp.exp(dt[..., None] * A)  # (B, di, N)
        xf = x_t.astype(jnp.float32)
        h = decay * h + (dt * xf)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)  # (B, di)
        return h, y.astype(x.dtype)

    raw_all = jnp.einsum("bsd,dr->bsr", xs, p["x_proj"])  # (B,S,R+2N)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    _, ys = lax.scan(
        step, h0, (xs.transpose(1, 0, 2), raw_all.transpose(1, 0, 2))
    )
    y = ys.transpose(1, 0, 2)  # (B,S,di)
    y = y + xs * p["D"]
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"])


# ---------------------------------------------------------------------------
# Decode path (stateful, O(1) per token)
# ---------------------------------------------------------------------------


def ssm_init_cache(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), jnp.bfloat16),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def ssm_decode_step(
    x: jnp.ndarray,  # (B, 1, d)
    cache: Dict[str, jnp.ndarray],
    p: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B = x.shape[0]
    di, N, R, K = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_, cfg.d_conv

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]
    xs, z = jnp.split(xz, 2, axis=-1)  # (B, di)
    window = jnp.concatenate([cache["conv"].astype(xs.dtype), xs[:, None, :]], axis=1)
    conv_out = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xs_c = jax.nn.silu(conv_out)

    raw = jnp.einsum("bd,dr->br", xs_c, p["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("br,rd->bd", raw[:, :R], p["dt_w"]).astype(jnp.float32)
        + p["dt_b"].astype(jnp.float32)
    )
    B_t = raw[:, R : R + N].astype(jnp.float32)
    C_t = raw[:, R + N :].astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt[..., None] * A)
    h = decay * cache["h"] + (dt * xs_c.astype(jnp.float32))[..., None] * B_t[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_t).astype(x.dtype)
    y = y + xs_c * p["D"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bd,de->be", y, p["out_proj"])[:, None, :]
    new_cache = {"conv": window[:, 1:, :].astype(jnp.bfloat16), "h": h}
    return out, new_cache
