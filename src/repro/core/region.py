"""AT regions — the ``!oat$ install Exchange(...) region start/end`` analogue.

In ppOpen-AT the software developer brackets a loop nest with directives; the
preprocessor generates all tuning candidates as subroutines and a dispatcher
that calls the selected one.  In `repro` the same three pieces are:

* a :class:`~repro.core.params.ParamSpace` — the candidate family,
* ``instantiate(point) -> callable`` — the "generated subroutine" for one
  candidate (pure function of the region's inputs),
* :class:`ATRegion` — the dispatcher: calls the currently-selected candidate,
  can be pointed at a tuning DB so selection follows the tuner's argmin.

All candidates exist ahead of time (ppOpen-AT's "light-load AT, no dynamic
code generation"): ``precompile()`` AOT-compiles every candidate with
``jax.jit(...).lower(...).compile()`` so run-time switching is a dict lookup.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, Optional, Sequence

import jax

from .db import TuningDB
from .params import BasicParams, ParamSpace, pp_key


class ATRegion:
    """A tunable computation with a finite, pre-generated candidate family.

    ``instantiate(point)`` must return a *pure* callable; every candidate
    must be semantically identical (the tests assert allclose across the
    whole family against the region's ``oracle``).
    """

    def __init__(
        self,
        name: str,
        space: ParamSpace,
        instantiate: Callable[[Mapping[str, Any]], Callable[..., Any]],
        oracle: Optional[Callable[..., Any]] = None,
        space_signature: Optional[str] = None,
        hints: Optional[Mapping[str, Mapping[str, Any]]] = None,
        arch: Optional[Any] = None,
    ) -> None:
        self.name = name
        self.space = space
        self.instantiate = instantiate
        self.oracle = oracle
        # emitted-space provenance (core/emit.py): the signature gates DB
        # final recall — a region whose space was emitted under a different
        # arch model must re-tune, not silently recall the stale winner
        self.space_signature = space_signature
        self.hints = dict(hints) if hints else None
        self.arch = arch
        self.selected: Dict[str, Any] = space.default()
        self._compiled: Dict[str, Callable[..., Any]] = {}
        # bumped on every (re-)selection and invalidation: dispatch fast
        # paths cache "the selected candidate's callable" against this, so
        # a RuntimeSelector demotion or a joint-program hot apply refreshes
        # them lazily with one integer compare per call (docs/program.md)
        self.version = 0

    # -- selection -------------------------------------------------------------

    def select(self, point: Mapping[str, Any]) -> None:
        self.space.validate(point)
        self.selected = dict(point)
        self.version += 1

    def invalidate(self) -> None:
        """Drop every materialized candidate (the family itself changed).

        For regions whose ``instantiate`` closes over mutable caller state
        (the Trainer's remat directive): after mutating that state, cached
        candidates are stale — they were built under the old closure.
        """
        self._compiled.clear()
        self.version += 1

    def select_from_db(self, db: TuningDB, bp: BasicParams) -> bool:
        """Adopt the tuned argmin for this BP if the DB has one."""
        best = db.best_point(bp)
        if best is not None:
            self.select(best)
            return True
        return False

    # -- execution -------------------------------------------------------------

    def candidate(self, point: Mapping[str, Any]) -> Callable[..., Any]:
        key = pp_key(point)
        if key in self._compiled:
            return self._compiled[key]
        # cache the instantiation: candidates are pure, and re-instantiating
        # a jitted candidate per call would re-trace every step
        fn = self.instantiate(point)
        self._compiled[key] = fn
        return fn

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        return self.candidate(self.selected)(*args, **kwargs)

    # -- ahead-of-time candidate generation -------------------------------------

    def precompile(
        self,
        example_args: Sequence[Any],
        points: Optional[Sequence[Mapping[str, Any]]] = None,
        jit: bool = True,
    ) -> int:
        """AOT-compile candidates so run-time selection never compiles.

        Returns the number of candidates compiled.  This is ppOpen-AT's
        pre-generated-subroutine model: pay all codegen cost up front
        (install / before-execution time), switch for free at run time.
        """
        pts = list(points) if points is not None else list(self.space.points())
        count = 0
        for point in pts:
            key = pp_key(point)
            if key in self._compiled:
                continue
            fn = self.instantiate(point)
            if jit:
                jfn = jax.jit(fn)
                compiled = jfn.lower(*example_args).compile()
                self._compiled[key] = compiled
            else:
                self._compiled[key] = fn
            count += 1
        return count

    def compiled_points(self) -> int:
        return len(self._compiled)

    def is_compiled(self, point: Mapping[str, Any]) -> bool:
        """True if this candidate is already materialized (warm/AOT)."""
        return pp_key(point) in self._compiled

    def is_compiled_key(self, key: str) -> bool:
        return key in self._compiled

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"ATRegion({self.name!r}, space={self.space!r}, "
            f"selected={self.selected})"
        )
