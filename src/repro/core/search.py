"""Search strategies over a :class:`~repro.core.params.ParamSpace`.

ppOpen-AT's before-execution layer enumerates every generated candidate (the
spaces are deliberately small — the paper limits candidate counts to avoid
code expansion).  We keep exhaustive search as the default and faithful
strategy, and add two cheaper strategies for the larger spaces our
distributed PPs create:

* :class:`ExhaustiveSearch` — measure every feasible point (the paper's).
* :class:`CoordinateDescent` — the hillclimb used by §Perf: sweep one
  parameter at a time, keep the argmin, repeat until a full pass moves
  nothing.  Exact for separable costs, good for near-separable ones.
* :class:`SuccessiveHalving` — measure all points with a cheap/noisy budget,
  keep the best half, re-measure with doubled budget, repeat.  Useful when
  cost evaluation itself is expensive (wall-clock with many repeats).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from .params import ParamSpace, pp_key


@dataclass
class Trial:
    point: Dict[str, Any]
    cost: float


@dataclass
class SearchResult:
    best: Trial
    trials: List[Trial] = field(default_factory=list)
    evaluations: int = 0

    def costs_by_key(self) -> Dict[str, float]:
        return {pp_key(t.point): t.cost for t in self.trials}


class Search:
    def run(self, space: ParamSpace, cost) -> SearchResult:  # pragma: no cover
        raise NotImplementedError


class ExhaustiveSearch(Search):
    """Measure every feasible PP point; return the argmin.

    ``on_trial`` (if given) is called after each evaluation — the tuner uses
    it for incremental DB writes so an interrupted AT run resumes where it
    stopped (fault tolerance applies to tuning too).
    """

    def __init__(self, on_trial: Optional[Callable[[Trial], None]] = None) -> None:
        self.on_trial = on_trial

    def run(self, space: ParamSpace, cost) -> SearchResult:
        trials: List[Trial] = []
        for point in space.points():
            t = Trial(dict(point), float(cost(point)))
            trials.append(t)
            if self.on_trial:
                self.on_trial(t)
        if not trials:
            raise ValueError("no feasible points to search")
        best = min(trials, key=lambda t: t.cost)
        return SearchResult(best=best, trials=trials, evaluations=len(trials))


class CoordinateDescent(Search):
    """Greedy one-parameter-at-a-time descent from ``start`` (or default)."""

    def __init__(
        self,
        start: Optional[Mapping[str, Any]] = None,
        max_passes: int = 8,
        on_trial: Optional[Callable[[Trial], None]] = None,
    ) -> None:
        self.start = dict(start) if start is not None else None
        self.max_passes = max_passes
        self.on_trial = on_trial

    def run(self, space: ParamSpace, cost) -> SearchResult:
        point = dict(self.start) if self.start is not None else space.default()
        space.validate(point)
        seen: Dict[str, float] = {}

        def eval_point(p: Dict[str, Any]) -> float:
            key = pp_key(p)
            if key not in seen:
                seen[key] = float(cost(p))
                trial = Trial(dict(p), seen[key])
                trials.append(trial)
                if self.on_trial:
                    self.on_trial(trial)
            return seen[key]

        trials: List[Trial] = []
        best_cost = eval_point(point)
        for _ in range(self.max_passes):
            moved = False
            for param in space.params:
                best_val = point[param.name]
                for candidate in param.domain:
                    if candidate == point[param.name]:
                        continue
                    trial_point = dict(point)
                    trial_point[param.name] = candidate
                    if not space.feasible(trial_point):
                        continue
                    c = eval_point(trial_point)
                    if c < best_cost:
                        best_cost, best_val, moved = c, candidate, True
                point[param.name] = best_val
            if not moved:
                break
        best = min(trials, key=lambda t: t.cost)
        return SearchResult(best=best, trials=trials, evaluations=len(trials))


class SuccessiveHalving(Search):
    """Rung-based elimination for expensive measured costs.

    ``cost`` must accept ``(point, budget)`` where budget is a positive int
    (e.g. number of timing repeats); wrap a plain cost with
    ``lambda p, b: cost(p)`` if budget-insensitive.
    """

    def __init__(self, initial_budget: int = 1, eta: int = 2) -> None:
        self.initial_budget = initial_budget
        self.eta = eta

    def run(self, space: ParamSpace, cost) -> SearchResult:
        alive: List[Dict[str, Any]] = [dict(p) for p in space.points()]
        if not alive:
            raise ValueError("no feasible points to search")
        budget = self.initial_budget
        trials: List[Trial] = []
        evaluations = 0
        while True:
            scored: List[Trial] = []
            for p in alive:
                c = float(cost(p, budget))
                evaluations += 1
                t = Trial(dict(p), c)
                scored.append(t)
                trials.append(t)
            scored.sort(key=lambda t: t.cost)
            if len(scored) == 1:
                return SearchResult(best=scored[0], trials=trials, evaluations=evaluations)
            keep = max(1, len(scored) // self.eta)
            alive = [t.point for t in scored[:keep]]
            budget *= self.eta
