"""Search strategies over a :class:`~repro.core.params.ParamSpace`.

ppOpen-AT's before-execution layer enumerates every generated candidate (the
spaces are deliberately small — the paper limits candidate counts to avoid
code expansion).  We keep exhaustive search as the default and faithful
strategy, and add two cheaper strategies for the larger spaces our
distributed PPs create:

* :class:`ExhaustiveSearch` — measure every feasible point (the paper's).
* :class:`CoordinateDescent` — the hillclimb used by §Perf: sweep one
  parameter at a time, keep the argmin, repeat until a full pass moves
  nothing.  Exact for separable costs, good for near-separable ones.
* :class:`SuccessiveHalving` — measure all points with a cheap/noisy budget,
  keep the best half, re-measure with doubled budget, repeat.  Useful when
  cost evaluation itself is expensive (wall-clock with many repeats).
* :class:`StagedSearch` — the staged tuning pipeline (docs/tuning.md): a
  cheap *prescreen* cost scores the full space (independent candidates
  dispatched concurrently — XLA lowering/compilation releases the GIL), only
  the top-k survivors reach the *measured finals* search, and an optional
  warm-start seed from a neighbouring shape class is always kept alive.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from ..obs.trace import current_tracer
from .params import ParamSpace, pp_key, project_point


@dataclass
class Trial:
    point: Dict[str, Any]
    cost: float


@dataclass
class SearchResult:
    best: Trial
    trials: List[Trial] = field(default_factory=list)
    evaluations: int = 0
    # staged pipeline bookkeeping: how many candidates the cheap prescreen
    # scored (zero for single-stage strategies) and what it scored them at.
    prescreen_evaluations: int = 0
    prescreen_costs: Dict[str, float] = field(default_factory=dict)

    def costs_by_key(self) -> Dict[str, float]:
        return {pp_key(t.point): t.cost for t in self.trials}


class Search:
    def run(self, space: ParamSpace, cost) -> SearchResult:  # pragma: no cover
        raise NotImplementedError


class ExhaustiveSearch(Search):
    """Measure every feasible PP point; return the argmin.

    ``on_trial`` (if given) is called after each evaluation — the tuner uses
    it for incremental DB writes so an interrupted AT run resumes where it
    stopped (fault tolerance applies to tuning too).
    """

    def __init__(self, on_trial: Optional[Callable[[Trial], None]] = None) -> None:
        self.on_trial = on_trial

    def run(self, space: ParamSpace, cost) -> SearchResult:
        trials: List[Trial] = []
        for point in space.points():
            t = Trial(dict(point), float(cost(point)))
            trials.append(t)
            if self.on_trial:
                self.on_trial(t)
        if not trials:
            raise ValueError("no feasible points to search")
        best = min(trials, key=lambda t: t.cost)
        return SearchResult(best=best, trials=trials, evaluations=len(trials))


class CoordinateDescent(Search):
    """Greedy one-parameter-at-a-time descent from ``start`` (or default)."""

    def __init__(
        self,
        start: Optional[Mapping[str, Any]] = None,
        max_passes: int = 8,
        on_trial: Optional[Callable[[Trial], None]] = None,
    ) -> None:
        self.start = dict(start) if start is not None else None
        self.max_passes = max_passes
        self.on_trial = on_trial

    def run(self, space: ParamSpace, cost) -> SearchResult:
        point = dict(self.start) if self.start is not None else space.default()
        space.validate(point)
        seen: Dict[str, float] = {}

        def eval_point(p: Dict[str, Any]) -> float:
            key = pp_key(p)
            if key not in seen:
                seen[key] = float(cost(p))
                trial = Trial(dict(p), seen[key])
                trials.append(trial)
                if self.on_trial:
                    self.on_trial(trial)
            return seen[key]

        trials: List[Trial] = []
        best_cost = eval_point(point)
        for _ in range(self.max_passes):
            moved = False
            for param in space.params:
                best_val = point[param.name]
                for candidate in param.domain:
                    if candidate == point[param.name]:
                        continue
                    trial_point = dict(point)
                    trial_point[param.name] = candidate
                    if not space.feasible(trial_point):
                        continue
                    c = eval_point(trial_point)
                    if c < best_cost:
                        best_cost, best_val, moved = c, candidate, True
                point[param.name] = best_val
            if not moved:
                break
        best = min(trials, key=lambda t: t.cost)
        return SearchResult(best=best, trials=trials, evaluations=len(trials))


class SuccessiveHalving(Search):
    """Rung-based elimination for expensive measured costs.

    ``cost`` must accept ``(point, budget)`` where budget is a positive int
    (e.g. number of timing repeats); wrap a plain cost with
    ``lambda p, b: cost(p)`` if budget-insensitive.

    ``on_trial`` (if given) is called after each evaluation — the same
    incremental-DB-write hook :class:`ExhaustiveSearch` and
    :class:`CoordinateDescent` have, so an interrupted measured-finals run
    resumes from its recorded trials instead of starting over
    (fault-tolerance parity across strategies).
    """

    needs_budget = True  # run() calls cost(point, budget), not cost(point)

    def __init__(
        self,
        initial_budget: int = 1,
        eta: int = 2,
        on_trial: Optional[Callable[[Trial], None]] = None,
    ) -> None:
        self.initial_budget = initial_budget
        self.eta = eta
        self.on_trial = on_trial

    def run(self, space: ParamSpace, cost) -> SearchResult:
        alive: List[Dict[str, Any]] = [dict(p) for p in space.points()]
        if not alive:
            raise ValueError("no feasible points to search")
        budget = self.initial_budget
        trials: List[Trial] = []
        evaluations = 0
        rung = 0
        while True:
            tr = current_tracer()
            if tr is None:
                scored = self._rung(alive, budget, cost, trials)
            else:
                with tr.span(
                    "search.rung", cat="search", rung=rung, budget=budget,
                    alive=len(alive),
                ) as attrs:
                    scored = self._rung(alive, budget, cost, trials)
                    attrs["best_cost"] = scored[0].cost
            evaluations += len(alive)
            if len(scored) == 1:
                return SearchResult(best=scored[0], trials=trials, evaluations=evaluations)
            keep = max(1, len(scored) // self.eta)
            alive = [t.point for t in scored[:keep]]
            budget *= self.eta
            rung += 1

    def _rung(
        self,
        alive: List[Dict[str, Any]],
        budget: int,
        cost,
        trials: List[Trial],
    ) -> List[Trial]:
        """Measure one elimination rung; returns the rung's trials sorted
        best-first (the caller keeps the top ``1/eta``)."""
        scored: List[Trial] = []
        for p in alive:
            t = Trial(dict(p), float(cost(p, budget)))
            scored.append(t)
            trials.append(t)
            if self.on_trial:
                self.on_trial(t)
        scored.sort(key=lambda t: t.cost)
        return scored


def default_prescreen_k(n_points: int) -> int:
    """How many prescreen survivors reach the measured-finals stage.

    ``ceil(sqrt(n))`` keeps the measured-evaluation count sublinear in the
    space size while leaving enough slack for prescreen ranking error — see
    docs/tuning.md for how to override it per op.
    """
    return max(2, math.isqrt(max(1, n_points - 1)) + 1)


class StagedSearch(Search):
    """Roofline prescreen → measured finals, with an optional warm-start seed.

    Stage 1 scores *every* feasible point with ``prescreen`` — an analytic /
    compile-only cost (e.g. :class:`~repro.core.cost.CompiledRooflineCost`)
    that never executes a candidate.  Independent candidates are scored
    concurrently on a bounded :class:`ThreadPoolExecutor`: XLA lowering and
    compilation release the GIL, so prescreen wall time scales down with
    cores.  A candidate whose prescreen raises is scored ``inf`` (it can
    still be reached by raising ``k`` — it is excluded, not failed).

    Stage 2 hands the ``k`` best-scoring survivors (plus ``warm_start``, if
    given — the seed is never pruned) to the ``finals`` search, which runs
    the *measured* ``cost`` the caller passed to :meth:`run`.  With
    ``k >= |space|`` every point survives and the result is exactly the
    exhaustive argmin of the measured cost.

    ``finals`` defaults to :class:`ExhaustiveSearch` over the survivors; a
    strategy with ``needs_budget`` (:class:`SuccessiveHalving`) gets the
    plain measured cost bridged to its ``(point, budget)`` signature unless
    the cost object itself advertises ``supports_budget``.
    """

    def __init__(
        self,
        prescreen: Callable[[Mapping[str, Any]], float],
        k: Optional[int] = None,
        finals: Optional[Search] = None,
        warm_start: Optional[Mapping[str, Any]] = None,
        max_workers: Optional[int] = None,
        on_trial: Optional[Callable[[Trial], None]] = None,
    ) -> None:
        self.prescreen = prescreen
        self.k = k
        self.finals = finals
        self.warm_start = dict(warm_start) if warm_start is not None else None
        self.max_workers = max_workers
        self.on_trial = on_trial

    def _score_all(
        self, points: List[Dict[str, Any]]
    ) -> Dict[str, float]:
        from .cost import score_points_concurrently

        batch = getattr(self.prescreen, "score_many", None)
        if batch is not None:  # e.g. CompiledRooflineCost: it owns the pool
            scores = batch(points, max_workers=self.max_workers)
        else:
            scores = score_points_concurrently(
                self.prescreen, points, self.max_workers
            )
        return {pp_key(p): s for p, s in zip(points, scores)}

    def run(self, space: ParamSpace, cost) -> SearchResult:
        points = [dict(p) for p in space.points()]
        if not points:
            raise ValueError("no feasible points to search")

        tr = current_tracer()
        if tr is None:
            scores = self._score_all(points)
        else:
            with tr.span(
                "search.prescreen", cat="search", candidates=len(points),
            ) as attrs:
                scores = self._score_all(points)
                finite = [s for s in scores.values() if math.isfinite(s)]
                attrs["scored"] = len(finite)
                attrs["excluded"] = len(points) - len(finite)
        k = self.k if self.k is not None else default_prescreen_k(len(points))
        ranked = sorted(points, key=lambda p: scores[pp_key(p)])
        survivors = ranked[: max(1, k)]

        seed = None
        if self.warm_start is not None:
            seed = project_point(space, self.warm_start)
        if seed is not None:
            skey = pp_key(seed)
            survivors = [p for p in survivors if pp_key(p) != skey]
            # the seed leads: it becomes the measured incumbent adaptive
            # costs prune against, so refinement runs stay short.  It
            # extends the survivor list (k+1 finals) rather than evicting
            # the k-th-ranked candidate — the seed is *additional* evidence,
            # and displacing a prescreen pick would make a stale sibling
            # winner able to shadow this class's own best candidate.
            survivors.insert(0, seed)

        finals = self.finals or ExhaustiveSearch(on_trial=self.on_trial)
        if getattr(finals, "needs_budget", False) and not getattr(
            cost, "supports_budget", False
        ):
            measured = lambda p, budget: cost(p)  # noqa: E731
        else:
            measured = cost
        tr = current_tracer()
        if tr is None:
            result = finals.run(space.subset(survivors), measured)
        else:
            with tr.span(
                "search.finals", cat="search", survivors=len(survivors),
                warm_seeded=seed is not None,
            ) as attrs:
                result = finals.run(space.subset(survivors), measured)
                attrs["best_pp"] = pp_key(result.best.point)
        result.prescreen_evaluations = len(points)
        result.prescreen_costs = scores
        return result
