"""Dynamic parallelism-degree control — the ``omp_set_num_threads`` analogue
(paper §IV).

The paper's generated subroutines do::

    call omp_set_num_threads ( NumThread )   ! tuned degree, on entry
    <candidate code>
    call omp_set_num_threads ( 32 )          ! restore user maximum, on exit

On TPU the device count is fixed per program, so "number of threads" is
reinterpreted (see docs/design.md §2) as the **grain of parallelism at fixed
device count**: Pallas grid size for kernels, chunk counts for collectives,
microbatch count for gradient accumulation.  What carries over exactly is
the *protocol*: a region-scoped degree that is set on entry and restored on
exit, tuned per kernel, and cheap to switch because every candidate is
precompiled.

:class:`DegreeController` implements that protocol; the run-time loops and
the Fig-12 benchmark use it, and :class:`repro.core.tuner.RuntimeSelector`
re-selects degrees through it when a straggler is detected.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Iterator, Optional


class DegreeController:
    """Region-scoped parallelism degree with OpenMP set/restore semantics."""

    def __init__(self, max_degree: int) -> None:
        if max_degree < 1:
            raise ValueError("max_degree must be >= 1")
        self.max_degree = int(max_degree)
        self._current = self.max_degree
        self._tuned: Dict[str, int] = {}
        self._lock = threading.Lock()
        self.switch_count = 0  # Fig-12 accounting: how often we switched

    @property
    def current(self) -> int:
        return self._current

    def set_tuned(self, region_name: str, degree: int) -> None:
        """Record the tuned degree for a region (from the before-execution AT)."""
        if not (1 <= degree <= self.max_degree):
            raise ValueError(
                f"degree {degree} outside [1, {self.max_degree}] for {region_name!r}"
            )
        with self._lock:
            self._tuned[region_name] = int(degree)

    def tuned(self, region_name: str) -> Optional[int]:
        return self._tuned.get(region_name)

    @contextmanager
    def region(self, region_name: str) -> Iterator[int]:
        """``omp_set_num_threads(NumThread) ... omp_set_num_threads(max)``.

        Enter: switch to the region's tuned degree (or keep max if untuned).
        Exit: restore the user's maximum.  Reentrant-safe via restore-to-max
        exactly as the paper's generated code does (it restores 32, not the
        previous value).
        """
        degree = self._tuned.get(region_name, self.max_degree)
        with self._lock:
            if degree != self._current:
                self.switch_count += 1
            self._current = degree
        try:
            yield degree
        finally:
            with self._lock:
                if self._current != self.max_degree:
                    self.switch_count += 1
                self._current = self.max_degree
