"""Architecture model — the hardware facts candidate spaces are derived from.

The paper's premise is that the best directive family (loop transform +
thread count) is a function of the *target machine*, so it must be
re-derived per architecture rather than fixed when the kernel is written.
:class:`ArchSpec` is our machine description: the handful of numbers an
emit policy (core/emit.py) needs to generate a kernel's candidate space —
vector lane width, MXU dimension, VMEM capacity, cache line, memory
bandwidth, core count.

Like :class:`~repro.fleet.fingerprint.DeviceFingerprint`, an ArchSpec is
identity, not preference: it composes into BasicParams via ``bp_entries()``
(all keys carry the ``arch_`` prefix) so emitted spaces are namespaced per
architecture and fleet merges/warm starts stay correct across machines.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass
from typing import Any, Dict, Iterable, Mapping, Optional, Tuple

_PREFIX = "arch_"


@dataclass(frozen=True)
class ArchSpec:
    """One target architecture, as seen by the emit layer.

    ``vmem_bytes`` is the physical on-chip fast-memory capacity;
    :meth:`vmem_budget` is what a single kernel invocation may plan
    against (half, leaving room for double buffering + compiler slack).
    """

    name: str
    backend: str                       # jax.default_backend() family
    lane_width: int = 128              # minor-most tile dim (VPU lanes)
    sublane_width: int = 8             # second-minor tile dim for f32
    mxu_dim: int = 128                 # systolic array edge
    vmem_bytes: int = 128 * 2**20      # on-chip vector memory capacity
    cacheline_bytes: int = 256
    hbm_bandwidth: float = 819e9       # bytes/s
    peak_flops: float = 197e12
    core_count: int = 1
    grid_overhead_s: float = 1.5e-6    # fixed cost per grid program

    BP_KEYS: Tuple[str, ...] = dataclasses.field(
        default=(
            "name", "backend", "lane_width", "sublane_width", "mxu_dim",
            "vmem_bytes", "cacheline_bytes", "hbm_bandwidth", "peak_flops",
            "core_count", "grid_overhead_s",
        ),
        init=False, repr=False, compare=False,
    )

    def vmem_budget(self) -> int:
        """Bytes one kernel's working set may plan to keep resident."""
        return self.vmem_bytes // 2

    def bp_entries(self) -> Dict[str, Any]:
        """This arch as composable BP entries (``arch_`` prefix)."""
        return {_PREFIX + k: getattr(self, k) for k in self.BP_KEYS}

    @classmethod
    def from_bp_entries(cls, entries: Mapping[str, Any]) -> "ArchSpec":
        """Inverse of :meth:`bp_entries` — rebuild from a BP mapping."""
        kwargs = {}
        for k in (
            "name", "backend", "lane_width", "sublane_width", "mxu_dim",
            "vmem_bytes", "cacheline_bytes", "hbm_bandwidth", "peak_flops",
            "core_count", "grid_overhead_s",
        ):
            key = _PREFIX + k
            if key not in entries:
                raise KeyError(f"missing BP entry {key!r}")
            kwargs[k] = entries[key]
        return cls(**kwargs)


# Known architecture table. Interpret-mode hosts still emit TPU-shaped
# tiles — the arch model describes the Pallas *target*, with a VMEM
# budget sized so the interpreter's working sets stay cache-resident
# (16 MiB planning budget, matching the historical hand-tuned cap).
_CPU_HOST = ArchSpec(
    name="cpu_host",
    backend="cpu",
    lane_width=128,
    sublane_width=8,
    mxu_dim=128,
    vmem_bytes=32 * 2**20,
    cacheline_bytes=64,
    hbm_bandwidth=50e9,
    peak_flops=0.5e12,
    core_count=max(1, os.cpu_count() or 1),
    # interpreted pallas_call pays a large per-program cost, so the
    # overhead term must dominate block-count ranking on this target
    grid_overhead_s=2e-4,
)

_TPU_V5E = ArchSpec(
    name="tpu_v5e",
    backend="tpu",
    vmem_bytes=128 * 2**20,
    hbm_bandwidth=819e9,
    peak_flops=197e12,
)

_TPU_V4 = ArchSpec(
    name="tpu_v4",
    backend="tpu",
    vmem_bytes=128 * 2**20,
    hbm_bandwidth=1200e9,
    peak_flops=275e12,
)

_GPU_GENERIC = ArchSpec(
    name="gpu_generic",
    backend="gpu",
    vmem_bytes=32 * 2**20,     # smem + L2 slice a block may plan against
    cacheline_bytes=128,
    hbm_bandwidth=2000e9,
    peak_flops=100e12,
    grid_overhead_s=3e-6,
)


def detect(backend: Optional[str] = None) -> ArchSpec:
    """Resolve the ArchSpec for a backend (default: the local one)."""
    import jax

    if backend is None:
        backend = jax.default_backend()
    if backend == "tpu":
        try:
            devices = jax.devices()
            kind = devices[0].device_kind.lower()
        except Exception:  # pragma: no cover - device query race
            devices, kind = [], ""
        base = _TPU_V4 if "v4" in kind else _TPU_V5E
        return dataclasses.replace(base, core_count=max(1, len(devices)))
    if backend == "gpu":
        try:
            n = len(jax.devices())
        except Exception:  # pragma: no cover
            n = 1
        return dataclasses.replace(_GPU_GENERIC, core_count=max(1, n))
    return _CPU_HOST


_LOCAL: Dict[str, ArchSpec] = {}


def local_arch() -> ArchSpec:
    """The local backend's ArchSpec, detected once per backend."""
    import jax

    backend = jax.default_backend()
    if backend not in _LOCAL:
        _LOCAL[backend] = detect(backend)
    return _LOCAL[backend]


def default_interpret() -> bool:
    """Pallas interpret-mode default: only when no accelerator is present."""
    import jax

    return jax.default_backend() == "cpu"


def arch_bp_entries(arch: Optional[ArchSpec] = None) -> Dict[str, Any]:
    """BP entries for an arch (default: the local one) — registry glue."""
    return (arch or local_arch()).bp_entries()
