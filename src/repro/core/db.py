"""Persistent tuning database.

FIBER's layered AT only works if results survive between layers: install-time
results are consulted at before-execution time, before-execution results at
run time.  ppOpen-AT persists them in generated source; we persist JSON, so
install-layer sweeps survive across *processes* — the registry's cross-run
cache (docs/registry.md) is just a TuningDB with a path.

On-disk layout (schema v2)::

    {
      "schema_version": 2,
      "entries": {
        "<bp_fingerprint>": {
           "bp": {...},                      # human-readable BP echo
           "layer": "before_execution",
           "best": {"point": {...}, "cost": 1.2e-3},
           "trials": {"<pp_key>": cost, ...},
           "quarantined": {"<pp_key>": {...}}, # broken-measurement markers
           "history": [...],                 # run-time layer observations
           "events": [...]                   # drift/canary audit log (docs/fleet.md)
        }, ...
      },
      "db_events": [...]                     # DB-level audit (salvage recoveries)
    }

Schema v1 (the seed format) was the bare ``entries`` mapping with no
envelope; :meth:`TuningDB.load` still reads it.

Writes are atomic (tmp + rename) so a crashed AT run never corrupts the DB —
the same discipline the checkpointing layer uses.  Each flush additionally
keeps the previous good flush as ``<path>.bak``, and loading salvages from
it when the main file is torn or missing (the recovery is logged in
``db_events``).  Every flush first merges the on-disk state into the
in-memory view, so concurrent writers (e.g. two install-layer sweeps over
disjoint shape classes) union rather than clobber.
"""
from __future__ import annotations

import json
import math
import os
import tempfile
import threading
import time
from typing import Any, Dict, Mapping, Optional, Tuple

from .params import BasicParams, pp_key

SCHEMA_VERSION = 2

# Run-time observations are telemetry, not results: keep a bounded window
# per entry and flush them to disk only every Nth record, so a long-running
# server's per-group observe() neither grows the file without bound nor
# pays a full-DB rewrite on its hot path.  Trials/bests still flush on
# every write (losing one would lose a search result).
HISTORY_LIMIT = 256
RUNTIME_FLUSH_EVERY = 16

# Tuning events (demotions, canary verdicts — docs/fleet.md) are the audit
# trail, rare and precious: bounded higher-level, flushed on every record.
# Overflow is never silent: the dropped oldest prefix is folded into one
# ``events_truncated`` tombstone (count + oldest/newest timestamps) that
# merge joins canonically (see _trim_events / _join_tombstones).
EVENT_LIMIT = 256
TOMBSTONE_KIND = "events_truncated"


class _SchemaTooNew(ValueError):
    """On-disk schema newer than this code: never salvage over it."""


class TuningDB:
    SCHEMA_VERSION = SCHEMA_VERSION

    def __init__(self, path: Optional[str] = None) -> None:
        self.path = path
        self._lock = threading.Lock()
        self._data: Dict[str, Dict[str, Any]] = {}
        self._db_events: list = []
        self._disk_sig: Optional[Tuple[int, int]] = None
        self._runtime_obs = 0
        self._event_seq = 0
        if path and (os.path.exists(path) or os.path.exists(path + ".bak")):
            self._data, self._db_events = self._load_salvaging(path)
            self._disk_sig = self._file_sig(path)

    # -- persistence ---------------------------------------------------------

    @classmethod
    def load(cls, path: str) -> "TuningDB":
        """Open (or create) a DB bound to ``path``."""
        return cls(path)

    def save(self, path: Optional[str] = None) -> str:
        """Write the DB to ``path`` (defaults to the bound path) atomically.

        Binds the DB to ``path`` for subsequent auto-flushes.
        """
        with self._lock:
            if path is not None:
                self.path = path
            if not self.path:
                raise ValueError("TuningDB.save() needs a path")
            self._flush()
            return self.path

    def merge(self, other: "TuningDB | Mapping[str, Dict[str, Any]]") -> "TuningDB":
        """Union another DB's entries into this one.

        Conflict policy (concurrent writers are additive, never destructive):
        trial costs keep the *minimum* observed cost per PP point, ``best``
        keeps the lower-cost record, histories concatenate.
        """
        entries = other._data if isinstance(other, TuningDB) else dict(other)
        with self._lock:
            _merge_entries(self._data, entries)
        return self

    def export_entries(
        self, fingerprints: Optional[list] = None
    ) -> Dict[str, Dict[str, Any]]:
        """A deep, JSON-safe copy of (some) entries — the service wire form.

        This is what a fleet host *pushes* to the global tuning service
        (docs/fleet.md): the snapshot round-trips through ``json`` exactly
        like the on-disk format, and feeding it to :meth:`merge` on any
        receiver is the idempotent lattice join — safe to retry, duplicate,
        or reorder in flight.
        """
        with self._lock:
            keys = self._data.keys() if fingerprints is None else [
                fp for fp in fingerprints if fp in self._data
            ]
            return {
                fp: json.loads(json.dumps(self._data[fp], default=str))
                for fp in keys
            }

    # -- write ---------------------------------------------------------------

    def record_trial(
        self, bp: BasicParams, point: Mapping[str, Any], cost: float, layer: str
    ) -> None:
        if not math.isfinite(cost):
            # measurement guardrail: a NaN/inf trial is a broken measurement,
            # not a slow one — quarantine it instead of letting NaN poison
            # the running best (NaN comparisons are always False, so a NaN
            # cost would silently survive min/argmin logic)
            self.record_quarantine(
                bp, point, f"non-finite trial cost {cost!r}", layer=layer
            )
            return
        with self._lock:
            entry = self._entry(bp, layer)
            entry["trials"][pp_key(point)] = cost
            best = entry.get("best")
            if best is None or cost < best["cost"]:
                entry["best"] = {"point": dict(point), "cost": cost}
            self._flush()

    def record_best(
        self, bp: BasicParams, point: Mapping[str, Any], cost: float, layer: str,
        space_signature: Optional[str] = None,
    ) -> None:
        """Record the argmin of a *completed* search.

        ``record_trial`` keeps a running best for crash robustness, but only
        this call marks the entry ``final`` — the registry's zero-re-tune
        fast path (``tuned_point``) trusts finals only, so an interrupted or
        budget-capped sweep resumes instead of freezing its interim winner.

        ``space_signature`` stamps the final with the emitted-space content
        hash it was searched under (core/emit.py); ``tuned_point`` callers
        that pass their current signature then refuse finals from a
        different emission — a changed arch model re-tunes instead of
        recalling a winner from a space that no longer exists.
        """
        if not math.isfinite(cost):
            raise ValueError(
                f"record_best: non-finite cost {cost!r} for {pp_key(point)} — "
                "quarantined candidates can never become a final best"
            )
        with self._lock:
            entry = self._entry(bp, layer)
            best = {"point": dict(point), "cost": cost, "final": True}
            if space_signature is not None:
                best["space_sig"] = str(space_signature)
            entry["best"] = best
            self._flush()

    def record_quarantine(
        self,
        bp: BasicParams,
        point: Mapping[str, Any],
        reason: str,
        layer: Optional[str] = None,
    ) -> None:
        """Mark one PP point as producing broken measurements.

        A quarantined point is barred from the zero-re-tune fast path
        (:meth:`tuned_point` refuses a best that sits on it) and from
        cross-class warm starts, and :meth:`merge` unions the markers, so a
        candidate that NaN'd on one fleet host is distrusted fleet-wide.
        """
        with self._lock:
            entry = self._entry(bp, layer)
            q = entry.setdefault("quarantined", {})
            q[pp_key(point)] = {"point": dict(point), "reason": str(reason)}
            self._flush()

    def record_runtime_observation(
        self, bp: BasicParams, point: Mapping[str, Any], cost: float
    ) -> None:
        """Run-time layer: append a measured (point, cost) observation.

        History is a bounded window (``HISTORY_LIMIT``) flushed every
        ``RUNTIME_FLUSH_EVERY`` records — observations are telemetry, and a
        crash losing a few of them is harmless, unlike trials/bests.
        """
        with self._lock:
            entry = self._entry(bp, "run_time")
            hist = entry.setdefault("history", [])
            hist.append({"point": dict(point), "cost": cost})
            if len(hist) > HISTORY_LIMIT:
                del hist[: len(hist) - HISTORY_LIMIT]
            self._runtime_obs += 1
            if self._runtime_obs % RUNTIME_FLUSH_EVERY == 0:
                self._flush()

    def record_event(self, bp: BasicParams, kind: str, **payload: Any) -> Dict[str, Any]:
        """Append one audit event to this entry's tuning-event log.

        The drift/canary lifecycle (docs/fleet.md) records every transition
        — ``demoted``, ``retune_scheduled``, ``canary_start``, ``promoted``,
        ``rolled_back`` — so an operator can reconstruct why a host is
        running the candidate it is running.  Events carry a wall-clock
        ``t`` plus a per-process ``seq`` so a merged log orders
        deterministically (see :func:`_merge_entries`).
        """
        with self._lock:
            entry = self._entry(bp)
            events = entry.setdefault("events", [])
            self._event_seq += 1
            ev = {"kind": str(kind), "t": round(time.time(), 6),
                  "seq": self._event_seq, **payload}
            events.append(ev)
            if len(events) > EVENT_LIMIT:
                # never drop silently: the overflowed prefix folds into a
                # single ``events_truncated`` tombstone (count + ts range)
                entry["events"] = _trim_events(events, EVENT_LIMIT)
            self._flush()
            return dict(ev)

    def demote_best(self, bp: BasicParams) -> bool:
        """Strip the ``final`` flag from this entry's best (drift demotion).

        The record itself survives (it is still the best *measured* result)
        but ``tuned_point`` stops trusting it, so every consumer of the
        zero-re-tune fast path re-enters tuning instead of freezing a
        winner the runtime has drifted away from.  The record is marked
        ``demoted`` so *flush-time reconciliation* (this process's own
        writes racing the disk) does not resurrect the final flag from a
        stale on-disk copy of the same point.  A symmetric ``merge`` with a
        foreign DB that still holds the pre-demotion final CAN re-promote
        it — finality wins there by design, because merge must stay a
        commutative join and a foreign final is usually a genuinely newer
        completed search; if the regression persists, the drift watch
        simply demotes again (docs/fleet.md).  Returns True when a final
        best was actually demoted.
        """
        return self.demote_fingerprint(bp.fingerprint())

    def demote_fingerprint(self, fingerprint: str) -> bool:
        """:meth:`demote_best` addressed by raw DB fingerprint.

        The global tuning service and the anti-entropy sync loop
        (docs/fleet.md) propagate demotions as fingerprints — the receiver
        may never have constructed the BasicParams object, only merged the
        entry — so demotion must work from the key alone.
        """
        with self._lock:
            entry = self._data.get(fingerprint)
            best = entry.get("best") if entry else None
            if not best or not best.get("final"):
                return False
            best.pop("final", None)
            best["demoted"] = True
            self._flush()
            return True

    # -- read ----------------------------------------------------------------

    def events(self, bp: BasicParams) -> list:
        """The persisted tuning-event log for this entry (audit order)."""
        entry = self._data.get(bp.fingerprint(), {})
        return [dict(e) for e in entry.get("events", [])]

    def best_point(self, bp: BasicParams) -> Optional[Dict[str, Any]]:
        entry = self._data.get(bp.fingerprint())
        if entry and entry.get("best"):
            return dict(entry["best"]["point"])
        return None

    def tuned_point(
        self, bp: BasicParams, space_signature: Optional[str] = None
    ) -> Optional[Dict[str, Any]]:
        """The best point, only if it came from a completed search and has
        not been quarantined (a merge can carry in a foreign final whose
        point a later measurement quarantined — distrust wins).

        When the caller passes its current emitted-space ``space_signature``,
        the final must carry the *same* signature to be trusted: a final
        recorded under a different (or no) signature was searched over a
        space that no longer exists, so recalling it would freeze a winner
        the current arch model may not even emit.  ``None`` keeps the
        legacy behaviour for hand-built spaces.
        """
        entry = self._data.get(bp.fingerprint())
        if entry and entry.get("best") and entry["best"].get("final"):
            best = entry["best"]
            if (space_signature is not None
                    and best.get("space_sig") != space_signature):
                return None
            point = best["point"]
            if pp_key(point) in entry.get("quarantined", {}):
                return None
            return dict(point)
        return None

    def space_signature(self, bp: BasicParams) -> Optional[str]:
        """The emitted-space signature the recorded final was searched under."""
        entry = self._data.get(bp.fingerprint())
        if entry and entry.get("best"):
            sig = entry["best"].get("space_sig")
            return None if sig is None else str(sig)
        return None

    def invalidate_stale_final(
        self, bp: BasicParams, space_signature: str
    ) -> bool:
        """Demote a final whose emitted-space signature no longer matches.

        The arch model changed (or the emit policy did), so the recorded
        winner came from a space that is no longer the one being tuned:
        strip the ``final`` flag, drop the stale trials (they would poison
        warm starts and runtime re-ranking with points the new space may
        not contain), and append a ``space_invalidated`` audit event.
        Returns True when a stale final was actually invalidated.
        """
        with self._lock:
            entry = self._data.get(bp.fingerprint())
            best = entry.get("best") if entry else None
            if not best or not best.get("final"):
                return False
            old_sig = best.get("space_sig")
            if old_sig == space_signature:
                return False
            best.pop("final", None)
            best["demoted"] = True
            entry["trials"] = {}
            self._flush()
        self.record_event(
            bp, "space_invalidated",
            old_sig=old_sig, new_sig=space_signature,
        )
        return True

    def quarantined(self, bp: BasicParams) -> Dict[str, Dict[str, Any]]:
        """The quarantine markers for this entry (pp_key → record)."""
        entry = self._data.get(bp.fingerprint(), {})
        return {k: dict(v) for k, v in entry.get("quarantined", {}).items()}

    def is_quarantined(self, bp: BasicParams, point: Mapping[str, Any]) -> bool:
        entry = self._data.get(bp.fingerprint(), {})
        return pp_key(point) in entry.get("quarantined", {})

    def best_cost(self, bp: BasicParams) -> Optional[float]:
        entry = self._data.get(bp.fingerprint())
        if entry and entry.get("best"):
            return float(entry["best"]["cost"])
        return None

    def trial_cost(self, bp: BasicParams, point: Mapping[str, Any]) -> Optional[float]:
        entry = self._data.get(bp.fingerprint())
        if entry:
            c = entry.get("trials", {}).get(pp_key(point))
            return None if c is None else float(c)
        return None

    def trials(self, bp: BasicParams) -> Dict[str, float]:
        entry = self._data.get(bp.fingerprint(), {})
        return dict(entry.get("trials", {}))

    def history(self, bp: BasicParams) -> list:
        entry = self._data.get(bp.fingerprint(), {})
        return list(entry.get("history", []))

    def fingerprints(self) -> list:
        return list(self._data)

    def entries_matching(self, **bp_filter: Any) -> Dict[str, Dict[str, Any]]:
        """Entries whose BP echo matches every given ``key=value``.

        This is the query surface that makes composed BP dimensions —
        traffic class, mesh fingerprint — first-class: e.g.
        ``db.entries_matching(phase="prefill", mesh="data2xmodel2")``.
        """
        out: Dict[str, Dict[str, Any]] = {}
        with self._lock:
            for fp, entry in self._data.items():
                bp = entry.get("bp", {})
                if all(bp.get(k) == v for k, v in bp_filter.items()):
                    out[fp] = json.loads(json.dumps(entry))
        return out

    def nearest_tuned(
        self,
        bp: BasicParams,
        match: Tuple[str, ...] = ("kernel",),
    ) -> Optional[Dict[str, Any]]:
        """The completed search nearest to ``bp`` among sibling shape classes.

        The cross-shape-class warm start (docs/tuning.md): an untuned class
        looks up the already-tuned entry with the same value for every
        ``match`` key (same kernel by default) and the smallest BP-echo
        distance — numeric dimensions compare on a log2 scale (bucket
        distance: seq 256 is one bucket from 512, not 256 away), any other
        mismatch costs 1.  Only *final* bests qualify (an interim winner
        from a crashed sweep must not seed refinement), and the entry for
        ``bp`` itself never matches.

        Returns ``{"point", "cost", "bp", "distance"}`` or ``None``.
        """
        target = _json_roundtrip(bp.asdict())
        if any(k not in target for k in match):
            return None
        own_fp = bp.fingerprint()
        best: Optional[Dict[str, Any]] = None
        with self._lock:
            for fp, entry in self._data.items():
                if fp == own_fp:
                    continue
                rec = entry.get("best")
                if not rec or not rec.get("final"):
                    continue
                if pp_key(rec.get("point", {})) in entry.get("quarantined", {}):
                    continue  # a distrusted winner must not seed warm starts
                echo = _json_roundtrip(entry.get("bp", {}))
                if any(echo.get(k) != target[k] for k in match):
                    continue
                d = _bp_distance(target, echo, skip=match)
                if best is None or d < best["distance"]:
                    best = {
                        "point": dict(rec["point"]),
                        "cost": float(rec["cost"]),
                        "bp": echo,
                        "distance": d,
                        "fingerprint": fp,
                    }
        return best

    def traffic_classes(self) -> list:
        """Distinct serving traffic classes present in the DB, sorted by label.

        Scans BP echoes for the :meth:`TrafficClass.bp_entries` keys; entries
        without them (plain kernels) are skipped.
        """
        from .traffic import TrafficClass

        seen: Dict[str, Any] = {}
        with self._lock:
            for entry in self._data.values():
                bp = entry.get("bp", {})
                if all(k in bp for k in TrafficClass.BP_KEYS):
                    tc = TrafficClass.from_bp_entries(bp)
                    seen[tc.label] = tc
        return [seen[k] for k in sorted(seen)]

    def devices(self) -> list:
        """Distinct device fingerprints present in the DB, sorted by label.

        The fleet-merge counterpart of :meth:`traffic_classes`: after
        ``TuningDB.merge`` unions DBs from heterogeneous hosts, this lists
        which devices contributed entries (docs/fleet.md).  Entries without
        the :class:`~repro.fleet.DeviceFingerprint` BP keys (single-host
        DBs) are skipped.
        """
        from repro.fleet.fingerprint import DeviceFingerprint

        seen: Dict[str, Any] = {}
        with self._lock:
            for entry in self._data.values():
                bp = entry.get("bp", {})
                if all(k in bp for k in DeviceFingerprint.BP_KEYS):
                    df = DeviceFingerprint.from_bp_entries(bp)
                    seen[df.label] = df
        return [seen[k] for k in sorted(seen)]

    # -- internals -------------------------------------------------------------

    @staticmethod
    def _read_raw(path: str) -> Any:
        with open(path) as f:
            raw = json.load(f)
        if isinstance(raw, dict) and "schema_version" in raw:
            version = raw["schema_version"]
            if version > SCHEMA_VERSION:
                raise _SchemaTooNew(
                    f"TuningDB {path}: schema v{version} is newer than "
                    f"supported v{SCHEMA_VERSION}"
                )
        return raw

    @classmethod
    def _read_file(cls, path: str) -> Dict[str, Dict[str, Any]]:
        raw = cls._read_raw(path)
        if isinstance(raw, dict) and "schema_version" in raw:
            return dict(raw.get("entries", {}))
        return dict(raw)  # legacy v1: bare entries mapping

    @classmethod
    def _load_salvaging(cls, path: str) -> Tuple[Dict[str, Dict[str, Any]], list]:
        """Load ``path``, falling back to its ``.bak`` (the previous good
        flush) when the main file is truncated/corrupt or missing.

        A flush that died mid-write leaves either a torn main file (the
        pre-atomic-rename legacy) or — with the two-step rename — a good
        ``.bak`` and no main file.  Either way the last *completed* flush
        survives, and the recovery is logged in the persisted ``db_events``
        list so an operator can see data was salvaged (and roughly how much
        was lost).  A schema-too-new error still raises: that is an operator
        mistake, not a crash to paper over.
        """
        bak = path + ".bak"
        try:
            raw = cls._read_raw(path)
            events = list(raw.get("db_events", [])) if (
                isinstance(raw, dict) and "schema_version" in raw
            ) else []
            return cls._entries_of(raw), events
        except _SchemaTooNew:
            raise
        except (json.JSONDecodeError, OSError, TypeError, ValueError) as exc:
            err = f"{type(exc).__name__}: {exc}"
        try:
            raw = cls._read_raw(bak)
            events = list(raw.get("db_events", [])) if (
                isinstance(raw, dict) and "schema_version" in raw
            ) else []
            entries = cls._entries_of(raw)
        except _SchemaTooNew:
            raise
        except (json.JSONDecodeError, OSError, TypeError, ValueError) as bak_exc:
            # neither file readable: start empty, but leave the audit trail
            return {}, [{
                "kind": "db_salvage_failed", "t": round(time.time(), 6),
                "error": err, "bak_error": f"{type(bak_exc).__name__}: {bak_exc}",
            }]
        events.append({
            "kind": "db_salvaged", "t": round(time.time(), 6),
            "source": os.path.basename(bak), "error": err,
            "entries": len(entries),
        })
        return entries, events

    @staticmethod
    def _entries_of(raw: Any) -> Dict[str, Dict[str, Any]]:
        if isinstance(raw, dict) and "schema_version" in raw:
            return dict(raw.get("entries", {}))
        return dict(raw)  # legacy v1: bare entries mapping

    def db_events(self) -> list:
        """DB-level audit events (salvage recoveries), persisted across
        flushes — distinct from per-entry tuning events."""
        with self._lock:
            return [dict(e) for e in self._db_events]

    def _entry(self, bp: BasicParams, layer: Optional[str] = None) -> Dict[str, Any]:
        fp = bp.fingerprint()
        if fp not in self._data:
            self._data[fp] = {
                "bp": bp.asdict(), "layer": layer or "run_time", "trials": {}
            }
        if layer is not None:  # event writes must not clobber the layer tag
            self._data[fp]["layer"] = layer
        return self._data[fp]

    @staticmethod
    def _file_sig(path: str) -> Optional[Tuple[int, int]]:
        try:
            st = os.stat(path)
            return (st.st_mtime_ns, st.st_size)
        except OSError:
            return None

    def _flush(self) -> None:
        """Atomically persist; caller must hold the lock.

        If the file changed under us (a concurrent writer), its entries are
        merged in first with *our* values winning on conflict — our in-memory
        costs are fresh measurements, the disk's may be stale; the other
        writer's shape classes and unknown points are adopted wholesale.  The
        mtime/size signature skips the re-read entirely in the common
        single-writer case (no O(file) read per trial).
        """
        if not self.path:
            return
        if os.path.exists(self.path) and self._file_sig(self.path) != self._disk_sig:
            try:
                _merge_entries(self._data, self._read_file(self.path),
                               prefer_ours=True)
            except (json.JSONDecodeError, OSError):
                pass  # half-written foreign file; keep our view
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(
                    {"schema_version": SCHEMA_VERSION, "entries": self._data,
                     "db_events": self._db_events},
                    f, indent=1, default=str,
                )
            # keep the outgoing file as the last-good-flush backup before
            # promoting the new one: a crash in the window between the two
            # renames leaves a good .bak and no main file, which
            # _load_salvaging recovers (logged as a db_salvaged event)
            if os.path.exists(self.path):
                os.replace(self.path, self.path + ".bak")
            os.replace(tmp, self.path)
            self._disk_sig = self._file_sig(self.path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)


def _json_roundtrip(d: Mapping[str, Any]) -> Dict[str, Any]:
    """Normalize a BP dict the way on-disk entries are stored (tuples become
    lists, exotic scalars become strings) so live and loaded echoes compare."""
    return json.loads(json.dumps(dict(d), default=str))


def _is_number(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _bp_distance(
    a: Mapping[str, Any], b: Mapping[str, Any], skip: Tuple[str, ...] = ()
) -> float:
    """Shape-class distance between two BP echoes.

    Numeric dimensions are compared as ``|log2(a) - log2(b)|`` — one
    power-of-two bucket apart costs 1 — everything else (missing keys,
    non-numeric mismatches like dtype or phase) costs a flat 1 per key.
    """
    d = 0.0
    for key in set(a) | set(b):
        if key in skip:
            continue
        va, vb = a.get(key), b.get(key)
        if va == vb:
            continue
        if _is_number(va) and _is_number(vb):
            d += abs(
                math.log2(max(abs(va), 1e-12)) - math.log2(max(abs(vb), 1e-12))
            )
        else:
            d += 1.0
    return d


def _merge_entries(
    into: Dict[str, Dict[str, Any]],
    other: Mapping[str, Dict[str, Any]],
    prefer_ours: bool = False,
) -> None:
    """Union ``other`` into ``into``.

    Symmetric mode (``prefer_ours=False``, the public ``merge``) is a
    *deterministic lattice join* — commutative, associative, idempotent —
    because the fleet sync barrier (docs/fleet.md) must produce the same
    merged DB no matter which worker's scratch results land first:

    * trial costs keep the minimum per PP point;
    * for bests a *final* record beats a non-final one regardless of cost —
      an interim best from a crashed sweep must never displace a completed
      search's argmin; among equal finality lower cost wins, and an exact
      cost tie breaks on the records' canonical JSON so merge order cannot
      pick the winner;
    * histories and event logs become sorted unions (dedup by canonical
      JSON; events order by their ``(t, seq)`` stamps) — order-insensitive
      telemetry, deterministically arranged.

    ``prefer_ours=True`` (flush-time reconciliation) only adopts shape
    classes / trial points / bests we don't already have: our values are
    fresh measurements, the disk's may be stale.
    """
    for fp, theirs in other.items():
        ours = into.get(fp)
        if ours is None:
            into[fp] = json.loads(json.dumps(theirs, default=str))  # deep copy
            continue
        # the layer tag is informational; merge to the furthest FIBER layer
        # either writer reached so the join stays order-independent
        if _LAYER_ORDER.get(theirs.get("layer"), -1) > _LAYER_ORDER.get(
            ours.get("layer"), -1
        ):
            ours["layer"] = theirs["layer"]
        trials = ours.setdefault("trials", {})
        for key, cost in theirs.get("trials", {}).items():
            if key not in trials:
                trials[key] = cost
            elif not prefer_ours and cost < trials[key]:
                trials[key] = cost
        their_best = theirs.get("best")
        if their_best is not None and _best_beats(
            their_best, ours.get("best"), prefer_ours
        ):
            ours["best"] = json.loads(json.dumps(their_best, default=str))
        # quarantine markers union (distrust is sticky fleet-wide); on a
        # same-key conflict the canonically smaller record wins so the join
        # stays commutative
        their_q = theirs.get("quarantined", {})
        if their_q:
            q = ours.setdefault("quarantined", {})
            for key, rec in their_q.items():
                rec_copy = json.loads(json.dumps(rec, default=str))
                if key not in q:
                    q[key] = rec_copy
                elif not prefer_ours and _canon(rec_copy) < _canon(q[key]):
                    q[key] = rec_copy
        for field, key, limit in _LOG_FIELDS:
            _union_log(ours, theirs, field, limit, key)
    if not prefer_ours:
        # normalize every result entry's logs (including receiver-only and
        # freshly adopted ones): a merged DB is a canonical form, so any
        # order/grouping of the same inputs is byte-identical.  Flush-time
        # reconciliation skips this — it runs per trial write on the hot
        # tuning path and has no order-independence contract to keep.
        for entry in into.values():
            for field, key, _limit in _LOG_FIELDS:
                if entry.get(field):
                    entry[field].sort(key=key)


_LAYER_ORDER = {"install": 0, "before_execution": 1, "run_time": 2}


def _canon(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, default=str)


# (field, sort key, bound) for each append-only log a DB entry can carry.
# Events order by their (wall clock, per-process seq) stamps so a merged
# audit log reads in lifecycle order; history has no stamps and orders
# canonically (it is an order-insensitive telemetry window).
_LOG_FIELDS = (
    ("history", _canon, HISTORY_LIMIT),
    ("events",
     lambda e: (e.get("t", 0.0), e.get("seq", 0), _canon(e)),
     EVENT_LIMIT),
)


def _is_tombstone(ev: Any) -> bool:
    return isinstance(ev, dict) and ev.get("kind") == TOMBSTONE_KIND


def _join_tombstones(tombs: list) -> Optional[Dict[str, Any]]:
    """Lattice join of truncation tombstones: count takes the maximum (two
    hosts that truncated divergent copies of a shared log overlap, so
    summing would double-count), the covered timestamp range widens to
    ``[min(oldest), max(newest)]``.  The ``(t=0.0, seq=0)`` stamp pins the
    tombstone first under the event sort key (real events carry wall-clock
    stamps), so a merged log always leads with its loss marker."""
    if not tombs:
        return None
    tomb: Dict[str, Any] = {
        "kind": TOMBSTONE_KIND, "t": 0.0, "seq": 0,
        "count": max(int(t.get("count", 0)) for t in tombs),
    }
    oldest = [float(t["oldest_t"]) for t in tombs if "oldest_t" in t]
    newest = [float(t["newest_t"]) for t in tombs if "newest_t" in t]
    if oldest:
        tomb["oldest_t"] = min(oldest)
    if newest:
        tomb["newest_t"] = max(newest)
    return tomb


def _trim_events(events: list, limit: int) -> list:
    """Bound an event log to ``limit`` records without silent loss: the
    dropped oldest prefix folds into a single ``events_truncated`` tombstone
    carrying the drop count and the timestamp range it covered.  Existing
    tombstones (from earlier trims, or several carried in by a merge) are
    first joined into one; newly dropped events then *accumulate* onto it —
    a sequential fold, which is exact for the single-writer append path.
    """
    tombs = [e for e in events if _is_tombstone(e)]
    real = [e for e in events if not _is_tombstone(e)]
    tomb = _join_tombstones(tombs)
    keep = limit - 1 if (tomb is not None or len(real) > limit) else limit
    if len(real) > keep:
        drop = real[: len(real) - keep]
        real = real[len(real) - keep:]
        if tomb is None:
            tomb = {"kind": TOMBSTONE_KIND, "t": 0.0, "seq": 0, "count": 0}
        ts = [float(e.get("t", 0.0)) for e in drop]
        tomb["count"] = int(tomb.get("count", 0)) + len(drop)
        tomb["oldest_t"] = round(min([tomb.get("oldest_t", ts[0])] + ts), 6)
        tomb["newest_t"] = round(max([tomb.get("newest_t", ts[0])] + ts), 6)
    return ([tomb] if tomb is not None else []) + real


def _union_log(
    ours: Dict[str, Any],
    theirs: Mapping[str, Any],
    field: str,
    limit: int,
    key,
) -> None:
    """Sorted max-multiplicity union of one append-only log field.

    Logs are multisets (the same observation can legitimately repeat), so
    the join takes each distinct record at the *maximum* multiplicity seen
    on either side — the multiset operation that is commutative,
    associative, and idempotent — then sorts deterministically.  Plain
    concat-dedup is neither: a record duplicated on one side would survive
    or collapse depending on merge direction.

    Truncation tombstones in the events log are lifted out of the multiset
    and joined on their own lattice (:func:`_join_tombstones`) — treating
    them as ordinary records would let divergently truncated logs keep two
    conflicting loss markers.
    """
    counts: Dict[str, int] = {}
    tombs: list = []
    for log in (ours.get(field, []), theirs.get(field, [])):
        side: Dict[str, int] = {}
        for h in log:
            if field == "events" and _is_tombstone(h):
                tombs.append(h)
                continue
            c = _canon(h)
            side[c] = side.get(c, 0) + 1
        for c, n in side.items():
            counts[c] = max(counts.get(c, 0), n)
    if not counts and not tombs:
        return  # neither side has this log: don't materialize an empty one
    merged = [json.loads(c) for c, n in counts.items() for _ in range(n)]
    merged.sort(key=key)
    tomb = _join_tombstones(tombs)
    if tomb is not None:
        merged.insert(0, tomb)
    if len(merged) > limit:
        merged = (
            _trim_events(merged, limit) if field == "events"
            else merged[len(merged) - limit:]
        )
    ours[field] = merged


def _best_beats(
    theirs: Dict[str, Any], ours: Optional[Dict[str, Any]], prefer_ours: bool
) -> bool:
    if ours is None:
        return True
    if prefer_ours:
        # flush reconciliation: keep our record unless the other writer
        # actually *finished* a search we haven't (our record_best, when it
        # comes, overwrites unconditionally anyway).  A best we *demoted*
        # (drift) must not have its final flag resurrected by the stale
        # on-disk copy of the very same point.
        if ours.get("demoted") and theirs.get("point") == ours.get("point"):
            return False
        return bool(theirs.get("final")) and not bool(ours.get("final"))
    if bool(theirs.get("final")) != bool(ours.get("final")):
        return bool(theirs.get("final"))
    if theirs["cost"] != ours["cost"]:
        return theirs["cost"] < ours["cost"]
    # exact tie: break on canonical JSON so A.merge(B) == B.merge(A)
    return _canon(theirs) < _canon(ours)
