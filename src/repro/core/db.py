"""Persistent tuning database.

FIBER's layered AT only works if results survive between layers: install-time
results are consulted at before-execution time, before-execution results at
run time.  ppOpen-AT persists them in generated source; we persist JSON.

Layout (one JSON file)::

    {
      "<bp_fingerprint>": {
         "bp": {...},                      # human-readable BP echo
         "layer": "before_execution",
         "best": {"point": {...}, "cost": 1.2e-3},
         "trials": {"<pp_key>": cost, ...},
         "history": [...]                  # run-time layer observations
      }, ...
    }

Writes are atomic (tmp + rename) so a crashed AT run never corrupts the DB —
the same discipline the checkpointing layer uses.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any, Dict, Mapping, Optional

from .params import BasicParams, pp_key


class TuningDB:
    def __init__(self, path: Optional[str] = None) -> None:
        self.path = path
        self._lock = threading.Lock()
        self._data: Dict[str, Dict[str, Any]] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                self._data = json.load(f)

    # -- write ---------------------------------------------------------------

    def record_trial(
        self, bp: BasicParams, point: Mapping[str, Any], cost: float, layer: str
    ) -> None:
        with self._lock:
            entry = self._entry(bp, layer)
            entry["trials"][pp_key(point)] = cost
            best = entry.get("best")
            if best is None or cost < best["cost"]:
                entry["best"] = {"point": dict(point), "cost": cost}
            self._flush()

    def record_best(
        self, bp: BasicParams, point: Mapping[str, Any], cost: float, layer: str
    ) -> None:
        with self._lock:
            entry = self._entry(bp, layer)
            entry["best"] = {"point": dict(point), "cost": cost}
            self._flush()

    def record_runtime_observation(
        self, bp: BasicParams, point: Mapping[str, Any], cost: float
    ) -> None:
        """Run-time layer: append a measured (point, cost) observation."""
        with self._lock:
            entry = self._entry(bp, "run_time")
            entry.setdefault("history", []).append(
                {"point": dict(point), "cost": cost}
            )
            self._flush()

    # -- read ----------------------------------------------------------------

    def best_point(self, bp: BasicParams) -> Optional[Dict[str, Any]]:
        entry = self._data.get(bp.fingerprint())
        if entry and entry.get("best"):
            return dict(entry["best"]["point"])
        return None

    def best_cost(self, bp: BasicParams) -> Optional[float]:
        entry = self._data.get(bp.fingerprint())
        if entry and entry.get("best"):
            return float(entry["best"]["cost"])
        return None

    def trial_cost(self, bp: BasicParams, point: Mapping[str, Any]) -> Optional[float]:
        entry = self._data.get(bp.fingerprint())
        if entry:
            c = entry.get("trials", {}).get(pp_key(point))
            return None if c is None else float(c)
        return None

    def trials(self, bp: BasicParams) -> Dict[str, float]:
        entry = self._data.get(bp.fingerprint(), {})
        return dict(entry.get("trials", {}))

    def history(self, bp: BasicParams) -> list:
        entry = self._data.get(bp.fingerprint(), {})
        return list(entry.get("history", []))

    # -- internals -------------------------------------------------------------

    def _entry(self, bp: BasicParams, layer: str) -> Dict[str, Any]:
        fp = bp.fingerprint()
        if fp not in self._data:
            self._data[fp] = {"bp": bp.asdict(), "layer": layer, "trials": {}}
        self._data[fp]["layer"] = layer
        return self._data[fp]

    def _flush(self) -> None:
        if not self.path:
            return
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._data, f, indent=1, default=str)
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
