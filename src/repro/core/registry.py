"""Process-wide autotuned-op registry — the install layer, generalized.

ppOpen-AT's install-time layer generates every candidate once per *build* and
lets every later run select among them for free.  The seed repo had the
pieces (ATRegion, Tuner, TuningDB) but every call site wired them by hand,
so tuning results died with the process and nothing was shared between the
train and serve hot paths.  This module is the single place where tunable
ops live:

* a :class:`KernelSpec` names an op, knows how to map *call arguments* to a
  bucketed shape class (a :class:`~repro.core.params.BasicParams`), and
  builds the op's :class:`~repro.core.region.ATRegion` for one shape class;
* the :class:`Registry` holds specs and hands out
  :class:`~repro.core.autotuned.AutotunedOp` dispatchers;
* :func:`autotuned` is the one-liner call sites use::

      out = autotuned("flash_attention")(q, k, v)

  First call per (kernel, shape class): TuningDB lookup → on miss, tune with
  the configured Search under a trial budget → AOT-warm the top-k candidates
  → attach a RuntimeSelector.  Every later call (same process or a fresh one
  reading the same DB file) performs zero cost evaluations.

The default registry lazily imports ``repro.kernels`` on a name miss so the
five Pallas kernels self-register without core depending on them at import
time.  Set ``REPRO_TUNING_DB`` to persist tuning across runs by default.
"""
from __future__ import annotations

import importlib
import os
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

from .db import TuningDB
from .params import BasicParams
from .region import ATRegion
from .search import Search
from .traffic import TrafficClass


@dataclass(frozen=True)
class KernelSpec:
    """One tunable op: shape-class extraction + region factory.

    ``shape_class(*args, **kwargs)`` maps a concrete call to the BP that keys
    the tuning database (bucket dimensions that don't affect the candidate
    family — batch size, number of heads — and keep the ones that do).
    ``make_region(bp)`` builds the candidate family for that class.
    ``cost_factory(region, bp, args, kwargs)``, when given, returns the cost
    function the tuner minimizes (e.g. an analytic model for install-time AT
    on a host without the target hardware); the default is wall-clock.
    ``traffic_class(*args, **kwargs)``, when given, maps the call to a
    :class:`~repro.core.traffic.TrafficClass`; its entries extend the shape
    class BP, so each traffic class tunes — and hot-swaps — independently
    (docs/serving.md).
    ``prescreen_factory``, when given, opts the op into the staged search
    pipeline (docs/tuning.md): it returns the *cheap* stage-1 cost (analytic
    model or compile-only roofline — :func:`repro.core.cost.roofline_prescreen`
    is the generic choice) that ranks the full candidate space so only the
    top-k survivors pay a measured evaluation; returning ``None`` falls back
    to single-stage search for that shape class.
    """

    name: str
    make_region: Callable[[BasicParams], ATRegion]
    shape_class: Callable[..., BasicParams]
    cost_factory: Optional[
        Callable[[ATRegion, BasicParams, tuple, dict], Callable[[Mapping[str, Any]], float]]
    ] = None
    tags: Tuple[str, ...] = ()
    traffic_class: Optional[Callable[..., "TrafficClass"]] = None
    prescreen_factory: Optional[
        Callable[[ATRegion, BasicParams, tuple, dict], Optional[Callable[[Mapping[str, Any]], float]]]
    ] = None


class Registry:
    def __init__(self, providers: Tuple[str, ...] = ()) -> None:
        self._specs: Dict[str, KernelSpec] = {}
        self._ops: Dict[str, Any] = {}
        self._providers = tuple(providers)
        self._imported_providers = False
        self._lock = threading.Lock()
        self._default_db: Optional[TuningDB] = None

    # -- registration --------------------------------------------------------

    def register(self, spec: KernelSpec, replace: bool = False) -> KernelSpec:
        with self._lock:
            if spec.name in self._specs and not replace:
                raise ValueError(
                    f"kernel {spec.name!r} already registered; pass replace=True "
                    "to overwrite"
                )
            self._specs[spec.name] = spec
            self._ops.pop(spec.name, None)  # drop stale dispatcher
        return spec

    def get(self, name: str) -> KernelSpec:
        if name not in self._specs:
            self._import_providers()
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(
                f"no registered kernel {name!r}; known: {sorted(self._specs)}"
            ) from None

    def names(self, tag: Optional[str] = None) -> Tuple[str, ...]:
        self._import_providers()
        return tuple(
            sorted(
                n for n, s in self._specs.items() if tag is None or tag in s.tags
            )
        )

    def specs(self, tag: Optional[str] = None) -> Tuple[KernelSpec, ...]:
        return tuple(self.get(n) for n in self.names(tag))

    # -- default persistent DB -----------------------------------------------

    def default_db(self) -> TuningDB:
        """The registry-wide cross-run cache.

        ``REPRO_TUNING_DB=<path>`` makes it persistent; otherwise it is
        in-memory (still shared by every op in the process).
        """
        with self._lock:
            if self._default_db is None:
                self._default_db = TuningDB(os.environ.get("REPRO_TUNING_DB"))
            return self._default_db

    def set_default_db(self, db: TuningDB) -> None:
        with self._lock:
            self._default_db = db
            self._ops.clear()  # ops cache selectors/states against the old DB

    # -- dispatch ------------------------------------------------------------

    def op(self, name: str, **options: Any):
        """An :class:`AutotunedOp` for ``name``.

        With no options the op is cached per name (the process-wide handle
        call sites share); with options a fresh, uncached op is built so
        callers can pin their own DB / search / budget.
        """
        from .autotuned import AutotunedOp  # local import: avoids a cycle

        if options:
            return AutotunedOp(self.get(name), registry=self, **options)
        with self._lock:
            cached = self._ops.get(name)
        if cached is not None:
            return cached
        op = AutotunedOp(self.get(name), registry=self)
        with self._lock:
            return self._ops.setdefault(name, op)

    # -- internals -----------------------------------------------------------

    def _import_providers(self) -> None:
        if self._imported_providers:
            return
        self._imported_providers = True
        for mod in self._providers:
            try:
                importlib.import_module(mod)
            except ImportError:  # pragma: no cover - missing optional provider
                pass


# The process-wide registry.  ``repro.kernels`` registers the five Pallas
# kernels on import; the lazy provider makes `autotuned("flash_attention")`
# work without the caller importing repro.kernels first.
REGISTRY = Registry(providers=("repro.kernels",))


def register_kernel(spec: KernelSpec, replace: bool = False) -> KernelSpec:
    return REGISTRY.register(spec, replace=replace)


def get_kernel(name: str) -> KernelSpec:
    return REGISTRY.get(name)


def kernel_names(tag: Optional[str] = None) -> Tuple[str, ...]:
    return REGISTRY.names(tag)


def autotuned(name: str, **options: Any):
    """The registry front door: ``autotuned("ssm_scan")(x, dt, A, B, C, D)``."""
    return REGISTRY.op(name, **options)
