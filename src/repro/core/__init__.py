"""repro.core — the paper's contribution: FIBER-layered autotuning for JAX.

Public API:

* :class:`~repro.core.params.BasicParams` / :class:`~repro.core.params.ParamSpace`
  / :class:`~repro.core.params.PerfParam` — FIBER BP/PP vocabulary.
* :class:`~repro.core.region.ATRegion` — the ``region start/end`` bracket.
* :class:`~repro.core.exchange.LoopNest` /
  :func:`~repro.core.exchange.enumerate_exchange_variants` — the Exchange +
  LoopFusion candidate generator (paper §III).
* :class:`~repro.core.degree.DegreeController` — dynamic parallelism degree
  (paper §IV, ``omp_set_num_threads`` analogue).
* :class:`~repro.core.tuner.Tuner` / :class:`~repro.core.tuner.RuntimeSelector`
  — the three-layer tuner.
* cost functions in :mod:`repro.core.cost`; searches in :mod:`repro.core.search`;
  persistence in :mod:`repro.core.db`.
* :func:`~repro.core.registry.autotuned` /
  :class:`~repro.core.registry.KernelSpec` /
  :class:`~repro.core.autotuned.AutotunedOp` — the process-wide autotuned-op
  registry with a persistent cross-run cache (docs/registry.md).
* :class:`~repro.core.program.ProgramSpec` /
  :class:`~repro.core.program.JointSearch` — whole-program joint autotuning
  over composed regions, measured end to end (docs/program.md).

The fleet control plane — device fingerprints, sharded N-worker search,
drift-aware canary re-tuning — lives in :mod:`repro.fleet` (docs/fleet.md)
and layers on this package without adding anything to its import cost.
"""
from .arch import ArchSpec, arch_bp_entries, default_interpret, local_arch
from .cost import (
    FX100,
    TPU_V5E,
    AdaptiveWallClockCost,
    CompiledRooflineCost,
    CostFunction,
    HardwareSpec,
    MemoryCost,
    RooflineTerms,
    WallClockCost,
    collective_bytes_from_hlo,
    roofline_from_compiled,
    roofline_prescreen,
)
from .db import TuningDB
from .degree import DegreeController
from .emit import (
    EmitPolicy,
    EmittedSpace,
    TileDim,
    TilePolicy,
    hint_prescreen,
    pow2_ladder,
    space_signature,
)
from .exchange import (
    GKV_FIGURE_OF_VARIANT,
    ExchangeVariant,
    LoopNest,
    enumerate_exchange_variants,
)
from .autotuned import AutotunedOp, OpState
from .params import (
    BasicParams,
    EmptySpace,
    ParamSpace,
    PerfParam,
    pp_key,
    project_point,
)
from .program import (
    JointSearch,
    ProgramMember,
    ProgramResult,
    ProgramSpec,
    flatten_assignment,
    unflatten_point,
)
from .region import ATRegion
from .registry import (
    REGISTRY,
    KernelSpec,
    Registry,
    autotuned,
    get_kernel,
    kernel_names,
    register_kernel,
)
from .search import (
    CoordinateDescent,
    ExhaustiveSearch,
    SearchResult,
    StagedSearch,
    SuccessiveHalving,
    Trial,
    default_prescreen_k,
)
from .traffic import PHASES, TrafficClass, bucket_pow2
from .tuner import Tuner, RuntimeSelector

__all__ = [
    "AutotunedOp",
    "OpState",
    "KernelSpec",
    "Registry",
    "REGISTRY",
    "autotuned",
    "get_kernel",
    "kernel_names",
    "register_kernel",
    "BasicParams",
    "EmptySpace",
    "ParamSpace",
    "PerfParam",
    "pp_key",
    "project_point",
    "ATRegion",
    "ArchSpec",
    "arch_bp_entries",
    "default_interpret",
    "local_arch",
    "EmitPolicy",
    "EmittedSpace",
    "TileDim",
    "TilePolicy",
    "hint_prescreen",
    "pow2_ladder",
    "space_signature",
    "LoopNest",
    "ExchangeVariant",
    "enumerate_exchange_variants",
    "GKV_FIGURE_OF_VARIANT",
    "DegreeController",
    "TrafficClass",
    "PHASES",
    "bucket_pow2",
    "ProgramSpec",
    "ProgramMember",
    "ProgramResult",
    "JointSearch",
    "flatten_assignment",
    "unflatten_point",
    "Tuner",
    "RuntimeSelector",
    "TuningDB",
    "CostFunction",
    "WallClockCost",
    "AdaptiveWallClockCost",
    "CompiledRooflineCost",
    "MemoryCost",
    "roofline_prescreen",
    "RooflineTerms",
    "HardwareSpec",
    "TPU_V5E",
    "FX100",
    "collective_bytes_from_hlo",
    "roofline_from_compiled",
    "ExhaustiveSearch",
    "CoordinateDescent",
    "SuccessiveHalving",
    "StagedSearch",
    "default_prescreen_k",
    "SearchResult",
    "Trial",
]
