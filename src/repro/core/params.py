"""FIBER parameter vocabulary (paper §II.A).

FIBER defines autotuning as::

    AT = argmin_{PP} cost(PP | BP)

at each of three layers (install / before-execution / run-time), where

* **BP** (basic parameter set) — facts fixed by the user / environment:
  problem size, mesh shape, max parallelism degree.  BP is *identity*: the
  tuning database is keyed by a BP fingerprint.
* **PP** (performance parameter set) — the knobs the tuner may move: loop
  variant, parallelism degree, block shape, sharding rule, ...

This module gives both sets a concrete, hashable, JSON-serializable form.
"""
from __future__ import annotations

import hashlib
import itertools
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, Mapping, Sequence, Tuple


# ---------------------------------------------------------------------------
# Basic parameter set (BP)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BasicParams:
    """The FIBER basic parameter set: everything the tuner must NOT change.

    ``entries`` maps names to plain values (ints, strs, tuples).  Examples:
    ``{"arch": "gkv_exb", "iv": 16, "iz": 16, "mx": 128, "my": 65}`` or
    ``{"arch": "llama3-405b", "shape": "train_4k", "mesh": "pod16x16"}``.
    """

    entries: Tuple[Tuple[str, Any], ...]

    @classmethod
    def make(cls, **kwargs: Any) -> "BasicParams":
        return cls(tuple(sorted((k, _freeze(v)) for k, v in kwargs.items())))

    def __getitem__(self, key: str) -> Any:
        for k, v in self.entries:
            if k == key:
                return v
        raise KeyError(key)

    def get(self, key: str, default: Any = None) -> Any:
        try:
            return self[key]
        except KeyError:
            return default

    def asdict(self) -> Dict[str, Any]:
        return dict(self.entries)

    def with_entries(self, **extra: Any) -> "BasicParams":
        """A new BP with ``extra`` merged in (later keys win).

        This is how orthogonal BP dimensions compose: a kernel's shape class
        extended with its traffic class and mesh fingerprint stays one flat,
        fingerprintable key.
        """
        merged = dict(self.entries)
        merged.update(extra)
        return BasicParams.make(**merged)

    def fingerprint(self) -> str:
        """Stable hash used as the tuning-database key (computed once)."""
        fp = getattr(self, "_fp", None)
        if fp is None:
            blob = json.dumps(self.entries, sort_keys=True, default=str)
            fp = hashlib.sha256(blob.encode()).hexdigest()[:16]
            object.__setattr__(self, "_fp", fp)  # frozen dataclass memo
        return fp

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        inner = ", ".join(f"{k}={v!r}" for k, v in self.entries)
        return f"BP({inner})"


def _freeze(v: Any) -> Any:
    if isinstance(v, list):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    return v


# ---------------------------------------------------------------------------
# Performance parameter set (PP)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PerfParam:
    """One tunable knob: a name and its finite candidate domain.

    The paper's two PPs are ``loop_variant`` (Figs 1-10) and ``num_threads``
    (1..32).  Ours add block shapes, sharding rules, remat policies, ...
    Domains are always finite and explicit — ppOpen-AT generates *all*
    candidates ahead of time, and so do we.
    """

    name: str
    domain: Tuple[Any, ...]

    def __post_init__(self) -> None:
        if len(self.domain) == 0:
            raise ValueError(f"PerfParam {self.name!r} has an empty domain")
        if len(set(map(repr, self.domain))) != len(self.domain):
            raise ValueError(f"PerfParam {self.name!r} has duplicate candidates")


class EmptySpace(ValueError):
    """A ParamSpace whose constraint rejects every cartesian point.

    Raised at construction (and by ``default()``/``shard()`` as a backstop)
    so an over-tight constraint — e.g. an emitted VMEM budget smaller than
    any candidate tile — fails where the space is built, naming the
    constraint and the architecture values, instead of surfacing as a
    confusing downstream search failure.
    """

    def __init__(self, message: str, label=None, context=None) -> None:
        super().__init__(message)
        self.label = label
        self.context = dict(context or {})


# Constructor-time emptiness is only provable by enumerating the whole
# cartesian product; past this many probes we defer to default()/points().
_EMPTY_PROBE_CAP = 4096


class ParamSpace:
    """The cartesian PP space plus an optional feasibility predicate.

    ``constraint(point) -> bool`` prunes infeasible combinations (e.g. a
    Pallas block shape whose VMEM footprint exceeds budget — the TPU version
    of "don't give each thread 2 iterations").  ``label``/``context`` name
    the space and the values its constraint was derived from; both ride
    along on the :class:`EmptySpace` error when nothing survives.
    """

    def __init__(
        self, params: Sequence[PerfParam], constraint=None,
        label: str = None, context: Mapping[str, Any] = None,
    ) -> None:
        names = [p.name for p in params]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate PerfParam names: {names}")
        self.params: Tuple[PerfParam, ...] = tuple(params)
        self.constraint = constraint
        self.label = label
        self.context = dict(context or {})
        self._members: Any = None  # explicit enumeration (see subset())
        if constraint is not None and self.size() <= _EMPTY_PROBE_CAP:
            for _ in self.points():
                break
            else:
                raise self._empty_error()

    def _empty_error(self) -> "EmptySpace":
        what = self.label or "ParamSpace"
        msg = f"{what}: constraint rejects all {self.size()} candidate points"
        if self.context:
            ctx = ", ".join(f"{k}={v}" for k, v in sorted(self.context.items()))
            msg += f" ({ctx})"
        return EmptySpace(msg, label=self.label, context=self.context)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(p.name for p in self.params)

    def size(self) -> int:
        n = 1
        for p in self.params:
            n *= len(p.domain)
        return n

    def feasible(self, point: Mapping[str, Any]) -> bool:
        return self.constraint is None or bool(self.constraint(dict(point)))

    def points(self) -> Iterator[Dict[str, Any]]:
        """Every feasible PP assignment (exhaustive enumeration).

        A subset space enumerates its explicit member list instead,
        preserving the order it was built with (prescreen rank order).
        """
        if self._members is not None:
            for point in self._members:
                yield dict(point)
            return
        domains = [p.domain for p in self.params]
        for combo in itertools.product(*domains):
            point = dict(zip(self.names, combo))
            if self.feasible(point):
                yield point

    def default(self) -> Dict[str, Any]:
        """First feasible point — the untuned baseline."""
        for point in self.points():
            return point
        raise self._empty_error()

    def subset(self, points: Sequence[Mapping[str, Any]]) -> "ParamSpace":
        """A space restricted to an explicit candidate list.

        The staged pipeline's measured-finals stage runs a full
        :class:`~repro.core.search.Search` over prescreen survivors only;
        the subset keeps the parent's params (so ``validate`` still checks
        domains) but enumeration and feasibility are membership in
        ``points``.
        """
        members = [dict(p) for p in points]
        if not members:
            raise ValueError("ParamSpace.subset() needs at least one point")
        keys = {pp_key(p) for p in members}
        parent_feasible = self.feasible
        sub = ParamSpace(
            self.params,
            constraint=lambda p: pp_key(p) in keys and parent_feasible(p),
        )
        sub._members = members  # ordered enumeration (prescreen rank order)
        return sub

    def shard(self, n: int, policy: str = "stride") -> "Tuple[ParamSpace, ...]":
        """Deterministically partition this space into ≤ ``n`` subset spaces.

        The fleet shard protocol (docs/fleet.md): every feasible point lands
        in exactly one shard, assignment depends only on the enumeration
        order (itself deterministic), and the union of shard argmins is the
        global argmin — which is what makes the N-worker fleet search return
        the single-process winner by construction.

        ``policy="stride"`` deals points round-robin (shard ``i`` takes
        enumeration indices ``i, i+n, ...``) so heavy-tail spaces balance;
        ``policy="block"`` gives each shard one contiguous run, keeping a
        prescreen's rank order intact within a shard.  Shards that would be
        empty (fewer points than workers) are dropped, so the result may
        have fewer than ``n`` members — never an empty subset space.
        """
        if n < 1:
            raise ValueError(f"shard count must be >= 1, got {n}")
        if policy not in ("stride", "block"):
            raise ValueError(f"unknown shard policy {policy!r}; "
                             "expected 'stride' or 'block'")
        points = [dict(p) for p in self.points()]
        if not points:
            raise self._empty_error()
        if policy == "stride":
            groups = [points[i::n] for i in range(n)]
        else:
            size = -(-len(points) // n)  # ceil division: first shards fill up
            groups = [points[i * size : (i + 1) * size] for i in range(n)]
        return tuple(self.subset(g) for g in groups if g)

    def neighbours(self, point: Mapping[str, Any]) -> Iterator[Dict[str, Any]]:
        """Coordinate-move neighbourhood (for hillclimb search): all feasible
        points differing from ``point`` in exactly one parameter."""
        for p in self.params:
            for candidate in p.domain:
                if candidate == point[p.name]:
                    continue
                moved = dict(point)
                moved[p.name] = candidate
                if self.feasible(moved):
                    yield moved

    def validate(self, point: Mapping[str, Any]) -> None:
        for p in self.params:
            if p.name not in point:
                raise KeyError(f"PP point missing {p.name!r}")
            if point[p.name] not in p.domain:
                raise ValueError(
                    f"{point[p.name]!r} not in domain of {p.name!r}: {p.domain}"
                )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        inner = ", ".join(f"{p.name}[{len(p.domain)}]" for p in self.params)
        return f"ParamSpace({inner}, size={self.size()})"


def pp_key(point: Mapping[str, Any]) -> str:
    """Canonical JSON key for one PP assignment (DB storage)."""
    return json.dumps({k: _freeze(v) for k, v in sorted(point.items())}, default=str)


def project_point(
    space: ParamSpace, point: Mapping[str, Any]
) -> "Dict[str, Any] | None":
    """Project a (possibly foreign-shape-class) PP point onto ``space``.

    Cross-shape-class warm starts reuse a neighbouring class's winner, but
    that class's domains can differ (block candidates divide *its* seq/width,
    not ours).  Per parameter: keep an in-domain value, snap a numeric value
    to the nearest numeric domain candidate, and fall back to the space
    default's value for anything else (missing params, non-numeric
    mismatches).  Returns ``None`` when the projected point is infeasible —
    a seed must never smuggle an invalid candidate past the constraint.
    """
    try:
        default = space.default()
    except ValueError:
        return None
    projected: Dict[str, Any] = {}
    for param in space.params:
        v = point.get(param.name, default[param.name])
        # compare frozen: a disk-loaded seed has JSON lists where the domain
        # has tuples, and that must still count as an exact match
        fv = _freeze(v)
        match = next((d for d in param.domain if _freeze(d) == fv), None)
        if match is not None:
            projected[param.name] = match
            continue
        numeric = [
            d for d in param.domain
            if isinstance(d, (int, float)) and not isinstance(d, bool)
        ]
        if numeric and isinstance(v, (int, float)) and not isinstance(v, bool):
            projected[param.name] = min(numeric, key=lambda d: abs(d - v))
        else:
            projected[param.name] = default[param.name]
    return projected if space.feasible(projected) else None
