"""FIBER cost-definition functions (paper §II.A).

The cost definition function maps a PP assignment to a scalar cost with BP
fixed.  The paper uses measured execution time on the FX100.  We provide:

* :class:`WallClockCost` — measured wall time of a compiled candidate.  Used
  for the paper-reproduction experiments (GKV / Seism3D run on this host) and
  for the FIBER *run-time* layer.
* :class:`CompiledRooflineCost` — the TPU-targeted analytic cost: lower +
  compile the candidate (no execution, no allocation), read
  ``cost_analysis()`` FLOPs/bytes and parse collective bytes out of the HLO,
  and return ``max(compute, memory, collective)`` seconds under the roofline
  model.  Used for the *before-execution* layer where the target hardware is
  not the host (this container is CPU; the target is TPU v5e).
* :class:`MemoryCost` — peak bytes/device from ``memory_analysis()``; FIBER
  explicitly names memory as an admissible cost.
"""
from __future__ import annotations

import math
import os
import re
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import jax


# ---------------------------------------------------------------------------
# Target-hardware model (TPU v5e, per assignment)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float        # FLOP/s per chip (bf16)
    hbm_bandwidth: float     # bytes/s per chip
    ici_bandwidth: float     # bytes/s per link
    hbm_bytes: float         # HBM capacity per chip
    vmem_bytes: float        # VMEM per core


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024 * 1024,  # v5e VMEM is ~128MiB/core budgeted conservatively
)

# The paper's machine, for the reproduction benchmarks' narrative only.
FX100 = HardwareSpec(
    name="fujitsu_fx100",
    peak_flops=1.1264e12,
    hbm_bandwidth=480e9 / 2,
    ici_bandwidth=12.5e9,
    hbm_bytes=32 * 1024**3,
    vmem_bytes=24 * 1024**2,
)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\)|[\w\[\],<>{}: ])+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)

_SHAPE_RE = re.compile(r"(pred|[usbf]\d+(?:e\d+m\d+)?)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1,
}


def _shape_bytes(shape_text: str) -> int:
    """Sum byte sizes of every typed array shape in an HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        base = _DTYPE_BYTES.get(dtype)
        if base is None:
            m = re.match(r"[usbf]?f?(\d+)", dtype)
            base = int(m.group(1)) // 8 if m else 4
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += base * n
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Parse an HLO dump and sum result sizes of every collective op.

    ``cost_analysis()`` does not report collective traffic, so we walk the
    HLO text.  Returns per-op-kind byte totals; ``sum(result.values())`` is
    the collective_bytes roofline numerator.  ``-start``/``-done`` pairs are
    counted once (we match the ``-start`` form or the plain form; ``-done``
    lines do not re-list operand shapes in the same way but are filtered by
    only counting lines that declare a result type).
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        if "-done(" in line:
            continue  # counted at -start
        nbytes = _shape_bytes(m.group(1))
        if nbytes == 0:
            continue
        out[kind] = out.get(kind, 0) + nbytes
    return out


@dataclass
class RooflineTerms:
    """The three roofline terms, in seconds, for one compiled candidate."""

    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    per_device_hbm_bytes: float = 0.0
    collective_breakdown: Dict[str, int] = field(default_factory=dict)

    @property
    def total_s(self) -> float:
        """Roofline lower bound: terms overlap perfectly, so cost = max."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def asdict(self) -> Dict[str, Any]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "total_s": self.total_s,
            "bottleneck": self.bottleneck,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "per_device_hbm_bytes": self.per_device_hbm_bytes,
            "collective_breakdown": dict(self.collective_breakdown),
        }


# Ring-model execution factors: an all-reduce moves ~2× its payload per
# device ((k-1)/k reduce-scatter + (k-1)/k all-gather); others ~1×.
_COLLECTIVE_EXEC_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def roofline_from_compiled(
    lowered: Any,
    compiled: Any,
    n_chips: int,
    hw: HardwareSpec = TPU_V5E,
) -> RooflineTerms:
    """Derive the three roofline terms from a lowered+compiled jit artifact.

    * compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    * memory     = HLO_bytes / (chips × HBM_bw)
    * collective = collective_bytes / (chips × link_bw), all-reduce weighted
      2× (ring model).

    The SPMD module is per-device, so per-device cost × n_chips = the global
    HLO_* numerators; the division by chips then cancels back to per-device
    time — i.e. the assignment's formula evaluated exactly, reported with
    global numerators.

    FLOPs/bytes/collectives come from :mod:`repro.core.hlo_analysis`, which
    multiplies ``while`` bodies by their known trip counts —
    ``compiled.cost_analysis()`` counts scan bodies once and is wrong by the
    layer count on scan-over-layers models (measured 6× on a 6-layer toy).
    """
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()

    from .hlo_analysis import analyze_hlo_text

    per_dev = analyze_hlo_text(hlo)
    flops_dev = per_dev.flops
    bytes_dev = per_dev.bytes
    coll = {k: float(v) for k, v in per_dev.collectives.items()}
    coll_bytes_dev = float(sum(coll.values()))
    coll_exec_dev = float(
        sum(_COLLECTIVE_EXEC_FACTOR.get(k, 1.0) * v for k, v in coll.items())
    )

    mem_per_dev = 0.0
    try:
        ma = compiled.memory_analysis()
        mem_per_dev = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        pass

    return RooflineTerms(
        compute_s=flops_dev / hw.peak_flops,
        memory_s=bytes_dev / hw.hbm_bandwidth,
        collective_s=coll_exec_dev / hw.ici_bandwidth,
        hlo_flops=flops_dev * n_chips,
        hlo_bytes=bytes_dev * n_chips,
        collective_bytes=coll_bytes_dev * n_chips,
        per_device_hbm_bytes=mem_per_dev,
        collective_breakdown={k: int(v * n_chips) for k, v in coll.items()},
    )


# ---------------------------------------------------------------------------
# Cost functions
# ---------------------------------------------------------------------------


def score_points_concurrently(
    score_one: Callable[[Mapping[str, Any]], float],
    points: Sequence[Mapping[str, Any]],
    max_workers: Optional[int] = None,
) -> List[float]:
    """Score candidates on a bounded thread pool; failures score ``inf``.

    The single shared policy for prescreen fan-out (XLA lowering/compilation
    release the GIL): `CompiledRooflineCost.score_many` and
    `StagedSearch`'s generic prescreen both delegate here, so the worker
    bound and the exclude-don't-fail error handling cannot diverge.
    """
    workers = max_workers or min(8, os.cpu_count() or 2)

    def score(p: Mapping[str, Any]) -> float:
        try:
            return float(score_one(p))
        except Exception:
            return math.inf

    if workers <= 1 or len(points) <= 1:
        return [score(p) for p in points]
    with ThreadPoolExecutor(max_workers=workers) as pool:
        return list(pool.map(score, points))


class CostFunction:
    """cost(PP point) -> float seconds (lower is better)."""

    def __call__(self, point: Mapping[str, Any]) -> float:  # pragma: no cover
        raise NotImplementedError


class WallClockCost(CostFunction):
    """Measured wall time of ``build(point)() `` — the paper's cost function.

    ``build`` maps a PP point to a zero-arg callable that runs the candidate
    once (already closed over its inputs, already jitted if appropriate).
    Measures ``repeats`` timed runs after ``warmup`` untimed ones and returns
    the minimum (standard practice to suppress OS noise; the paper runs 1000
    iterations for the same reason).
    """

    def __init__(
        self,
        build: Callable[[Mapping[str, Any]], Callable[[], Any]],
        warmup: int = 2,
        repeats: int = 5,
        inner_iters: int = 1,
    ) -> None:
        self.build = build
        self.warmup = warmup
        self.repeats = repeats
        self.inner_iters = inner_iters

    def __call__(self, point: Mapping[str, Any]) -> float:
        fn = self.build(point)
        for _ in range(self.warmup):
            _block(fn())
        best = math.inf
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            for _ in range(self.inner_iters):
                out = fn()
            _block(out)
            best = min(best, (time.perf_counter() - t0) / self.inner_iters)
        return best


class AdaptiveWallClockCost(CostFunction):
    """Measured wall time with variance-aware adaptive repeats.

    Fixed-repeat timing spends the same budget on a candidate that is 10×
    off the incumbent as on one within noise of it.  This cost times each
    point until its confidence interval separates from the best cost seen so
    far (the *incumbent*), then stops:

    * after ``min_repeats`` timed runs, a point whose best time is already
      ``rel_margin`` above the incumbent is abandoned immediately;
    * otherwise timing continues until ``best ± halfwidth`` (a
      ``confidence``-sigma standard-error interval) no longer straddles the
      incumbent, or ``max_repeats`` is reached.

    ``supports_budget`` lets :class:`~repro.core.search.SuccessiveHalving`
    pass its rung budget through: ``cost(point, budget)`` scales the repeat
    cap.  ``timed_runs`` / ``measured_points`` expose the totals the
    tuning-throughput benchmark reports.
    """

    supports_budget = True

    def __init__(
        self,
        build: Callable[[Mapping[str, Any]], Callable[[], Any]],
        warmup: int = 1,
        min_repeats: int = 1,
        max_repeats: int = 4,
        rel_margin: float = 0.25,
        confidence: float = 2.0,
    ) -> None:
        self.build = build
        self.warmup = warmup
        self.min_repeats = max(1, min_repeats)
        self.max_repeats = max(self.min_repeats, max_repeats)
        self.rel_margin = rel_margin
        self.confidence = confidence
        self.incumbent = math.inf
        self.timed_runs = 0
        self.measured_points = 0

    def __call__(
        self, point: Mapping[str, Any], budget: Optional[int] = None
    ) -> float:
        fn = self.build(point)
        for _ in range(self.warmup):
            _block(fn())
        cap = self.max_repeats * max(1, int(budget or 1))
        times: List[float] = []
        while len(times) < cap:
            t0 = time.perf_counter()
            out = fn()
            _block(out)
            times.append(time.perf_counter() - t0)
            self.timed_runs += 1
            if len(times) < self.min_repeats:
                continue
            best = min(times)
            if not math.isfinite(self.incumbent):
                if len(times) >= self.min_repeats + 1:
                    break  # first point: just establish the incumbent
                continue
            if best > self.incumbent * (1.0 + self.rel_margin):
                break  # clearly worse: stop paying for precision
            if len(times) >= 2:
                mean = sum(times) / len(times)
                var = sum((t - mean) ** 2 for t in times) / (len(times) - 1)
                halfwidth = self.confidence * math.sqrt(var / len(times))
                if (best + halfwidth < self.incumbent
                        or best - halfwidth > self.incumbent):
                    break  # CI separated from the incumbent either way
        cost = min(times)
        self.measured_points += 1
        self.incumbent = min(self.incumbent, cost)
        return cost


class CompiledRooflineCost(CostFunction):
    """Lower+compile the candidate and score it with the roofline model.

    ``lower`` maps a PP point to a ``jax.stages.Lowered`` (the caller does
    ``jax.jit(step, in_shardings=...).lower(*specs)`` with whatever shardings
    the point dictates).  No device execution ever happens: this is FIBER
    before-execution AT with the hardware absent.
    """

    def __init__(
        self,
        lower: Callable[[Mapping[str, Any]], Any],
        n_chips: int,
        hw: HardwareSpec = TPU_V5E,
        keep_compiled: bool = False,
    ) -> None:
        self.lower = lower
        self.n_chips = n_chips
        self.hw = hw
        self.last_terms: Optional[RooflineTerms] = None
        self.terms_by_point: Dict[str, RooflineTerms] = {}
        # keep_compiled retains each candidate's compiled executable so a
        # downstream measured stage can execute it instead of recompiling
        # (the staged pipeline's prescreen already paid the compile cost).
        # The executables are argument-shape-specialized, so they are valid
        # only for the example arguments the prescreen lowered against.
        self.keep_compiled = keep_compiled
        self.compiled_by_point: Dict[str, Any] = {}

    def __call__(self, point: Mapping[str, Any]) -> float:
        from .params import pp_key

        lowered = self.lower(point)
        compiled = lowered.compile()
        terms = roofline_from_compiled(lowered, compiled, self.n_chips, self.hw)
        self.last_terms = terms
        key = pp_key(point)
        self.terms_by_point[key] = terms
        if self.keep_compiled:
            self.compiled_by_point[key] = compiled
        return terms.total_s

    def score_many(
        self,
        points: Sequence[Mapping[str, Any]],
        max_workers: Optional[int] = None,
    ) -> List[float]:
        """Score candidates concurrently on a bounded thread pool.

        Lowering and XLA compilation release the GIL, so independent
        candidates compile in parallel — this is the staged pipeline's
        prescreen fan-out (docs/tuning.md).  Per-point failures score
        ``inf`` rather than aborting the batch.
        """
        return score_points_concurrently(self, points, max_workers)


class MemoryCost(CostFunction):
    """Peak per-device bytes of the compiled candidate (FIBER's memory cost)."""

    def __init__(self, lower: Callable[[Mapping[str, Any]], Any]) -> None:
        self.lower = lower

    def __call__(self, point: Mapping[str, Any]) -> float:
        compiled = self.lower(point).compile()
        ma = compiled.memory_analysis()
        return float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
        )


def roofline_prescreen(
    region: Any, bp: Any, args: tuple, kwargs: dict,
) -> Optional[CompiledRooflineCost]:
    """The generic staged-pipeline prescreen for any AT region.

    Matches the ``KernelSpec.prescreen_factory`` signature: lowers + compiles
    each candidate against the call's example arguments (no execution, no
    allocation) and scores it with the roofline model — FIBER's
    before-execution layer as stage 1 of the staged pipeline
    (docs/tuning.md).  Returns ``None`` when there are no example arguments
    to lower against (nothing to compile — the op falls back to single-stage
    search).

    The compiled executables are retained (``keep_compiled``): the measured
    finals run on the same example arguments, so survivors execute the
    prescreen's artifact instead of paying a second compilation — the eval
    reduction becomes a wall-clock reduction too.
    """
    if not args and not kwargs:
        return None

    def lower(point: Mapping[str, Any]) -> Any:
        return jax.jit(region.instantiate(point)).lower(*args, **kwargs)

    return CompiledRooflineCost(lower, n_chips=1, keep_compiled=True)


def _block(x: Any) -> Any:
    try:
        return jax.block_until_ready(x)
    except Exception:
        return x
