"""Whole-program joint autotuning — compose registered ops into one problem.

The paper's headline 1.801x is a *whole-application* number: ppOpen-AT picks
a loop variant and a thread count per kernel region so the composition is
fast, not each kernel in isolation — per-region optima shift under
whole-program pressure (shared caches, memory bandwidth, activation-memory
headroom).  PRs 1–3 tuned each registered op greedily against its own
wall clock; this module tunes the *composition*:

* a :class:`ProgramMember` wraps one tunable region of the program — an
  :class:`~repro.core.region.ATRegion` from a registered
  :class:`~repro.core.registry.KernelSpec`, its shape-class BP, and an
  optional cheap prescreen (the same roofline stage the per-kernel staged
  pipeline uses, docs/tuning.md);
* a :class:`ProgramSpec` flattens the members' PP spaces into one joint
  space (``"<member>.<param>"`` names), fingerprints the composition as a
  BP (the **program fingerprint** keying the TuningDB), and knows how to
  ``build`` the full program step for any joint assignment — the cost the
  tuner minimizes is the *measured whole step*, never a per-kernel proxy;
* a :class:`JointSearch` prunes the product space: per-member staged
  survivors (top-k by prescreen / recorded per-kernel trials) → capped
  rank-sum cross product → coordinate descent *across members* → measured
  finals, with the per-kernel-greedy composition always evaluated first so
  the joint winner can never be worse than greedy on the same measured
  cost (tests/test_program.py pins both properties);
* :meth:`ProgramSpec.apply` hot-applies the winner **through
  ``region.select``** per member — the paper changing directives *and*
  thread count per kernel within one run, with switching still free
  because candidates are precompiled dict entries.

Joint winners persist under the program fingerprint, so a rerun of the same
composition performs zero cost evaluations (the registry acceptance bar,
extended to programs).  See docs/program.md.
"""
from __future__ import annotations

import itertools
import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from .cost import AdaptiveWallClockCost, score_points_concurrently
from .db import TuningDB
from .params import BasicParams, ParamSpace, PerfParam, pp_key
from .region import ATRegion
from .search import Search, SearchResult, Trial
from .tuner import Tuner

SEP = "."  # joint param names are "<member><SEP><param>"


# ---------------------------------------------------------------------------
# Members
# ---------------------------------------------------------------------------


@dataclass
class ProgramMember:
    """One tunable region of the program.

    ``bp`` is the member's own shape-class BP — it keys the member's
    *per-kernel* DB entries (greedy winners, recorded trials) and feeds the
    program fingerprint.  ``prescreen`` (optional) maps a member PP point to
    a cheap score; when absent, recorded per-kernel trials rank the space,
    and failing that the domain order stands.
    """

    name: str
    region: ATRegion
    bp: Optional[BasicParams] = None
    prescreen: Optional[Callable[[Mapping[str, Any]], float]] = None
    op: Optional[Any] = None  # AutotunedOp, for fast-path refresh bookkeeping
    args: tuple = ()
    kwargs: dict = field(default_factory=dict)

    def __post_init__(self) -> None:
        if SEP in self.name:
            raise ValueError(
                f"program member name {self.name!r} must not contain {SEP!r}"
            )

    @classmethod
    def from_op(
        cls, name: str, op: Any, *args: Any, **kwargs: Any
    ) -> "ProgramMember":
        """Build a member from a registered :class:`AutotunedOp` call.

        Resolves the call's shape class without tuning (the joint search is
        the tuner here) and adopts the spec's ``prescreen_factory`` as the
        member's stage-1 ranking, exactly like the per-kernel staged
        pipeline.
        """
        state = op.resolve_deferred(*args, **kwargs)
        prescreen = None
        if op.spec.prescreen_factory is not None:
            prescreen = op.spec.prescreen_factory(
                state.region, state.bp, args, kwargs
            )
        return cls(
            name=name, region=state.region, bp=state.bp, prescreen=prescreen,
            op=op, args=args, kwargs=dict(kwargs),
        )


# ---------------------------------------------------------------------------
# Flatten / unflatten
# ---------------------------------------------------------------------------


def flatten_assignment(assignment: Mapping[str, Mapping[str, Any]]) -> Dict[str, Any]:
    """``{"m": {"p": v}}`` -> ``{"m.p": v}`` (the joint PP point form)."""
    flat: Dict[str, Any] = {}
    for member, sub in assignment.items():
        for pname, v in sub.items():
            flat[f"{member}{SEP}{pname}"] = v
    return flat


def unflatten_point(point: Mapping[str, Any]) -> Dict[str, Dict[str, Any]]:
    """``{"m.p": v}`` -> ``{"m": {"p": v}}`` (member sub-points)."""
    out: Dict[str, Dict[str, Any]] = {}
    for key, v in point.items():
        member, _, pname = key.partition(SEP)
        out.setdefault(member, {})[pname] = v
    return out


# ---------------------------------------------------------------------------
# Joint search
# ---------------------------------------------------------------------------


class JointSearch(Search):
    """Pruned search over the product of per-member survivor sets.

    Stages (docs/program.md):

    1. **survivors** — the caller (``ProgramSpec.survivors``) hands each
       member's top-k sub-points, rank-ordered by the cheap layer (roofline
       prescreen or recorded per-kernel trials).  ``groups`` holds them as
       *flattened* sub-point dicts.
    2. **capped cross product** — joint candidates enumerate in rank-sum
       order (best-ranked member points first).  When the whole product
       fits under ``cap`` every candidate is measured, so with
       ``k >= |member space|`` and ``cap=None`` this reduces *exactly* to
       the exhaustive joint argmin.
    3. **coordinate descent across members** — for a product bigger than
       the cap, descend one member at a time from the per-member-greedy
       composition: try each survivor sub-point for that member with the
       others fixed, keep the measured argmin, repeat until a full pass
       moves nothing.  This is the paper's whole-application AT loop with
       "kernel region" as the coordinate.
    4. **measured finals** — the ``final_k`` best points are re-measured at
       ``finals_budget`` (when the cost is budget-aware, e.g.
       :class:`~repro.core.cost.AdaptiveWallClockCost`), so the recorded
       argmin rests on the program's most trusted measurements.

    ``start`` (the greedy composition) and ``seed`` (a warm start, e.g. a
    sibling program's winner) are always evaluated, never pruned — the
    joint winner is therefore never worse than either on the measured cost.
    """

    def __init__(
        self,
        groups: Sequence[Tuple[str, Sequence[Mapping[str, Any]]]],
        start: Optional[Mapping[str, Any]] = None,
        seed: Optional[Mapping[str, Any]] = None,
        cap: Optional[int] = 16,
        final_k: int = 3,
        finals_budget: Optional[int] = 2,
        max_passes: int = 4,
        prescreen_evaluations: int = 0,
        fresh: bool = False,
    ) -> None:
        if not groups:
            raise ValueError("JointSearch needs at least one member group")
        self.groups = [(name, [dict(p) for p in pts]) for name, pts in groups]
        for name, pts in self.groups:
            if not pts:
                raise ValueError(f"member {name!r} has no survivor points")
        self.start = dict(start) if start is not None else None
        self.seed = dict(seed) if seed is not None else None
        self.cap = cap
        self.final_k = final_k
        self.finals_budget = finals_budget
        self.max_passes = max_passes
        self.prescreen_evaluations = prescreen_evaluations
        # fresh=True (ProgramSpec.tune(force=True)): every evaluation passes
        # an explicit budget so a budget-aware caching cost (the Tuner's)
        # re-measures instead of returning recorded trials — a forced
        # re-tune must not silently recycle stale measurements.
        self.fresh = fresh

    # -- enumeration ---------------------------------------------------------

    def _merge(self, combo: Sequence[int]) -> Dict[str, Any]:
        point: Dict[str, Any] = {}
        for (name, pts), i in zip(self.groups, combo):
            point.update(pts[i])
        return point

    def _product(self) -> List[Dict[str, Any]]:
        """The full survivor cross product in rank-sum order (stable)."""
        index_lists = [range(len(pts)) for _, pts in self.groups]
        combos = sorted(itertools.product(*index_lists), key=sum)
        return [self._merge(c) for c in combos]

    def _head(self, n: int) -> List[Dict[str, Any]]:
        """The first ``n`` product points in rank-sum order, lazily.

        A best-first frontier walk over the index lattice (pop the lowest
        rank-sum combo, push its one-step successors): O(n log n) time and
        O(n) memory regardless of the product size, so a five-member
        program with sixteen survivors each never materializes 16^5 dicts
        to slice off a handful.
        """
        import heapq

        sizes = [len(pts) for _, pts in self.groups]
        origin = tuple(0 for _ in sizes)
        heap: List[Tuple[int, Tuple[int, ...]]] = [(0, origin)]
        seen = {origin}
        out: List[Dict[str, Any]] = []
        while heap and len(out) < n:
            s, combo = heapq.heappop(heap)
            out.append(self._merge(combo))
            for i, c in enumerate(combo):
                if c + 1 < sizes[i]:
                    succ = combo[:i] + (c + 1,) + combo[i + 1:]
                    if succ not in seen:
                        seen.add(succ)
                        heapq.heappush(heap, (s + 1, succ))
        return out

    def product_size(self) -> int:
        n = 1
        for _, pts in self.groups:
            n *= len(pts)
        return n

    # -- run -----------------------------------------------------------------

    def run(self, space: ParamSpace, cost) -> SearchResult:
        trials: List[Trial] = []
        evaluated: Dict[str, Trial] = {}
        fresh_budget = self.fresh and getattr(cost, "supports_budget", False)

        def eval_point(point: Dict[str, Any]) -> Optional[float]:
            key = pp_key(point)
            if key in evaluated:
                return evaluated[key].cost
            if not space.feasible(point):
                return None
            if fresh_budget:
                c = float(cost(point, 1))  # bypass recorded-trial recall
            else:
                c = float(cost(point))
            t = Trial(dict(point), c)
            evaluated[key] = t
            trials.append(t)
            return t.cost

        # incumbents first: greedy composition, then the warm seed — the
        # adaptive measured cost prunes later candidates against them, and
        # evaluating them at all is what makes "never worse than greedy" a
        # construction property rather than a hope.
        for incumbent in (self.start, self.seed):
            if incumbent is not None:
                eval_point(dict(incumbent))

        n = self.product_size()
        if self.cap is None or n <= self.cap:
            for point in self._product():
                eval_point(point)
        else:
            for point in self._head(max(1, self.cap // 2)):
                eval_point(point)
            self._descend(space, eval_point, evaluated)
        # measured finals run in *both* branches: the recorded winner must
        # rest on the program's most trusted numbers, not on one lucky
        # min_repeats=1 timing that then gets recalled forever.
        self._finals(cost, evaluated, trials)

        if not evaluated:
            raise ValueError("no feasible joint candidate to search")
        best = min(evaluated.values(), key=lambda t: t.cost)
        result = SearchResult(
            best=best, trials=trials, evaluations=len(trials),
            prescreen_evaluations=self.prescreen_evaluations,
        )
        return result

    def _descend(
        self,
        space: ParamSpace,
        eval_point: Callable[[Dict[str, Any]], Optional[float]],
        evaluated: Dict[str, Trial],
    ) -> None:
        """Coordinate descent with one *member* (not one scalar) per move."""
        budget = 2 * (self.cap or 0) or None  # hard stop for pathological spaces
        current = min(evaluated.values(), key=lambda t: t.cost).point
        current_cost = min(t.cost for t in evaluated.values())
        for _ in range(self.max_passes):
            moved = False
            for name, pts in self.groups:
                best_sub = None
                for sub in pts:
                    candidate = dict(current)
                    candidate.update(sub)
                    if pp_key(candidate) == pp_key(current):
                        continue
                    c = eval_point(candidate)
                    if c is not None and c < current_cost:
                        current_cost, best_sub, moved = c, sub, True
                    if budget is not None and len(evaluated) >= budget:
                        return
                if best_sub is not None:
                    current = dict(current)
                    current.update(best_sub)
            if not moved:
                break

    def _finals(
        self,
        cost,
        evaluated: Dict[str, Trial],
        trials: List[Trial],
    ) -> None:
        """Re-measure the leaders at a higher budget when the cost allows.

        Refinement can *raise* a leader's cost past an unrefined candidate,
        so the loop continues until the argmin itself is refined — the
        recorded winner must never rest on a single untrusted timing that
        only won because its rivals were noise-corrected upward.
        """
        if not self.finals_budget or not getattr(cost, "supports_budget", False):
            return
        refined: set = set()

        def refine(t: Trial) -> None:
            c = float(cost(t.point, self.finals_budget))
            key = pp_key(t.point)
            evaluated[key] = Trial(dict(t.point), c)
            trials.append(evaluated[key])
            refined.add(key)

        for t in sorted(evaluated.values(), key=lambda t: t.cost)[: self.final_k]:
            refine(t)
        for _ in range(len(evaluated)):  # bounded: each pass refines one more
            best = min(evaluated.values(), key=lambda t: t.cost)
            if pp_key(best.point) in refined:
                break
            refine(best)


# ---------------------------------------------------------------------------
# Program spec
# ---------------------------------------------------------------------------


@dataclass
class ProgramResult:
    """What a :meth:`ProgramSpec.tune` call produced (or recalled)."""

    point: Dict[str, Any]                 # flattened joint winner
    assignment: Dict[str, Dict[str, Any]]  # per-member sub-points
    cost: Optional[float]
    evaluations: int = 0                  # measured whole-step evaluations
    prescreen_evaluations: int = 0
    from_cache: bool = False              # winner recalled by fingerprint


class ProgramSpec:
    """A joint tuning problem over named program members.

    ``build(assignment)`` must return a zero-arg callable executing one full
    program step under that assignment; the default composes the members'
    regions sequentially on their example arguments (right for pipelines of
    standalone ops — the train and serve paths pass their own ``build``).
    ``on_apply(assignment)`` is invoked after :meth:`apply` selects every
    member, for callers that mirror the winner into caller-side state (the
    Trainer's remat directive, the serve DegreeController).
    """

    def __init__(
        self,
        name: str,
        members: Sequence[ProgramMember],
        db: Optional[TuningDB] = None,
        build: Optional[
            Callable[[Mapping[str, Mapping[str, Any]]], Callable[[], Any]]
        ] = None,
        on_apply: Optional[Callable[[Dict[str, Dict[str, Any]]], None]] = None,
        extra: Optional[Mapping[str, Any]] = None,
    ) -> None:
        if not members:
            raise ValueError("ProgramSpec needs at least one member")
        names = [m.name for m in members]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate program member names: {names}")
        self.name = name
        self.members = list(members)
        self.db = db or TuningDB()
        self._build = build
        self.on_apply = on_apply
        self.extra = dict(extra or {})
        self.last_result: Optional[ProgramResult] = None

    # -- identity ------------------------------------------------------------

    def fingerprint(self) -> BasicParams:
        """The program fingerprint: composition identity for the TuningDB.

        Combines the program name, every member's shape-class fingerprint
        *and* PP-space signature (a changed candidate domain must invalidate
        the recalled winner), plus caller ``extra`` entries (the measured
        step's own shape: batch, seq, backend).
        """
        entries: Dict[str, Any] = {"program": self.name}
        for m in self.members:
            entries[f"m_{m.name}"] = m.bp.fingerprint() if m.bp else "none"
            entries[f"s_{m.name}"] = tuple(
                (p.name, tuple(p.domain)) for p in m.region.space.params
            )
        entries.update(self.extra)
        return BasicParams.make(**entries)

    # -- joint space -----------------------------------------------------------

    def joint_space(self) -> ParamSpace:
        params: List[PerfParam] = []
        for m in self.members:
            for p in m.region.space.params:
                params.append(PerfParam(f"{m.name}{SEP}{p.name}", p.domain))
        members = self.members

        def feasible(point: Mapping[str, Any]) -> bool:
            subs = unflatten_point(point)
            return all(m.region.space.feasible(subs.get(m.name, {})) for m in members)

        return ParamSpace(params, constraint=feasible)

    def joint_region(self) -> ATRegion:
        """The program as one ATRegion: candidates are whole-step builds."""
        return ATRegion(
            f"program/{self.name}",
            self.joint_space(),
            instantiate=lambda point: self.build_executable(unflatten_point(point)),
        )

    # -- executables -----------------------------------------------------------

    def build_executable(
        self, assignment: Mapping[str, Mapping[str, Any]]
    ) -> Callable[[], Any]:
        """A zero-arg callable running one full step under ``assignment``.

        Never touches live selections — measurement must not disturb the
        hot path (the same ``select=False`` discipline the background tuner
        uses).
        """
        if self._build is not None:
            return self._build(assignment)
        fns = [
            (m, m.region.candidate(dict(assignment[m.name])))
            for m in self.members
        ]

        def step() -> Any:
            out = None
            for m, fn in fns:
                out = fn(*m.args, **m.kwargs)
            return out

        return step

    def measured_cost(
        self, warmup: int = 1, min_repeats: int = 1, max_repeats: int = 3
    ) -> AdaptiveWallClockCost:
        """Default joint cost: measured wall time of the full program step."""
        return AdaptiveWallClockCost(
            lambda point: self.build_executable(unflatten_point(point)),
            warmup=warmup, min_repeats=min_repeats, max_repeats=max_repeats,
        )

    # -- per-member staging ------------------------------------------------------

    def greedy_composition(self) -> Dict[str, Dict[str, Any]]:
        """Each member's own winner: per-kernel-greedy, the paper's baseline.

        A member whose BP has a *final* per-kernel best in the DB
        contributes that point; otherwise its live selection (the safe
        default) stands.
        """
        out: Dict[str, Dict[str, Any]] = {}
        for m in self.members:
            point = self.db.tuned_point(m.bp) if m.bp is not None else None
            if point is not None:
                try:
                    m.region.space.validate(point)
                except (KeyError, ValueError):
                    point = None
            out[m.name] = dict(point) if point is not None else dict(m.region.selected)
        return out

    def survivors(
        self, k: Optional[int] = None
    ) -> Tuple[List[Tuple[str, List[Dict[str, Any]]]], int]:
        """Per-member top-k sub-points (flattened), plus prescreen-eval count.

        Ranking priority per member: recorded per-kernel DB trials (already
        *measured* evidence) → the member's prescreen (the staged
        pipeline's cheap stage 1) → domain order.  The member's greedy
        point is never pruned.
        """
        groups: List[Tuple[str, List[Dict[str, Any]]]] = []
        prescreen_evals = 0
        greedy = self.greedy_composition()
        for m in self.members:
            points = [dict(p) for p in m.region.space.points()]
            if not points:
                raise ValueError(f"member {m.name!r} has no feasible points")
            trials = self.db.trials(m.bp) if m.bp is not None else {}
            if trials:
                order = {key: c for key, c in trials.items()}
                points.sort(key=lambda p: order.get(pp_key(p), float("inf")))
            elif m.prescreen is not None:
                scores = score_points_concurrently(m.prescreen, points)
                prescreen_evals += len(points)
                ranked = sorted(zip(points, scores), key=lambda ps: ps[1])
                points = [p for p, _ in ranked]
            kk = len(points) if k is None else max(1, k)
            chosen = points[:kk]
            g = greedy[m.name]
            if not any(pp_key(p) == pp_key(g) for p in chosen):
                chosen.insert(0, dict(g))
            flat = [
                {f"{m.name}{SEP}{pn}": v for pn, v in p.items()} for p in chosen
            ]
            groups.append((m.name, flat))
        return groups, prescreen_evals

    # -- hot apply ---------------------------------------------------------------

    def apply(self, point_or_assignment: Mapping[str, Any]) -> Dict[str, Dict[str, Any]]:
        """Hot-apply a joint point through each member's ``region.select``.

        This is the run-time switch: every member candidate is a
        precompiled dict entry, so adopting a whole-program winner costs a
        handful of dict writes (and bumps each region's version so op fast
        paths refresh their cached callables lazily).
        """
        first = next(iter(point_or_assignment.values()), None)
        if isinstance(first, Mapping):
            assignment = {k: dict(v) for k, v in point_or_assignment.items()}
        else:
            assignment = unflatten_point(point_or_assignment)
        for m in self.members:
            sub = assignment.get(m.name)
            if sub:
                m.region.select(sub)
        if self.on_apply is not None:
            self.on_apply(assignment)
        return assignment

    # -- tuning ------------------------------------------------------------------

    def tune(
        self,
        cost: Optional[Callable[..., float]] = None,
        k: Optional[int] = None,
        cap: Optional[int] = 16,
        final_k: int = 3,
        finals_budget: Optional[int] = 2,
        seed: Optional[Mapping[str, Any]] = None,
        force: bool = False,
        select: bool = True,
    ) -> ProgramResult:
        """Joint AT = argmin over the composition, measured end to end.

        A *final* DB winner under the program fingerprint short-circuits the
        whole search (zero evaluations — the cross-run cache, extended to
        programs); ``force=True`` re-tunes anyway, and passes explicit
        budgets through a budget-aware cost so recorded trials are
        *re-measured* rather than recalled (a forced re-tune after the
        machine changed must not recycle stale numbers).  ``select=True``
        applies the winner through :meth:`apply`.
        """
        bp = self.fingerprint()
        if not force:
            recalled = self.db.tuned_point(bp)
            if recalled is not None:
                if select:
                    self.apply(recalled)
                result = ProgramResult(
                    point=dict(recalled),
                    assignment=unflatten_point(recalled),
                    cost=self.db.best_cost(bp),
                    from_cache=True,
                )
                self.last_result = result
                return result

        groups, prescreen_evals = self.survivors(k)
        search = JointSearch(
            groups,
            start=flatten_assignment(self.greedy_composition()),
            seed=seed,
            cap=cap,
            final_k=final_k,
            finals_budget=finals_budget,
            prescreen_evaluations=prescreen_evals,
            fresh=force,
        )
        cost = cost or self.measured_cost()
        tuner = Tuner(self.db)
        sr = tuner.tune(self.joint_region(), bp, cost, select=False, search=search)
        winner = dict(sr.best.point)
        if select:
            self.apply(winner)
        result = ProgramResult(
            point=winner,
            assignment=unflatten_point(winner),
            cost=sr.best.cost,
            evaluations=sr.evaluations,
            prescreen_evaluations=sr.prescreen_evaluations,
        )
        self.last_result = result
        return result

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        inner = ", ".join(m.name for m in self.members)
        return f"ProgramSpec({self.name!r}, members=[{inner}])"
