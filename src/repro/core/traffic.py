"""Serving traffic classes — the request-shape BP dimension.

The paper switches the tuned implementation *and* parallelism degree per
computational kernel at run time.  At serving scale the analogue of "which
kernel is running" is **which traffic is arriving**: a prefill over a long
prompt and a single-token decode step are different computations with
different tuned optima, and so are a batch of 2 and a batch of 32.  A
:class:`TrafficClass` buckets a concrete serve call into

    (phase, batch bucket, sequence bucket)

where phase is ``prefill`` or ``decode`` and the numeric dimensions round up
to the next power of two, so the unbounded space of request shapes collapses
into a small, enumerable set of classes.  Each class is one more BP
dimension (docs/design.md §3): it extends the kernel's shape-class
``BasicParams`` and therefore keys its own TuningDB entry, its own tuned
winner, and its own precompiled candidate set.

Classes are deliberately *coarse*: a class must be stable enough that tuning
it once in the background (``repro.runtime.background_tuner``) pays off for
every later request that lands in it — see docs/serving.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

# "stream" is the scheduler's own phase: the continuous-batching engine
# (repro.runtime.engine) classifies *queue states* rather than single calls —
# batch bucket = waiting requests, seq bucket = mean prompt length.
PHASES = ("prefill", "decode", "stream")


def bucket_pow2(n: int, floor: int = 1) -> int:
    """Round ``n`` up to the next power of two (at least ``floor``)."""
    if n < 1:
        raise ValueError(f"bucket_pow2 needs n >= 1, got {n}")
    b = max(1, int(floor))
    while b < n:
        b <<= 1
    return b


@dataclass(frozen=True)
class TrafficClass:
    """One serving traffic class: phase × batch bucket × sequence bucket."""

    phase: str
    batch_bucket: int
    seq_bucket: int

    # the BP-entry names bp_entries() emits — the single source of truth the
    # TuningDB traffic scan (db.traffic_classes) keys on
    BP_KEYS = ("phase", "batch_bucket", "seq_bucket")

    def __post_init__(self) -> None:
        if self.phase not in PHASES:
            raise ValueError(f"phase {self.phase!r} not in {PHASES}")

    @classmethod
    def of(cls, phase: str, batch: int, seq_len: int) -> "TrafficClass":
        """Bucket a concrete (phase, batch, seq_len) call into its class."""
        return cls(phase, bucket_pow2(int(batch)), bucket_pow2(int(seq_len)))

    @property
    def label(self) -> str:
        return f"{self.phase}/b{self.batch_bucket}/s{self.seq_bucket}"

    def bp_entries(self) -> Dict[str, Any]:
        """The BP entries this class contributes to a kernel's shape class.

        These names (:attr:`BP_KEYS`) are what
        :meth:`repro.core.db.TuningDB.traffic_classes` scans for, making
        traffic a queryable DB dimension.
        """
        return {k: getattr(self, k) for k in self.BP_KEYS}

    @classmethod
    def from_bp_entries(cls, entries: Dict[str, Any]) -> "TrafficClass":
        return cls(
            str(entries["phase"]),
            int(entries["batch_bucket"]),
            int(entries["seq_bucket"]),
        )
