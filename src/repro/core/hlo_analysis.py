"""Trip-count-aware HLO cost analysis from the compiled module text.

Why this exists: ``compiled.cost_analysis()`` counts every ``while`` body
**once**, but our models are scan-over-layers (trip 126 for llama3-405b) with
seq-scans inside (trip 32768 for a Mamba prefill) — XLA's number can be 5
orders of magnitude off for exactly the programs this framework cares about.
XLA does annotate ``backend_config={"known_trip_count":{"n":"..."}}`` on
whiles it has analyzed, so we walk the HLO text ourselves:

* per-computation symbol table (param + op result shapes),
* FLOPs: ``dot``/``convolution`` exactly (2 × result elems × contraction
  size), elementwise ops at 1 FLOP/elem, fusions by recursing into the
  called computation (dots are usually wrapped in fusions on CPU),
* bytes: fusion-level accounting — a fusion call site costs its operands +
  result (models perfect producer fusion, close to XLA's own model);
  in-place-friendly ops (dynamic-update-slice) cost ~2× their update,
* collectives: result bytes per kind (``-start`` counted, ``-done`` skipped),
* ``while``: body cost × trip count (condition ignored: O(1) scalar ops),
  with multiplicative nesting; unknown trip counts fall back to 1 with a
  warning (never observed on XLA:CPU for lax.scan).

Everything is **per-device** (the SPMD module is per-device); callers
multiply by chip count for global numbers.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_SHAPE_RE = re.compile(r"(pred|[usbf]\d+(?:e\d+m\d+)?(?:fn)?)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1,
}

_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$"
)
# TYPE is either a tuple "(s32[], bf16[...], /*index=5*/f32[...])" — which may
# contain `/*index=N*/` comments with `=` inside — or a single array type.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\]{},./:]+?)\s+"
    r"([\w\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_FREE_OPS = frozenset(
    {
        "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
        "after-all", "partition-id", "replica-id", "domain", "opt-barrier",
    }
)


def shape_elems_and_bytes(type_text: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(type_text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        base = _DTYPE_BYTES.get(dtype)
        if base is None:
            m = re.search(r"(\d+)", dtype)
            base = int(m.group(1)) // 8 if m else 4
        nbytes += n * base
    return elems, nbytes


def _shape_dims(type_text: str) -> List[int]:
    """Dims of the FIRST array shape in a type string."""
    m = _SHAPE_RE.search(type_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    rest: str  # operand list + attributes (raw text after the '(')

    def operand_names(self) -> List[str]:
        # operands live before the first '),' at paren depth 0 — just take
        # %refs from the full rest; attribute refs (calls=, body=) are
        # handled separately and excluded here.
        cut = self.rest
        for attr in ("calls=", "to_apply=", "body=", "condition=", "branch_computations="):
            idx = cut.find(attr)
            if idx >= 0:
                cut = cut[:idx]
        return _OPERAND_RE.findall(cut)


@dataclass
class Computation:
    name: str
    params: Dict[str, str] = field(default_factory=dict)  # name -> type text
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> type text


@dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    warnings: List[str] = field(default_factory=list)
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)

    def charge(self, kind: str, nbytes: float) -> None:
        self.bytes += nbytes
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + nbytes

    def add(self, other: "HLOCost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + mult * v
        for k, v in other.bytes_by_kind.items():
            self.bytes_by_kind[k] = self.bytes_by_kind.get(k, 0.0) + mult * v
        self.warnings.extend(w for w in other.warnings if w not in self.warnings)

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.collectives.values()))


def parse_hlo_computations(txt: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    current: Optional[Computation] = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                is_entry, name, params_text, _ = m.groups()
                current = Computation(name=name)
                for pm in re.finditer(r"%?([\w.\-]+)\s*:\s*((?:\([^()]*\)|[\w\[\]{},.])+)", params_text):
                    current.params[pm.group(1)] = pm.group(2)
                    current.symbols[pm.group(1)] = pm.group(2)
                if is_entry:
                    entry = name
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, rtype, kind, rest = m.groups()
            op = Op(name=name, kind=kind, result_type=rtype, rest=rest)
            current.ops.append(op)
            current.symbols[name] = rtype
    if current is not None:  # unterminated (shouldn't happen)
        comps[current.name] = current
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 × result elems × contraction size."""
    res_elems, _ = shape_elems_and_bytes(op.result_type)
    operands = op.operand_names()
    if not operands:
        return 0.0
    lhs_type = comp.symbols.get(operands[0], "")
    lhs_dims = _shape_dims(lhs_type)
    m = _CONTRACT_RE.search(op.rest)
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * res_elems * contract


def _conv_flops(op: Op, comp: Computation) -> float:
    """2 × result elems × (kernel spatial × input features)."""
    res_elems, _ = shape_elems_and_bytes(op.result_type)
    operands = op.operand_names()
    if len(operands) < 2:
        return 0.0
    k_dims = _shape_dims(comp.symbols.get(operands[1], ""))
    k_prod = 1
    for d in k_dims[:-1]:  # all but output-feature dim (approx)
        k_prod *= d
    return 2.0 * res_elems * max(1, k_prod)


def _operand_bytes(op: Op, comp: Computation) -> float:
    total = 0.0
    for name in op.operand_names():
        t = comp.symbols.get(name)
        if t:
            _, b = shape_elems_and_bytes(t)
            total += b
    return total


def _fusion_byte_charge(
    op: Op, comp: Computation, comps: Dict[str, Computation]
) -> float:
    """HBM bytes for one fusion call site, via backward demand propagation.

    XLA fusions compute lazily: a ``convert`` feeding a ``dynamic-slice``
    only materializes the sliced elements, a producer fused into a reduce is
    read once, etc.  Charging call-site operands at full size overcounts a
    32768-step seq scan by ~1000× (measured on the falcon-mamba prefill
    cell).  We propagate demanded element counts backward from the fusion
    root: parameters are charged at their demanded extent, the result at its
    write size (in-place DUS roots write only the update region).
    """
    m = _CALLS_RE.search(op.rest)
    called = comps.get(m.group(1)) if m else None
    _, rb = shape_elems_and_bytes(op.result_type)
    if called is None or not called.ops:
        return rb + _operand_bytes(op, comp)

    root = called.ops[-1]
    defs = {o.name: o for o in called.ops}

    # In-place stacked-buffer update detection: root chain
    # (convert/bitcast/copy/reshape)* -> dynamic-update-slice whose buffer
    # operand traces (through the same pass-throughs) to a parameter of equal
    # element count.  XLA:CPU wraps the DUS in bf16<->f32 converts (its bf16
    # emulation); on TPU the DUS aliases the buffer, so the real traffic is
    # the update region, not the 32768-step stack.
    inplace_param: Optional[str] = None
    inplace_update_bytes = 0.0

    def _through(name: str) -> Optional[Op]:
        seen = 0
        while name in defs and seen < 8:
            o = defs[name]
            if o.kind in ("convert", "bitcast", "copy", "reshape"):
                ops_ = o.operand_names()
                if not ops_:
                    return o
                name = ops_[0]
                seen += 1
                continue
            return o
        return None

    root_elems = float(shape_elems_and_bytes(root.result_type)[0])
    tail = _through(root.name)
    if tail is not None and tail.kind == "dynamic-update-slice":
        refs = tail.operand_names()
        if refs:
            buf = _through(refs[0])
            if (
                buf is not None
                and buf.kind == "parameter"
                and float(shape_elems_and_bytes(buf.result_type)[0]) == root_elems
            ):
                inplace_param = buf.name
                if len(refs) > 1:
                    t = called.symbols.get(refs[1])
                    if t:
                        inplace_update_bytes = float(shape_elems_and_bytes(t)[1])

    demand: Dict[str, float] = {root.name: float(shape_elems_and_bytes(root.result_type)[0])}
    for o in reversed(called.ops):
        E = demand.get(o.name, 0.0)
        if E <= 0 or o.kind == "parameter":
            continue
        res_elems = float(shape_elems_and_bytes(o.result_type)[0]) or 1.0
        refs = o.operand_names()
        for pos, ref in enumerate(refs):
            t = called.symbols.get(ref)
            if t is None:
                continue
            ref_elems = float(shape_elems_and_bytes(t)[0])
            if o.kind in ("dot", "convolution"):
                d = ref_elems
            elif o.kind in ("reduce", "reduce-window"):
                d = ref_elems * min(1.0, E / res_elems) if pos == 0 else 0.0
            elif o.kind in ("dynamic-slice", "slice", "gather"):
                d = E if pos == 0 else 0.0
            elif o.kind == "dynamic-update-slice":
                if pos == 0:
                    d = E  # aliased buffer passthrough (charged as update below)
                elif pos == 1:
                    d = min(ref_elems, E)
                else:
                    d = 0.0
            elif o.kind in ("constant", "iota"):
                continue
            elif o.kind == "broadcast":
                d = min(ref_elems, E)
            else:  # elementwise / convert / bitcast / transpose / reshape ...
                d = min(ref_elems, E)
            if d > 0:
                demand[ref] = max(demand.get(ref, 0.0), d)

    # parameter index -> call-site operand
    operands = op.operand_names()
    total = 0.0
    if inplace_param is not None:
        total += 2 * inplace_update_bytes  # read+write of the update region
    else:
        total += rb
    for o in called.ops:
        if o.kind != "parameter":
            continue
        mi = re.match(r"\s*(\d+)", o.rest)
        if not mi:
            continue
        if o.name == inplace_param:
            continue  # aliased in-place buffer: not read in full
        pidx = int(mi.group(1))
        site = operands[pidx] if pidx < len(operands) else None
        t = comp.symbols.get(site) if site else None
        if t is None:
            t = o.result_type
        elems, full_bytes = shape_elems_and_bytes(t)
        if elems == 0:
            continue
        dtype_bytes = full_bytes / elems
        d = demand.get(o.name, 0.0)
        total += min(float(full_bytes), d * dtype_bytes)
    return total


def _fusion_dot_flops(
    comp_name: str, comps: Dict[str, Computation], memo: Dict[str, float]
) -> float:
    """Dot/conv/elementwise FLOPs inside a fusion-called computation."""
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    if comp is None:
        return 0.0
    memo[comp_name] = 0.0  # cycle guard
    total = 0.0
    for op in comp.ops:
        if op.kind == "dot":
            total += _dot_flops(op, comp)
        elif op.kind == "convolution":
            total += _conv_flops(op, comp)
        elif op.kind in ("fusion", "call", "map"):
            m = _CALLS_RE.search(op.rest)
            if m:
                total += _fusion_dot_flops(m.group(1), comps, memo)
        elif op.kind in _FREE_OPS or op.kind in COLLECTIVE_KINDS:
            continue
        else:
            elems, _ = shape_elems_and_bytes(op.result_type)
            total += elems  # 1 flop/elem elementwise estimate
    memo[comp_name] = total
    return total


def analyze_computation(
    comp_name: str,
    comps: Dict[str, Computation],
    memo: Dict[str, HLOCost],
    fusion_memo: Dict[str, float],
) -> HLOCost:
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    cost = HLOCost()
    memo[comp_name] = cost  # pre-insert (cycle guard)
    if comp is None:
        cost.warnings.append(f"missing computation {comp_name}")
        return cost

    for op in comp.ops:
        kind = op.kind
        base_kind = kind[:-6] if kind.endswith("-start") else kind
        if base_kind.endswith("-done"):
            continue
        if base_kind in COLLECTIVE_KINDS:
            _, rb = shape_elems_and_bytes(op.result_type)
            cost.collectives[base_kind] = cost.collectives.get(base_kind, 0.0) + rb
            cost.charge(base_kind, rb)  # collective results land in HBM too
            continue
        if kind in _FREE_OPS:
            continue
        if kind == "while":
            trip = 1
            m = _TRIP_RE.search(op.rest)
            if m:
                trip = int(m.group(1))
            else:
                cost.warnings.append(f"while without known_trip_count in {comp_name}")
            bm = _BODY_RE.search(op.rest)
            if bm:
                cost.add(analyze_computation(bm.group(1), comps, memo, fusion_memo), trip)
            continue
        if kind == "conditional":
            bm = _BRANCHES_RE.search(op.rest)
            if bm:
                branch_costs = [
                    analyze_computation(b.strip().lstrip("%"), comps, memo, fusion_memo)
                    for b in bm.group(1).split(",")
                ]
                if branch_costs:  # charge the max-cost branch
                    worst = max(branch_costs, key=lambda c: c.flops + c.bytes)
                    cost.add(worst)
            continue
        if kind in ("fusion", "call", "map", "custom-call", "reduce", "sort", "scatter"):
            m = _CALLS_RE.search(op.rest)
            if m:
                cost.flops += _fusion_dot_flops(m.group(1), comps, fusion_memo)
            if kind == "fusion":
                cost.charge(kind, _fusion_byte_charge(op, comp, comps))
            else:
                _, rb = shape_elems_and_bytes(op.result_type)
                cost.charge(kind, rb + _operand_bytes(op, comp))
            continue
        if kind == "dot":
            cost.flops += _dot_flops(op, comp)
            _, rb = shape_elems_and_bytes(op.result_type)
            cost.charge(kind, rb + _operand_bytes(op, comp))
            continue
        if kind == "convolution":
            cost.flops += _conv_flops(op, comp)
            _, rb = shape_elems_and_bytes(op.result_type)
            cost.charge(kind, rb + _operand_bytes(op, comp))
            continue
        if kind in ("dynamic-update-slice",):
            # in-place update: read+write the update region, not the buffer
            operands = op.operand_names()
            ub = 0.0
            if len(operands) >= 2:
                t = comp.symbols.get(operands[1])
                if t:
                    _, ub = shape_elems_and_bytes(t)
            cost.charge(kind, 2 * ub)
            continue
        if kind in ("dynamic-slice", "slice", "copy", "transpose", "reshape",
                    "broadcast", "iota", "concatenate", "pad", "gather",
                    "reverse", "reduce-window", "select-and-scatter"):
            _, rb = shape_elems_and_bytes(op.result_type)
            cost.charge(kind, 2 * rb if kind != "iota" else rb)
            if kind in ("reduce-window", "select-and-scatter"):
                cost.flops += shape_elems_and_bytes(op.result_type)[0]
            continue
        # default: elementwise-ish op
        elems, rb = shape_elems_and_bytes(op.result_type)
        cost.flops += elems
        cost.charge(kind, rb + _operand_bytes(op, comp))
    return cost


def analyze_hlo_text(txt: str) -> HLOCost:
    """Per-device trip-count-aware cost of a compiled SPMD module."""
    comps, entry = parse_hlo_computations(txt)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    # Fusion-called computations must not be double counted: analyze only
    # from ENTRY; while bodies/conditions/branches reached via the walk.
    return analyze_computation(entry, comps, {}, {})
