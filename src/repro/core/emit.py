"""Emit policies — candidate spaces *generated* from the architecture model.

ppOpen-AT enumerates every directive variant ahead of time from a fixed,
hand-written list.  This module replaces the hand-written part: a kernel
describes its tunable dimensions (:class:`TileDim` — extent plus a semantic
role), and an :class:`EmitPolicy` derives the candidate :class:`ParamSpace`
from an :class:`~repro.core.arch.ArchSpec` — pow2 tile ladders clipped to
divisibility and the arch's actual VMEM budget, pipeline-stage counts,
memory-space placement, and a per-point roofline estimate the staged
prescreen consumes for ranking.

Every emitted space carries a ``signature``: a content hash over the policy,
the arch, the dims, and the resulting point list.  The TuningDB records the
signature with each final so a changed arch model *invalidates* stale
winners instead of silently recalling them (docs/arch.md).
"""
from __future__ import annotations

import hashlib
import json
import math
from dataclasses import dataclass, field
from typing import (
    Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple,
)

from .arch import ArchSpec, local_arch
from .params import EmptySpace, ParamSpace, PerfParam, pp_key

try:  # pragma: no cover - Protocol is cosmetic on older pythons
    from typing import Protocol
except ImportError:  # pragma: no cover
    Protocol = object  # type: ignore[assignment]


# Dimension semantics → the smallest tile worth emitting.  "lane" dims map
# to the VPU minor axis (tiles below lane width waste the vector unit);
# "sequential" dims are loop-carried chunks (a few sublanes deep is the
# floor); "grid" dims are pure program-count splits (any size works).
_SEMANTICS = ("lane", "sequential", "grid")


@dataclass(frozen=True)
class TileDim:
    """One tunable dimension of a kernel, as the emit layer sees it.

    ``allow_padding`` marks dims the kernel can tile past the array edge
    (masking the tail), so non-dividing pow2 tiles stay candidates —
    without it a prime extent collapses to the single full-extent tile.
    """

    name: str
    extent: int
    semantic: str = "lane"
    min_tile: Optional[int] = None
    allow_padding: bool = False

    def __post_init__(self) -> None:
        if self.semantic not in _SEMANTICS:
            raise ValueError(
                f"TileDim {self.name!r}: unknown semantic {self.semantic!r}; "
                f"expected one of {_SEMANTICS}"
            )
        if self.extent < 1:
            raise ValueError(f"TileDim {self.name!r}: extent must be >= 1")

    def resolved_min(self, arch: ArchSpec) -> int:
        if self.min_tile is not None:
            return max(1, self.min_tile)
        if self.semantic == "lane":
            return arch.lane_width
        if self.semantic == "sequential":
            return arch.sublane_width * 4
        return 1


@dataclass
class EmittedSpace:
    """What an emit policy returns: the space plus everything derived from it.

    ``hints`` maps ``pp_key(point)`` to the per-point model estimates
    (``est_s``, ``vmem_bytes``, ``programs``, ``stages``, ``memory_space``,
    ``pad_factor``) that :func:`hint_prescreen` folds into ranking.
    """

    space: ParamSpace
    signature: str
    arch: ArchSpec
    policy: str
    hints: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    dims: Tuple[TileDim, ...] = ()


class EmitPolicy(Protocol):
    """Anything that can turn (arch, shape BP) into an EmittedSpace."""

    name: str
    version: int

    def emit(
        self, arch: ArchSpec, bp: Mapping[str, Any],
        pinned: Sequence[Mapping[str, Any]] = (),
        vmem_budget: Optional[int] = None,
    ) -> EmittedSpace:
        ...  # pragma: no cover - protocol


def pow2_ladder(dim: TileDim, arch: ArchSpec, cap: int = 8) -> Tuple[int, ...]:
    """Candidate tile sizes for one dim: pow2 multiples of the semantic
    minimum up to the extent, clipped to divisibility (unless the dim
    allows padded tails), plus the full extent itself.  At most ``cap``
    values survive — the largest ones, since the VMEM constraint prunes
    from above anyway."""
    lo = min(dim.resolved_min(arch), dim.extent)
    out = []
    v = lo
    while v < dim.extent:
        if dim.extent % v == 0 or dim.allow_padding:
            out.append(v)
        v *= 2
    out.append(dim.extent)
    out = sorted(set(out))
    return tuple(out[-cap:])


def _pad_factor(dims: Sequence[TileDim], point: Mapping[str, Any]) -> float:
    """Compute/traffic inflation from tiling past the array edge."""
    factor = 1.0
    for d in dims:
        if d.name not in point:
            continue
        tile = int(point[d.name])
        padded = -(-d.extent // tile) * tile
        factor *= padded / d.extent
    return factor


def _programs(dims: Sequence[TileDim], point: Mapping[str, Any]) -> int:
    n = 1
    for d in dims:
        if d.name in point:
            n *= -(-d.extent // int(point[d.name]))
    return n


class TilePolicy:
    """The default emit policy: arch-derived pow2 tile ladders.

    * ``dims(bp)`` returns the kernel's :class:`TileDim` list for a shape BP.
    * ``vmem_model(bp, point)`` returns the candidate's working-set bytes —
      the constraint is ``vmem_model <= arch.vmem_budget()``.
    * ``traffic_model(bp, point)`` (optional) returns ``(flops, bytes)`` of
      one whole call, used for the roofline part of the per-point hint.
    """

    def __init__(
        self,
        kernel: str,
        dims: Callable[[Mapping[str, Any]], Sequence[TileDim]],
        vmem_model: Callable[[Mapping[str, Any], Mapping[str, Any]], int],
        traffic_model: Optional[
            Callable[[Mapping[str, Any], Mapping[str, Any]], Tuple[float, float]]
        ] = None,
        max_per_dim: int = 8,
        version: int = 1,
    ) -> None:
        self.kernel = kernel
        self.name = "tile_pow2"
        self.version = version
        self.dims = dims
        self.vmem_model = vmem_model
        self.traffic_model = traffic_model
        self.max_per_dim = max_per_dim

    # -- hints -----------------------------------------------------------

    def _hint(
        self,
        arch: ArchSpec,
        bp: Mapping[str, Any],
        dims: Sequence[TileDim],
        point: Mapping[str, Any],
        budget: int,
    ) -> Dict[str, Any]:
        vmem = int(self.vmem_model(bp, point))
        stages = 2 if 2 * vmem <= budget else 1
        programs = _programs(dims, point)
        pad = _pad_factor(dims, point)
        est = programs * arch.grid_overhead_s
        flops = bytes_ = 0.0
        if self.traffic_model is not None:
            flops, bytes_ = self.traffic_model(bp, point)
            flops *= pad
            bytes_ *= pad
            # single-stage candidates cannot overlap copy-in with compute
            mem_penalty = 1.0 if stages >= 2 else 1.5
            est += max(
                flops / arch.peak_flops,
                bytes_ * mem_penalty / arch.hbm_bandwidth,
            )
        return {
            "est_s": est,
            "vmem_bytes": vmem,
            "stages": stages,
            "programs": programs,
            "pad_factor": pad,
            "memory_space": "vmem" if vmem <= budget else "hbm",
            "flops": flops,
            "bytes": bytes_,
        }

    # -- emit ------------------------------------------------------------

    def emit(
        self,
        arch: Optional[ArchSpec] = None,
        bp: Mapping[str, Any] = (),
        pinned: Sequence[Mapping[str, Any]] = (),
        vmem_budget: Optional[int] = None,
    ) -> EmittedSpace:
        arch = arch or local_arch()
        bp = dict(bp)
        budget = int(vmem_budget if vmem_budget is not None
                     else arch.vmem_budget())
        dims = tuple(self.dims(bp))
        pinned_pts = [dict(p) for p in pinned]
        pinned_keys = {pp_key(p) for p in pinned_pts}

        domains: Dict[str, List[Any]] = {
            d.name: list(pow2_ladder(d, arch, self.max_per_dim)) for d in dims
        }
        # escape hatch: hand-pinned points are always candidates, even when
        # their values fall outside the ladder or past the VMEM budget — a
        # known winner must never be lost to a model change
        for p in pinned_pts:
            for name, value in p.items():
                if name in domains and value not in domains[name]:
                    domains[name].append(value)
        params = [PerfParam(d.name, tuple(sorted(domains[d.name]))) for d in dims]

        def fits(point: Mapping[str, Any]) -> bool:
            if pp_key(point) in pinned_keys:
                return True
            return int(self.vmem_model(bp, point)) <= budget

        context = {
            "kernel": self.kernel,
            "arch": arch.name,
            "vmem_budget": budget,
            **{f"extent_{d.name}": d.extent for d in dims},
        }
        base = ParamSpace(
            params, constraint=fits,
            label=f"emitted:{self.kernel}", context=context,
        )
        feasible = list(base.points())
        if not feasible:  # pragma: no cover - base construction raises first
            raise EmptySpace(
                f"emitted:{self.kernel}: no candidate fits", context=context
            )

        hints = {
            pp_key(p): self._hint(arch, bp, dims, p, budget) for p in feasible
        }
        ordered = sorted(
            feasible, key=lambda p: (hints[pp_key(p)]["est_s"], pp_key(p))
        )
        space = base.subset(ordered)
        space.label, space.context = base.label, base.context

        signature = space_signature(
            policy=self.name, version=self.version, kernel=self.kernel,
            arch=arch, dims=dims, budget=budget,
            point_keys=[pp_key(p) for p in ordered],
        )
        return EmittedSpace(
            space=space, signature=signature, arch=arch,
            policy=self.name, hints=hints, dims=dims,
        )


def space_signature(
    policy: str,
    version: int,
    kernel: str,
    arch: ArchSpec,
    dims: Sequence[TileDim],
    budget: int,
    point_keys: Sequence[str],
) -> str:
    """Content hash of an emitted space — byte-identical iff the policy,
    the arch model, the shape dims, the budget, and the resulting ordered
    candidate list are all identical."""
    payload = {
        "policy": policy,
        "version": version,
        "kernel": kernel,
        "arch": arch.bp_entries(),
        "dims": [
            {
                "name": d.name, "extent": d.extent, "semantic": d.semantic,
                "min_tile": d.min_tile, "allow_padding": d.allow_padding,
            }
            for d in dims
        ],
        "vmem_budget": budget,
        "points": list(point_keys),
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


class HintedRooflineCost:
    """Compiled roofline prescreen, re-ranked with the emit-layer hints.

    Wraps :class:`~repro.core.cost.CompiledRooflineCost`: the HLO roofline
    gives flops/bytes truth, while the hint contributes what the HLO cannot
    see — the per-program grid overhead and the single-stage pipeline
    penalty.  Exposes the same ``score_many`` / ``compiled_by_point``
    surface so the measured stage still reuses the prescreen's executables.
    """

    def __init__(self, inner: Any, hints: Mapping[str, Mapping[str, Any]],
                 arch: ArchSpec) -> None:
        self.inner = inner
        self.hints = hints
        self.arch = arch

    @property
    def compiled_by_point(self) -> Dict[str, Any]:
        return self.inner.compiled_by_point

    @property
    def terms_by_point(self) -> Dict[str, Any]:
        return self.inner.terms_by_point

    def __call__(self, point: Mapping[str, Any]) -> float:
        base = float(self.inner(point))
        h = self.hints.get(pp_key(point))
        if h:
            penalty = 1.0 if h.get("stages", 2) >= 2 else 1.5
            base = base * penalty + h["programs"] * self.arch.grid_overhead_s
        return base

    def score_many(
        self,
        points: Sequence[Mapping[str, Any]],
        max_workers: Optional[int] = None,
    ) -> List[float]:
        from .cost import score_points_concurrently

        return score_points_concurrently(self, points, max_workers)


def hint_prescreen(
    region: Any, bp: Any, args: tuple, kwargs: dict
) -> Optional[Any]:
    """Staged-pipeline prescreen for emitted regions.

    With example arguments, compiles candidates like
    :func:`~repro.core.cost.roofline_prescreen` and folds the emit hints
    into the score.  Without example arguments (where the compiled
    prescreen must return ``None``), falls back to ranking purely on the
    hint estimates — an emitted region always has *some* prescreen.
    """
    from .cost import roofline_prescreen

    hints = getattr(region, "hints", None) or {}
    arch = getattr(region, "arch", None) or local_arch()
    compiled = roofline_prescreen(region, bp, args, kwargs)
    if compiled is not None:
        return HintedRooflineCost(compiled, hints, arch) if hints else compiled
    if not hints:
        return None

    def score(point: Mapping[str, Any]) -> float:
        h = hints.get(pp_key(point))
        return float(h["est_s"]) if h else math.inf

    return score
