"""The FIBER three-layer tuner (paper §II.A, §IV.A).

Layer semantics:

* **install** — BP-independent sweeps done once per build (kernel block
  shapes on reference shapes).  Results seed later layers.
* **before_execution** — the user has fixed BP (problem size, mesh, max
  degree).  The tuner searches the PP space with the given cost function and
  records the argmin.  This is where the paper measures all candidates
  ("Perform AT for changing the number of threads for all candidates...").
* **run_time** — the selected candidate is used for real work; measured step
  times are appended to the DB.  If the selected candidate regresses
  (straggler, interference), :meth:`RuntimeSelector.observe` re-selects the
  next-best *precompiled* candidate — switching is free because every
  candidate was AOT-compiled (paper §IV.D: "we can change the number of
  threads frequently at run-time").
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Mapping, Optional

from ..obs.trace import current_tracer
from .db import TuningDB
from .params import BasicParams, ParamSpace, pp_key
from .region import ATRegion
from .search import ExhaustiveSearch, Search, SearchResult, Trial

LAYERS = ("install", "before_execution", "run_time")


class Tuner:
    def __init__(self, db: Optional[TuningDB] = None, search: Optional[Search] = None):
        self.db = db or TuningDB()
        self.search = search or ExhaustiveSearch()

    def tune(
        self,
        region: ATRegion,
        bp: BasicParams,
        cost: Callable[[Mapping[str, Any]], float],
        layer: str = "before_execution",
        select: bool = True,
        search: Optional[Search] = None,
        fresh: bool = False,
        finalize: bool = True,
    ) -> SearchResult:
        """AT = argmin_PP cost(PP | BP).  Records every trial in the DB.

        ``search`` overrides the tuner's strategy for this one problem —
        the staged pipeline builds a per-shape-class search (warm-start
        seed, prescreen over this class's example args) that cannot be
        pinned at construction time.

        ``fresh=True`` disables the recorded-trial short-circuit: every
        point is re-measured (still recorded).  This is the drift re-tune
        path (docs/fleet.md) — the recorded costs are exactly what the
        runtime has drifted away from, so replaying them would just
        reconfirm the demoted winner.  ``finalize=False`` skips the final
        ``record_best`` (the re-tune's challenger is only finalized after
        it survives its canary window).
        """
        if layer not in LAYERS:
            raise ValueError(f"unknown FIBER layer {layer!r}; expected one of {LAYERS}")

        supports_budget = bool(getattr(cost, "supports_budget", False))

        def quarantine(point: Mapping[str, Any], reason: str) -> None:
            tr = current_tracer()
            if tr is not None:
                tr.instant(
                    "tuner.quarantine", cat="tuner", region=region.name,
                    layer=layer, pp=pp_key(point), reason=reason,
                )
            self.db.record_quarantine(bp, point, reason, layer=layer)

        def measured(point: Mapping[str, Any], fn: Callable[[], float]) -> float:
            """Measurement guardrail: a candidate whose cost raises or comes
            back non-finite (NaN/inf) is *quarantined* in the DB — it can
            never win this search (cost becomes +inf) nor any later one
            (merge propagates the marker fleet-wide) — instead of a NaN
            silently surviving argmin comparisons or one broken candidate
            aborting the whole sweep.  Control-flow exceptions (trial-budget
            exhaustion marks itself ``tuning_control``) still propagate."""
            try:
                c = float(fn())
            except Exception as exc:
                if getattr(exc, "tuning_control", False):
                    raise
                quarantine(point, f"cost raised {type(exc).__name__}: {exc}")
                return math.inf
            if not math.isfinite(c):
                quarantine(point, f"non-finite cost {c!r}")
                return math.inf
            return c

        def guarded(point: Mapping[str, Any], fn: Callable[[], float]) -> float:
            tr = current_tracer()
            if tr is None:
                return measured(point, fn)
            with tr.span(
                "tuner.trial", cat="tuner", region=region.name, layer=layer,
                pp=pp_key(point),
            ) as attrs:
                c = measured(point, fn)
                attrs["cost"] = c
                attrs["verdict"] = "ok" if math.isfinite(c) else "quarantined"
                return c

        def caching_cost(
            point: Mapping[str, Any], budget: Optional[int] = None
        ) -> float:
            if self.db.is_quarantined(bp, point):
                return math.inf  # known-broken: never re-measure, never wins
            if budget is not None and supports_budget:
                # budget-aware re-measurement (SuccessiveHalving rungs): a
                # higher budget buys a *better* estimate, so the cached
                # trial must not short-circuit it; the DB keeps the latest
                # (highest-budget) estimate for resume.
                c = guarded(point, lambda: cost(point, budget))
                if math.isfinite(c):
                    self.db.record_trial(bp, point, c, layer)
                return c
            prior = None if fresh else self.db.trial_cost(bp, point)
            if prior is not None:
                return prior  # resume support: interrupted AT re-uses trials
            c = guarded(point, lambda: cost(point))
            if math.isfinite(c):
                self.db.record_trial(bp, point, c, layer)
            return c

        # budgeted searches probe this to decide whether budgets pass through
        caching_cost.supports_budget = supports_budget

        tr = current_tracer()
        if tr is None:
            result = (search or self.search).run(region.space, caching_cost)
        else:
            with tr.span(
                "tuner.tune", cat="tuner", region=region.name, layer=layer,
                fingerprint=bp.fingerprint(),
            ) as attrs:
                result = (search or self.search).run(region.space, caching_cost)
                attrs["evaluations"] = result.evaluations
                attrs["best_pp"] = pp_key(result.best.point)
                attrs["best_cost"] = result.best.cost
        if not math.isfinite(result.best.cost):
            # every candidate raised or returned NaN/inf: there is no sane
            # winner to select or finalize — fail the search loudly (the
            # BackgroundTuner records it as a failed job; the live path
            # keeps serving on the region's default selection)
            raise RuntimeError(
                f"tuning failed for {region.name}: every candidate "
                "quarantined (raising or non-finite cost)"
            )
        if finalize:
            self.db.record_best(
                bp, result.best.point, result.best.cost, layer,
                space_signature=getattr(region, "space_signature", None),
            )
        if select:
            region.select(result.best.point)
        return result


class RuntimeSelector:
    """FIBER run-time layer: monitor the live candidate, re-select if it regresses.

    This doubles as our straggler-mitigation hook: a candidate whose measured
    cost drifts ``tolerance``× above its tuned cost (e.g. a slow host, noisy
    neighbour, thermal throttle) is demoted and the next-best precompiled
    candidate takes over — no recompilation, mirroring the paper's free
    ``omp_set_num_threads`` switches.
    """

    def __init__(
        self,
        region: ATRegion,
        bp: BasicParams,
        db: TuningDB,
        tolerance: float = 1.5,
        window: int = 8,
    ) -> None:
        self.region = region
        self.bp = bp
        self.db = db
        self.tolerance = tolerance
        self.window = window
        self._recent: list = []
        ranked = sorted(db.trials(bp).items(), key=lambda kv: kv[1])
        self._ranking = [k for k, _ in ranked]
        self.switches = 0

    def observe(self, measured_cost: float) -> bool:
        """Record a live measurement; returns True if a re-selection happened."""
        self.db.record_runtime_observation(self.bp, self.region.selected, measured_cost)
        self._recent.append(measured_cost)
        if len(self._recent) > self.window:
            self._recent.pop(0)
        tuned = self.db.trial_cost(self.bp, self.region.selected)
        if tuned is None or len(self._recent) < self.window:
            return False
        median = sorted(self._recent)[len(self._recent) // 2]
        if median <= self.tolerance * tuned:
            return False
        # Demote: pick the best-ranked *precompiled* candidate that is not the
        # current one (switching must stay free — no compilation at run time).
        # If nothing is precompiled (plain regions), any ranked candidate will do.
        import json

        current = pp_key(self.region.selected)
        others = [k for k in self._ranking if k != current]
        pool = [k for k in others if self.region.is_compiled_key(k)] or others
        if pool:
            self.region.select(json.loads(pool[0]))
            self._recent.clear()
            self.switches += 1
            return True
        return False
