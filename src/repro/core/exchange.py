"""The ``Exchange`` + ``LoopFusion`` candidate generator (paper §III).

ppOpen-AT's model: an N-deep perfect loop nest whose body is an elementwise
"calculation kernel".  Two composable transforms produce the candidate
family:

* **LoopFusion (collapse)** — merge the innermost ``N-m+1`` dims into one
  loop, leaving an ``m``-deep nest (m = 1..N).
* **Exchange (directive position)** — place the parallel directive on loop
  ``j`` of the transformed nest (j = 1..m).

This yields ``N(N+1)/2`` variants — exactly the paper's 10 for the GKV
quadruple loop (Figs 1–10).

JAX realization of one variant ``(m, j)`` with parallelism degree ``d``
(the ``omp_set_num_threads`` analogue — see :mod:`repro.core.degree`):

* loops **above** the directive run sequentially (``lax.map`` steps), as in
  OpenMP where each outer iteration forks/joins a parallel region;
* the **directive loop** (length P) is split into ``min(d, P)`` chunks of
  ``ceil(P/d)`` iterations — OpenMP static scheduling.  Chunks execute as
  ``lax.map`` steps (this host has one core, so "threads" serialize; the
  *structure* — grain size, vector shapes — is what the variant changes,
  and it is the structure that the FX100 results are about: a 65-long loop
  split 32 ways leaves 2-element vectors, killing pipelining there and
  vectorization here);
* loops **below** the directive are fully vectorized inside the body block
  (collapse becomes a reshape — free under XLA, unlike the Fortran div/mod
  index reconstruction; recorded as an assumption change in docs/design.md §7).

The same (m, j, d) family drives the Pallas kernel's (grid, BlockSpec)
candidates in :mod:`repro.kernels.exb` — grid = outer×chunks, block = chunk
× inner — so the paper's transform is applied identically at both levels.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial, reduce
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .params import ParamSpace, PerfParam
from .region import ATRegion


@dataclass(frozen=True)
class ExchangeVariant:
    """One candidate loop structure: m loops after collapse, directive on j."""

    m: int  # loop count of transformed nest (innermost N-m+1 dims collapsed)
    j: int  # 1-based directive depth in the transformed nest, 1 <= j <= m

    def __post_init__(self) -> None:
        if not (1 <= self.j <= self.m):
            raise ValueError(f"invalid variant (m={self.m}, j={self.j})")

    def label(self, dim_names: Sequence[str]) -> str:
        n = len(dim_names)
        loops = [str(d) for d in dim_names[: self.m - 1]]
        collapsed = "_".join(str(d) for d in dim_names[self.m - 1 :])
        loops.append(collapsed)
        marked = [f"OMP[{l}]" if i + 1 == self.j else l for i, l in enumerate(loops)]
        return ">".join(marked)


def enumerate_exchange_variants(ndims: int) -> List[ExchangeVariant]:
    """All (collapse-depth × directive-position) candidates — N(N+1)/2 of them.

    Ordered to match the paper's figures for N=4:
    (4,2)=Fig1 original, (3,2)=Fig2, (2,2)=Fig3, (4,1)=Fig4, (3,1)=Fig5,
    (2,1)=Fig6, (1,1)=Fig7, (4,3)=Fig8, (3,3)=Fig9, (4,4)=Fig10.
    """
    variants = []
    for m in range(ndims, 0, -1):
        for j in range(1, m + 1):
            variants.append(ExchangeVariant(m=m, j=j))
    return variants


# The paper's figure numbering for the GKV quadruple loop (N=4).
GKV_FIGURE_OF_VARIANT: Dict[Tuple[int, int], str] = {
    (4, 2): "Fig1:original",
    (3, 2): "Fig2:xy-collapse",
    (2, 2): "Fig3:zxy-collapse",
    (4, 1): "Fig4:omp@outermost",
    (3, 1): "Fig5:omp@outermost+xy",
    (2, 1): "Fig6:omp@outermost+zxy",
    (1, 1): "Fig7:vzxy-collapse",
    (4, 3): "Fig8:omp@depth3",
    (3, 3): "Fig9:omp@mx_my",
    (4, 4): "Fig10:omp@innermost",
}


def _prod(xs: Sequence[int]) -> int:
    return reduce(lambda a, b: a * b, xs, 1)


class LoopNest:
    """An N-deep elementwise loop nest bracketed as an AT region.

    ``body`` is a pure function ``body(inputs_block) -> output_block`` that
    must be shape-polymorphic (elementwise kernels are).  ``inputs`` given to
    :meth:`run_variant` / :meth:`reference` are a pytree whose array leaves
    are all shaped exactly ``lengths`` (pre-broadcast by the caller; GKV's
    rank-3 fields are broadcast against the rank-4 domain once, outside the
    timed region, matching how the Fortran code streams them repeatedly).
    """

    def __init__(
        self,
        name: str,
        dims: Sequence[Tuple[str, int]],
        body: Callable[[Any], Any],
    ) -> None:
        if not dims:
            raise ValueError("LoopNest needs at least one dim")
        self.name = name
        self.dim_names = tuple(d[0] for d in dims)
        self.lengths = tuple(int(d[1]) for d in dims)
        self.body = body

    # -- oracle ---------------------------------------------------------------

    def reference(self, inputs: Any) -> Any:
        """Whole-domain single-shot evaluation — the pure-jnp oracle."""
        return self.body(inputs)

    # -- candidate execution ----------------------------------------------------

    def variant_fn(
        self, variant: ExchangeVariant, degree: int
    ) -> Callable[[Any], Any]:
        """Build the pure callable for one (variant, degree) candidate."""
        n = len(self.lengths)
        if variant.m > n:
            raise ValueError(f"variant {variant} exceeds nest depth {n}")
        jj = variant.j - 1  # 0-based directive loop index in transformed nest
        if jj < variant.m - 1:
            # directive on an uncollapsed dim
            outer_lens = self.lengths[:jj]
            par_len = self.lengths[jj]
            inner_shape = tuple(self.lengths[jj + 1 : variant.m - 1]) + (
                _prod(self.lengths[variant.m - 1 :]),
            )
        else:
            # directive on the collapsed innermost group
            outer_lens = self.lengths[: variant.m - 1]
            par_len = _prod(self.lengths[variant.m - 1 :])
            inner_shape = ()

        o_len = _prod(outer_lens)
        nchunks = max(1, min(int(degree), par_len))  # threads beyond P idle
        chunk = -(-par_len // nchunks)  # ceil — OpenMP static schedule grain
        padded = nchunks * chunk
        pad = padded - par_len
        full = self.lengths

        def run(inputs: Any) -> Any:
            def to_blocks(x: jnp.ndarray) -> jnp.ndarray:
                x = x.reshape((o_len, par_len) + inner_shape)
                if pad:
                    widths = [(0, 0)] * x.ndim
                    widths[1] = (0, pad)
                    x = jnp.pad(x, widths, mode="edge")
                return x.reshape((o_len * nchunks, chunk) + inner_shape)

            xs = jax.tree.map(to_blocks, inputs)
            ys = lax.map(self.body, xs)

            def from_blocks(y: jnp.ndarray) -> jnp.ndarray:
                y = y.reshape((o_len, padded) + inner_shape)
                if pad:
                    y = lax.slice_in_dim(y, 0, par_len, axis=1)
                return y.reshape(full)

            return jax.tree.map(from_blocks, ys)

        run.__name__ = f"{self.name}_{variant.label(self.dim_names)}_d{degree}"
        return run

    # -- AT region ----------------------------------------------------------------

    def at_region(
        self,
        degrees: Sequence[int] = (1, 2, 4, 8, 16, 32),
        variants: Optional[Sequence[ExchangeVariant]] = None,
    ) -> ATRegion:
        """Bracket this nest as an AT region over (variant × degree).

        This is the ``!oat$ install Exchange region start/end`` +
        dynamic-thread-count PP of the paper, as one joint space (§V co-tunes
        them because the optimal degree depends on the variant).
        """
        vs = tuple(variants or enumerate_exchange_variants(len(self.lengths)))
        space = ParamSpace(
            [
                PerfParam("variant", tuple((v.m, v.j) for v in vs)),
                PerfParam("degree", tuple(int(d) for d in degrees)),
            ]
        )

        def instantiate(point: Mapping[str, Any]) -> Callable[[Any], Any]:
            m, j = point["variant"]
            return self.variant_fn(ExchangeVariant(m=m, j=j), point["degree"])

        return ATRegion(
            name=self.name, space=space, instantiate=instantiate, oracle=self.reference
        )
