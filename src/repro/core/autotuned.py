"""AutotunedOp — registry-backed dispatch for one tunable op.

The life of a call ``autotuned("flash_attention")(q, k, v)``:

1. **shape class** — ``spec.shape_class(*args)`` buckets the call into a
   :class:`~repro.core.params.BasicParams` (the DB key).
2. **lookup** — an in-process state cache, then the TuningDB.  Either hit
   means *zero* cost-function evaluations (the acceptance bar: a second call
   for the same shape class never re-tunes, even in a fresh process reading
   the same DB file).
3. **tune on miss** — the configured :class:`~repro.core.search.Search`
   under ``trial_budget`` evaluations; every trial lands in the DB, so an
   interrupted sweep resumes where it stopped.  With no pinned search the
   op builds a per-shape-class staged pipeline (docs/tuning.md): a
   **cross-shape-class warm start** (the nearest already-tuned sibling
   class seeds the search) when the DB has one, a **roofline prescreen →
   measured finals** :class:`~repro.core.search.StagedSearch` when the spec
   provides a ``prescreen_factory`` (or ``staged=True`` forces the generic
   compile-only prescreen), and plain exhaustive measured search otherwise.
4. **top-k AOT warm** — the k best candidates are materialized through
   ``region.candidate`` (compiling them for this shape class), so run-time
   switching is a dict lookup — ppOpen-AT's free ``omp_set_num_threads``
   switch, generalized.
5. **run-time layer** — a :class:`~repro.core.tuner.RuntimeSelector` watches
   measured call times and demotes a regressing candidate to the next-best
   *precompiled* one.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional

import jax

from ..obs.trace import current_tracer
from .cost import AdaptiveWallClockCost, roofline_prescreen
from .db import TuningDB
from .params import BasicParams, pp_key, project_point
from .region import ATRegion
from .registry import KernelSpec
from .search import CoordinateDescent, Search, StagedSearch, default_prescreen_k
from .traffic import TrafficClass
from .tuner import RuntimeSelector, Tuner


class TrialBudgetExhausted(Exception):
    """Raised internally when a search hits its evaluation budget."""

    # marks this as tuner control flow: the measurement guardrail in
    # Tuner.tune must re-raise it, not quarantine the candidate it
    # happened to interrupt
    tuning_control = True


# Upper bound on fast-dispatch routes per op.  Structural keys include
# hashable scalar argument *values*, so an op called with an unbounded
# stream of distinct scalars (a step counter, say) would otherwise leak one
# entry per value; past the limit new keys simply stay on the slow path
# (correct, just not collapsed), while the bounded _states cache still
# dedupes by shape class.
FAST_TABLE_LIMIT = 512


class _FastEntry:
    """One finalized dispatch route: structural arg key -> bound callable.

    ``version`` mirrors the region's selection version at bind time; a
    RuntimeSelector demotion or joint-program hot apply bumps the region's
    version, and the next fast call rebinds with one dict lookup — the
    finalized class never re-enters the slow path (no BP extraction, no
    lock, no selector walk).
    """

    __slots__ = ("fn", "state", "region", "version", "calls")

    def __init__(self, fn: Callable[..., Any], state: "OpState", version: int) -> None:
        self.fn = fn
        self.state = state
        self.region = state.region
        self.version = version
        self.calls = 0


def _arg_sig(a: Any) -> Any:
    """Cheap structural signature of one call argument (shape-class safe).

    Arrays key on (shape, dtype); containers recurse; hashable scalars key
    on value.  Raises TypeError for anything else — the caller falls back
    to the slow path rather than guessing.
    """
    try:
        return (a.shape, a.dtype)  # the hot case: arrays
    except AttributeError:
        pass
    if isinstance(a, (int, float, str, bool, bytes)) or a is None:
        return a
    if isinstance(a, dict):
        return tuple(sorted((k, _arg_sig(v)) for k, v in a.items()))
    if isinstance(a, (list, tuple)):
        return tuple(map(_arg_sig, a))
    raise TypeError(f"unkeyable dispatch argument: {type(a)!r}")


def _fast_key(args: tuple, kwargs: dict) -> Optional[tuple]:
    """Structural dispatch key, or ``None`` when args cannot be keyed."""
    try:
        if kwargs:
            return (
                tuple(map(_arg_sig, args)),
                tuple(sorted((k, _arg_sig(v)) for k, v in kwargs.items())),
            )
        return tuple(map(_arg_sig, args))
    except TypeError:
        return None


@dataclass
class OpState:
    """Everything the op holds for one shape class."""

    bp: BasicParams
    region: ATRegion
    selector: Optional[RuntimeSelector] = None
    tuned: bool = False           # did *this process* run cost evaluations?
    from_cache: bool = False      # selection came from the DB, zero evals
    cost_evaluations: int = 0     # measured (stage-2) evaluations only
    prescreen_evaluations: int = 0  # cheap stage-1 scores (never measured)
    warm_seed: Optional[Dict[str, Any]] = None  # cross-class warm-start seed
    warmed: int = 0
    traffic: Optional[TrafficClass] = None  # set when the spec buckets traffic
    tune_thread: Optional[int] = None       # ident of the thread that tuned


class AutotunedOp:
    """Callable dispatcher for one registered kernel.

    ``monitor=True`` (default) blocks on the output and feeds the measured
    wall time to the RuntimeSelector; latency-critical callers that do their
    own timing (the train loop) pass ``monitor=False`` and call
    ``state.selector.observe`` themselves.
    """

    def __init__(
        self,
        spec: KernelSpec,
        registry=None,
        db: Optional[TuningDB] = None,
        search: Optional[Search] = None,
        top_k: int = 2,
        trial_budget: Optional[int] = None,
        warm: bool = True,
        tune: bool = True,
        monitor: bool = True,
        tolerance: float = 1.5,
        window: int = 8,
        cost_factory: Optional[Callable[..., Callable[[Mapping[str, Any]], float]]] = None,
        staged: Optional[bool] = None,
        prescreen_k: Optional[int] = None,
        warm_start: bool = True,
        fast_dispatch: bool = True,
        monitor_every: int = 64,
        device_key: Optional[bool] = None,
        drift: Optional[Any] = None,
    ) -> None:
        self.spec = spec
        self._registry = registry
        self._db = db
        self.search = search
        self.top_k = top_k
        self.trial_budget = trial_budget
        self.warm = warm
        self.tune = tune
        self.monitor = monitor
        self.tolerance = tolerance
        self.window = window
        self.cost_factory = cost_factory or spec.cost_factory
        # staged-pipeline policy (only consulted when no ``search`` is
        # pinned): None = staged iff the spec has a prescreen_factory,
        # True = force the generic roofline prescreen, False = never stage.
        self.staged = staged
        self.prescreen_k = prescreen_k
        self.warm_start = warm_start
        # zero-overhead dispatch (docs/program.md): once a shape class is
        # *final* (completed search in the DB), calls collapse to one dict
        # lookup on a structural key — no BP extraction, no fingerprint
        # hash, no lock.  Value-dependent class extraction (traffic-class
        # specs bucket on runtime scalars) cannot be keyed structurally, so
        # those ops stay on the slow path.
        self.fast_dispatch = fast_dispatch and spec.traffic_class is None
        self.monitor_every = max(1, monitor_every)
        # fleet device keying (docs/fleet.md): extend every shape class with
        # the host's DeviceFingerprint BP entries, so finals only recall on
        # the matching device and heterogeneous DBs merge without
        # clobbering.  Opt-in per op (None defers to REPRO_DEVICE_KEY) —
        # flipping it changes every BP fingerprint, i.e. starts a fresh
        # device-scoped namespace in an existing DB.
        if device_key is None:
            import os

            device_key = os.environ.get(
                "REPRO_DEVICE_KEY", ""
            ).lower() in ("1", "true", "yes")
        self.device_key = bool(device_key)
        # drift watch (docs/fleet.md): a DriftMonitor fed by the same
        # run-time trickle the RuntimeSelector gets; settable post-hoc
        # (op.drift = monitor) since monitors usually outlive one op.
        self.drift = drift
        self._fast: Dict[tuple, _FastEntry] = {}
        self.slow_resolutions = 0  # full shape-class resolutions performed
        self._states: Dict[str, OpState] = {}
        self._state_lock = threading.Lock()  # guards the two dicts below
        self._build_locks: Dict[str, threading.Lock] = {}

    # -- public --------------------------------------------------------------

    @property
    def db(self) -> TuningDB:
        if self._db is None:
            if self._registry is None:
                self._db = TuningDB()
            else:
                self._db = self._registry.default_db()
        return self._db

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        if self._fast:
            entry = self._fast_lookup(args, kwargs)
            if entry is not None:
                entry.calls += 1
                if self.monitor and entry.calls % self.monitor_every == 0:
                    # a trickle of run-time-layer observations keeps the
                    # straggler watch alive without per-call timing; still
                    # no BP extraction, no lock, no re-resolution
                    return self._monitored(entry.state, args, kwargs)
                return entry.fn(*args, **kwargs)
        state = self.resolve(*args, **kwargs)
        self._maybe_install_fast(state, args, kwargs)
        if not self.monitor or state.selector is None:
            return state.region(*args, **kwargs)
        return self._monitored(state, args, kwargs)

    def dispatch(self, *args: Any, **kwargs: Any) -> Callable[..., Any]:
        """The callable this call would execute — dispatch decision only.

        On the fast path this is a single dict lookup; otherwise a full
        resolution (tuning on a miss, like ``__call__``).  The dispatch
        microbenchmark times exactly this.
        """
        if self._fast:
            entry = self._fast_lookup(args, kwargs)
            if entry is not None:
                return entry.fn
        state = self.resolve(*args, **kwargs)
        self._maybe_install_fast(state, args, kwargs)
        return state.region.candidate(state.region.selected)

    def finalize(self, state: OpState, *args: Any, **kwargs: Any) -> bool:
        """Install the fast dispatch route for ``state`` and these args.

        Used by callers that pin or hot-apply a selection outside a
        completed per-kernel search (joint program winners): the class is
        final *by decree*, so dispatch may collapse even though the op's
        own DB entry never finished a search.
        """
        if not self.fast_dispatch:
            return False
        key = _fast_key(args, kwargs)
        if key is None:
            return False
        region = state.region
        version = region.version  # pre-read: same stale-pin guard as
        # _fast_lookup — a concurrent select() just forces one extra rebind
        entry = _FastEntry(region.candidate(region.selected), state, version)
        with self._state_lock:
            if key not in self._fast and len(self._fast) >= FAST_TABLE_LIMIT:
                return False  # bounded: overflow keys keep the slow path
            self._fast[key] = entry
        return True

    def _monitored(self, state: OpState, args: tuple, kwargs: dict) -> Any:
        if state.selector is None:
            return state.region(*args, **kwargs)
        t0 = time.perf_counter()
        out = state.region(*args, **kwargs)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        state.selector.observe(dt)
        if self.drift is not None:
            # the same trickle feeds the fleet drift watch: demotion /
            # canary decisions ride the monitor_every observations the
            # fast path already pays for (docs/fleet.md)
            self.drift.observe(self, state, dt, args, kwargs)
        return out

    def _fast_lookup(self, args: tuple, kwargs: dict) -> Optional[_FastEntry]:
        # flat on purpose: this is the measured per-call overhead, so the
        # key is built inline (no helper-call tower) and misses bail early
        try:
            if kwargs:
                key = (
                    tuple(map(_arg_sig, args)),
                    tuple(sorted((k, _arg_sig(v)) for k, v in kwargs.items())),
                )
            else:
                key = tuple(map(_arg_sig, args))
        except TypeError:
            return None
        entry = self._fast.get(key)
        if entry is None:
            return None
        region = entry.region
        version = region.version  # read BEFORE building the callable: if a
        # concurrent select() lands in between, we store the older version
        # and the next call rebinds again — never the reverse (a stale
        # callable pinned under a newer version would stick forever)
        if entry.version != version:
            # selection moved (demotion / joint hot apply): rebind, still
            # without touching the slow path
            entry.fn = region.candidate(region.selected)
            entry.version = version
        return entry

    def _maybe_install_fast(self, state: OpState, args: tuple, kwargs: dict) -> None:
        """Collapse future dispatches once this shape class is final."""
        if not self.fast_dispatch:
            return
        if not (state.from_cache or state.tuned):
            return
        sig = getattr(state.region, "space_signature", None)
        if self.db.tuned_point(state.bp, space_signature=sig) is None:
            return  # interim winner (budget-capped sweep): not final yet
        self.finalize(state, *args, **kwargs)

    def resolve(self, *args: Any, **kwargs: Any) -> OpState:
        """The op's state for this call's shape class, tuning if needed."""
        return self._resolve(args, kwargs, self.tune)

    def resolve_deferred(self, *args: Any, **kwargs: Any) -> OpState:
        """Resolve without ever tuning on the calling thread.

        The background-tuner entry: a DB hit still selects the tuned winner,
        a miss returns the safe default for someone else to tune later.
        Unlike toggling ``self.tune`` around ``resolve``, this is safe under
        concurrent callers.
        """
        return self._resolve(args, kwargs, False)

    def _resolve(self, args: tuple, kwargs: dict, tune: bool) -> OpState:
        self.slow_resolutions += 1
        bp = self.spec.shape_class(*args, **kwargs)
        traffic = None
        if self.spec.traffic_class is not None:
            traffic = self.spec.traffic_class(*args, **kwargs)
            bp = bp.with_entries(**traffic.bp_entries())
        if self.device_key:
            from repro.fleet.fingerprint import device_bp_entries

            bp = bp.with_entries(**device_bp_entries())
        fp = bp.fingerprint()
        # one canonical state per shape class even under concurrent callers:
        # a losing racer must not build (and possibly tune) a duplicate that
        # the background tuner would then hot-swap into the void.  The build
        # runs under a per-fingerprint lock so an inline tune of one class
        # never blocks resolution of another.
        with self._state_lock:
            state = self._states.get(fp)
            if state is not None:
                return state
            build_lock = self._build_locks.setdefault(fp, threading.Lock())
        with build_lock:
            with self._state_lock:
                state = self._states.get(fp)
            if state is not None:
                return state
            # tracer guard lives HERE, on the slow path only: the fast
            # dispatch route in __call__/_fast_lookup carries zero tracer
            # code (the bench_dispatch >=10x and obs_overhead <=2% gates)
            tr = current_tracer()
            if tr is None:
                state = self._build_state(bp, args, kwargs, tune)
            else:
                with tr.span(
                    "dispatch.resolve", cat="dispatch", op=self.spec.name,
                    fingerprint=fp,
                ) as attrs:
                    state = self._build_state(bp, args, kwargs, tune)
                    attrs["from_cache"] = state.from_cache
                    attrs["tuned"] = state.tuned
            state.traffic = traffic
            with self._state_lock:
                self._states[fp] = state
            return state

    def select(self, point: Mapping[str, Any], *args: Any, **kwargs: Any) -> OpState:
        """Pin a PP point for this shape class (bypasses tuning)."""
        state = self.resolve_deferred(*args, **kwargs)
        state.region.select(point)
        return state

    def states(self) -> Dict[str, OpState]:
        return dict(self._states)

    def retune_state(
        self, state: OpState, args: tuple, kwargs: dict
    ) -> Dict[str, Any]:
        """Fresh re-measure of an already-tuned class (the drift path).

        Unlike :meth:`tune_state` this runs even when ``state.tuned`` /
        ``from_cache`` — that is the point: the recorded winner drifted.
        The search re-measures every candidate (``fresh``: the recorded
        trial costs are what reality walked away from), does NOT select the
        winner (the caller canaries it first), does NOT record a final (the
        challenger earns that by surviving its canary window), and warms
        the challenger so the canary hot swap never compiles.
        """
        winner = self._tune(state, args, kwargs, select=False, fresh=True,
                            finalize=False)
        fn = state.region.candidate(winner)
        if (args or kwargs) and dict(winner) != dict(state.region.selected):
            jax.block_until_ready(fn(*args, **kwargs))
        return winner

    def tune_state(
        self,
        state: OpState,
        args: tuple,
        kwargs: dict,
        search: Optional[Search] = None,
    ) -> OpState:
        """Run deferred tuning for an already-resolved state.

        This is the background-tuner entry point: ``resolve_deferred`` hands
        out a state serving the region's safe default, and a worker thread
        later calls this to search, warm the top-k, and hot-swap the
        region's selection — the serve hot path never pays a cost
        evaluation.  Ordering matters: the search runs with ``select=False``
        so the hot path keeps serving the (already compiled) default while
        we warm — selecting the winner before it is compiled would hand a
        concurrent request its trace/compile cost.  Only once the winner is
        warm does ``region.select`` swap it in.  Warming happens here
        regardless of ``self.warm`` (we are off the hot path by
        construction), and the selector is rebuilt because its ranking was
        computed before any trials existed.
        """
        if state.tuned or state.from_cache:
            return state
        winner = self._tune(state, args, kwargs, select=False, search=search)
        state.warmed = self._warm_topk(state, args, kwargs)
        if (args or kwargs) and dict(winner) == dict(state.region.selected):
            # winner == the live default: _warm_topk skipped executing it
            # ("about to run for real" — true inline, false here), so pay
            # any residual compile on this worker thread
            jax.block_until_ready(state.region.candidate(winner)(*args, **kwargs))
        state.region.select(winner)  # the hot swap: winner is warm by now
        state.selector = RuntimeSelector(
            state.region, state.bp, self.db,
            tolerance=self.tolerance, window=self.window,
        )
        return state

    # -- internals -----------------------------------------------------------

    def _build_state(
        self, bp: BasicParams, args: tuple, kwargs: dict, tune: bool
    ) -> OpState:
        region = self.spec.make_region(bp)
        state = OpState(bp=bp, region=region)
        sig = getattr(region, "space_signature", None)
        if sig is not None:
            # emitted region: a final recorded under a different emission
            # (changed arch model / emit policy) is stale — demote it and
            # drop its trials so the search below starts clean
            self.db.invalidate_stale_final(bp, sig)
        tuned = self.db.tuned_point(bp, space_signature=sig)
        if tuned is not None:
            region.select(tuned)
            state.from_cache = True
        elif tune:
            self._tune(state, args, kwargs)
        if self.warm:
            state.warmed = self._warm_topk(state, args, kwargs)
        state.selector = RuntimeSelector(
            region, bp, self.db, tolerance=self.tolerance, window=self.window
        )
        return state

    def _tune(
        self,
        state: OpState,
        args: tuple,
        kwargs: dict,
        select: bool = True,
        fresh: bool = False,
        finalize: bool = True,
        search: Optional[Search] = None,
    ) -> Dict[str, Any]:
        """Search this state's PP space; returns the winning point.

        ``select=False`` leaves the region's live selection untouched (the
        background path swaps only after warming the winner).  ``fresh`` /
        ``finalize`` implement the drift re-tune (see :meth:`retune_state`);
        ``search`` overrides the strategy for this one run (the
        BackgroundTuner's fleet-sharded mode).
        """
        region, bp = state.region, state.bp
        search = search or self.search or self._default_search(state, args, kwargs)
        if self.cost_factory is not None:
            cost = self.cost_factory(region, bp, args, kwargs)
        else:
            # a staged search's prescreen keeps its compiled executables;
            # the measured stage runs on the same example args, so survivors
            # execute those artifacts instead of compiling a second time
            precompiled = getattr(
                getattr(search, "prescreen", None), "compiled_by_point", None
            )
            cost = _wallclock_cost(region, args, kwargs, precompiled)

        def budgeted(
            point: Mapping[str, Any], budget: Optional[int] = None
        ) -> float:
            if (
                self.trial_budget is not None
                and state.cost_evaluations >= self.trial_budget
            ):
                raise TrialBudgetExhausted(self.spec.name)
            state.cost_evaluations += 1
            if budget is not None and budgeted.supports_budget:
                return cost(point, budget)
            return cost(point)

        # let budget-aware searches (SuccessiveHalving rungs) pass their
        # repeat budget through to an AdaptiveWallClockCost-style cost
        budgeted.supports_budget = bool(getattr(cost, "supports_budget", False))

        tuner = Tuner(self.db)
        try:
            result = tuner.tune(region, bp, budgeted, select=select,
                                search=search, fresh=fresh, finalize=finalize)
            state.prescreen_evaluations += result.prescreen_evaluations
            winner = dict(result.best.point)
            self._record_search_event(state, result, winner)
        except TrialBudgetExhausted:
            # Budget hit mid-search: select the argmin over what we measured,
            # but do NOT record a DB best — only a completed search is final,
            # so the next run resumes from the recorded trials and keeps
            # exploring instead of treating the interim winner as tuned.
            trials = self.db.trials(bp)
            if not trials:
                raise ValueError(
                    f"{self.spec.name}: trial_budget={self.trial_budget} "
                    "allowed no evaluations"
                ) from None
            best_key = min(trials, key=trials.get)
            winner = json.loads(best_key)
            if select:
                region.select(winner)
        state.tuned = True
        state.tune_thread = threading.get_ident()
        return winner

    def _record_search_event(
        self, state: OpState, result: Any, winner: Mapping[str, Any]
    ) -> None:
        """Persist the decision audit of a completed search: the measured
        winner, how many candidates each stage touched, and the prescreen
        ranking that chose the finalists — what ``launch/observe.py
        explain`` later replays against the measured trial costs."""
        payload: Dict[str, Any] = {
            "winner": pp_key(winner),
            "cost": float(result.best.cost),
            "evaluations": result.evaluations,
            "prescreen_evaluations": result.prescreen_evaluations,
        }
        if result.prescreen_costs:
            ranked = sorted(
                result.prescreen_costs.items(), key=lambda kv: (kv[1], kv[0])
            )
            payload["prescreen_rank"] = [k for k, _ in ranked[:8]]
        sig = getattr(state.region, "space_signature", None)
        if sig is not None:
            payload["space_sig"] = str(sig)
        if state.warm_seed is not None:
            payload["warm_seed"] = pp_key(state.warm_seed)
        self.db.record_event(state.bp, "search_completed", **payload)

    def _default_search(
        self, state: OpState, args: tuple, kwargs: dict
    ) -> Optional[Search]:
        """The per-shape-class strategy when no search was pinned.

        Priority (docs/tuning.md): a staged prescreen → measured-finals
        pipeline when the op has a prescreen and the space is big enough to
        prune; a warm-started refinement when a sibling shape class is
        already tuned (seeding either the staged ranking or a
        CoordinateDescent hillclimb); ``None`` otherwise — the Tuner's
        exhaustive default, the paper's faithful strategy.
        """
        space = state.region.space
        seed = None
        if self.warm_start:
            near = self.db.nearest_tuned(state.bp)
            if near is not None:
                seed = project_point(space, near["point"])
                if seed is not None:
                    # warm-start provenance: which sibling class seeded this
                    # search and how far away it was (explainability trail)
                    self.db.record_event(
                        state.bp, "warm_start",
                        source_fp=near.get("fingerprint"),
                        distance=near["distance"], seed=dict(seed),
                    )
        prescreen = None
        if self.staged is not False:
            if self.spec.prescreen_factory is not None:
                prescreen = self.spec.prescreen_factory(
                    state.region, state.bp, args, kwargs
                )
            elif self.staged:
                prescreen = roofline_prescreen(state.region, state.bp, args, kwargs)
        if prescreen is not None:
            n = sum(1 for _ in space.points())
            k = self.prescreen_k or default_prescreen_k(n)
            if n > k:  # otherwise nothing would be pruned: prescreen is waste
                if seed is not None:
                    state.warm_seed = dict(seed)
                return StagedSearch(prescreen, k=k, warm_start=seed)
        if seed is not None:
            state.warm_seed = dict(seed)
            return CoordinateDescent(start=seed)
        return None

    def _warm_topk(self, state: OpState, args: tuple, kwargs: dict) -> int:
        """Materialize the k best candidates so switching never compiles."""
        ranked = sorted(self.db.trials(state.bp).items(), key=lambda kv: kv[1])
        points: List[Dict[str, Any]] = [json.loads(k) for k, _ in ranked]
        if not points:  # untuned (pinned selection): warm the live point only
            points = [dict(state.region.selected)]
        warmed = 0
        for point in points[: max(1, self.top_k)]:
            fn = state.region.candidate(point)  # caches into region._compiled
            # the selected point is about to run for real — executing it here
            # too would double the first call's latency for nothing
            if (args or kwargs) and dict(point) != state.region.selected:
                jax.block_until_ready(fn(*args, **kwargs))
            warmed += 1
        return warmed


def _wallclock_cost(
    region: ATRegion,
    args: tuple,
    kwargs: dict,
    precompiled: Optional[Mapping[str, Any]] = None,
) -> Callable[[Mapping[str, Any]], float]:
    """Default measured cost: compile (untimed), then adaptive timed runs.

    Variance-aware repeats (docs/tuning.md): the first steady-state run is
    free to end the point's measurement if it is already clearly off the
    incumbent; candidates within noise of the lead earn up to two more runs
    until the confidence interval separates.

    ``precompiled`` maps pp_keys to argument-specialized executables the
    staged prescreen already built for these exact example args — reusing
    them here skips the survivors' second compilation.  They are measurement
    artifacts only and never enter ``region._compiled`` (dispatch stays on
    shape-polymorphic jitted candidates; "precompiled" for the selector
    still means the top-k warm set).
    """
    from .params import pp_key

    def build(point: Mapping[str, Any]) -> Callable[[], Any]:
        if precompiled:
            compiled = precompiled.get(pp_key(point))
            if compiled is not None:
                return lambda: compiled(*args, **kwargs)
        fn = region.instantiate(point)  # NOT region.candidate: only the
        # top-k winners should count as "precompiled" for the selector
        return lambda: fn(*args, **kwargs)

    return AdaptiveWallClockCost(build, warmup=1, min_repeats=1, max_repeats=3)
