"""Continuous-batching streaming engine (docs/serving.md).

:class:`~repro.runtime.serve.Server` batches a request *list*: compose a
fixed-size group, prefill it once, decode every row to the group's max —
padded tail rows and short requests ride along as waste, and a request that
arrives mid-batch waits for the whole batch to finish.  This module replaces
that with the engine shape every production LLM server converged on
(Orca-style iteration-level scheduling, vLLM-style paged KV):

* an **admission queue** consumes :class:`~repro.data.pipeline.ServingRequest`
  with open-loop ``arrival_s`` timestamps (``bursty_open_loop_trace``);
* an **iteration-level scheduler** composes every step from interleaved
  prefill and decode work and retires a finished request *that step* — no
  row ever decodes past its own ``max_new_tokens``;
* a **paged KV cache**: a block pool with a free-list
  :class:`BlockAllocator` and a ``block_table`` (rid → block).  Blocks here
  are sequence-granular — one block holds one request's whole KV row at
  fixed capacity, the honest granularity for a cache dict whose layout the
  model owns — so decode batches compose by *index gather/scatter* into the
  pool instead of the ``_cache_chunk``/``_cache_concat`` copy round-trips.

The paper's posture carries over intact.  Prefill groups and decode gathers
dispatch through registry ops (``engine_prefill`` / ``engine_decode``) whose
candidate family is the chunking **degree**, bracketed by the
:class:`~repro.core.degree.DegreeController`'s set-on-entry/restore-on-exit
protocol.  New here: the *scheduler itself* is a tuned kernel
(``serve_scheduler``) — prefill chunk size, prefill/decode interleave ratio,
admission policy and max in-flight form a
:class:`~repro.core.params.ParamSpace` keyed per
:class:`~repro.core.traffic.TrafficClass` of the *queue state* (phase
``stream``), searched off the hot path by the
:class:`~repro.runtime.background_tuner.BackgroundTuner` with a measured
shadow replay as the cost.  The DegreeController is thereby demoted from
"the serving policy" to one policy among the scheduler's knobs.

Decode composes heterogeneous positions by ``jax.vmap`` of the batch-1
decode step over gathered pool rows: ``cache["len"]`` is scalar per row, so
every request advances at its own position, and
:func:`~repro.models.attention.decode_attention` masks unwritten slots with
``-inf`` — extra pool capacity is numerically inert, which is what makes the
engine bit-match the one-request-at-a-time reference (the conformance test).
MoE is the one asymmetry: capacity-bounded dispatch couples rows *within a
prefill group* (prefill chunk pins to 1), but vmapped batch-1 decode rows
are independent, so MoE decode chunks freely — a capability the static
server never had.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ATRegion,
    AutotunedOp,
    BasicParams,
    DegreeController,
    KernelSpec,
    ParamSpace,
    PerfParam,
    TrafficClass,
    TuningDB,
    bucket_pow2,
    register_kernel,
)
from repro.core.autotuned import OpState
from repro.data.pipeline import ServingRequest
from repro.distributed.sharding import mesh_bp_entries
from repro.models import cache_batch_axis, decode_fn, init_cache, prefill_fn
from repro.models.config import ModelConfig
from repro.runtime.background_tuner import BackgroundTuner
from repro.runtime.serve import (
    _batch_chunk,
    _cache_concat,
    build_batch_inputs,
    check_unique_rids,
)


# ---------------------------------------------------------------------------
# Paged KV cache
# ---------------------------------------------------------------------------


class BlockAllocator:
    """Free-list allocator over a fixed pool of KV blocks."""

    def __init__(self, n_blocks: int) -> None:
        if n_blocks < 1:
            raise ValueError("n_blocks must be >= 1")
        self.n_blocks = int(n_blocks)
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.peak_in_use = 0

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_blocks - len(self._free)

    def allocate(self) -> int:
        if not self._free:
            raise RuntimeError(
                f"KV block pool exhausted ({self.n_blocks} blocks in use); "
                "the scheduler must bound admissions by allocator.free"
            )
        block = self._free.pop()
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return block

    def release(self, block: int) -> None:
        if not (0 <= block < self.n_blocks) or block in self._free:
            raise ValueError(f"release of invalid or free block {block}")
        self._free.append(block)


class PagedKVCache:
    """A block pool of per-request KV rows plus the rid → block table.

    Every leaf of the model's cache dict for batch 1 at fixed ``capacity``
    is stacked under a leading ``(n_blocks,)`` axis; the scalar ``len`` leaf
    becomes ``(n_blocks,)`` so each block carries its own position.  Insert
    scatters prefilled rows into allocated blocks; decode gathers rows by
    block index, steps them, and scatters the updated rows back — all under
    one jit, with no split/concat copies of the full cache.
    """

    def __init__(self, cfg: ModelConfig, n_blocks: int, capacity: int) -> None:
        self.cfg = cfg
        self.capacity = int(capacity)
        self.allocator = BlockAllocator(n_blocks)
        self.block_table: Dict[int, int] = {}
        row = jax.eval_shape(lambda: init_cache(cfg, 1, capacity))
        self.pool: Dict[str, jnp.ndarray] = {
            k: jnp.zeros((n_blocks,) + tuple(v.shape), v.dtype)
            for k, v in row.items()
        }
        self._insert_jit = jax.jit(_insert_rows)

    @property
    def n_blocks(self) -> int:
        return self.allocator.n_blocks

    @property
    def free(self) -> int:
        return self.allocator.free

    def allocate(self, rid: int) -> int:
        if rid in self.block_table:
            raise ValueError(f"rid {rid} already holds block {self.block_table[rid]}")
        block = self.allocator.allocate()
        self.block_table[rid] = block
        return block

    def release(self, rid: int) -> None:
        self.allocator.release(self.block_table.pop(rid))

    def block_of(self, rid: int) -> int:
        return self.block_table[rid]

    def insert(self, rids: Sequence[int], cache: Dict[str, Any]) -> None:
        """Scatter the rows of a freshly prefilled group cache into blocks.

        ``cache`` has batch ``len(rids)`` and this pool's exact capacity;
        row ``i`` lands in ``rids[i]``'s allocated block.
        """
        slots = jnp.asarray([self.block_table[r] for r in rids], jnp.int32)
        self.pool = self._insert_jit(self.pool, cache, slots)


def _insert_rows(pool, cache, slots):
    """pool[slots[i]] <- row i of the batched group cache (per leaf)."""
    out = {}
    B = slots.shape[0]
    for k, v in pool.items():
        if k == "len":
            ln = jnp.broadcast_to(cache["len"], (B,)).astype(v.dtype)
            out[k] = v.at[slots].set(ln)
            continue
        ax = cache_batch_axis(k, cache[k].ndim)
        rows = jnp.moveaxis(cache[k], ax, 0)
        # restore the inner batch-1 axis the pool rows keep (row = the
        # model's own batch-1 cache layout, so decode_fn applies unchanged)
        rows = jnp.expand_dims(rows, ax + 1)
        out[k] = v.at[slots].set(rows.astype(v.dtype))
    return out


# ---------------------------------------------------------------------------
# Engine stats
# ---------------------------------------------------------------------------


@dataclass
class StreamStats:
    tokens_out: int = 0          # tokens delivered to real requests, only
    prefill_steps: int = 0       # scheduler iterations that ran a prefill
    decode_steps: int = 0        # scheduler iterations' decode micro-steps
    prefill_calls: int = 0       # underlying jitted prefill invocations
    decode_calls: int = 0        # underlying jitted gather-step invocations
    prefill_s: float = 0.0
    decode_s: float = 0.0
    idle_s: float = 0.0          # virtual-clock time with nothing runnable
    makespan_s: float = 0.0      # arrival of first request -> last retire
    peak_in_flight: int = 0
    ttft_s: Dict[int, float] = field(default_factory=dict)
    finish_s: Dict[int, float] = field(default_factory=dict)

    @property
    def tok_per_s(self) -> float:
        return self.tokens_out / self.makespan_s if self.makespan_s else 0.0

    def ttft_percentile(self, q: float) -> float:
        if not self.ttft_s:
            return 0.0
        return float(np.percentile(np.asarray(list(self.ttft_s.values())), q))


@dataclass
class _Active:
    """One in-flight request: its block, generated tokens, current context."""

    req: ServingRequest
    block: int
    gen: List[int]
    last_tok: int
    ctx: int  # tokens currently in the row's KV (plen + decodes done)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

# scheduler-knob vocabulary: max requests per prefill group, decode
# micro-steps per scheduler iteration, queue ordering, admission ceiling
SCHED_KNOBS = ("prefill_chunk", "interleave", "admission", "max_in_flight")


class StreamingEngine:
    """Continuous-batching server over a paged KV pool.

    ``serve(requests)`` replays an open-loop trace on a virtual clock: the
    clock advances by each step's *measured* wall time and jumps over idle
    gaps, so time-to-first-token percentiles are deterministic-shaped and
    CI-safe (no sleeps) while still reflecting real step costs.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        n_blocks: int = 8,
        max_len: int = 128,
        tuning_db: Optional[TuningDB] = None,
        mesh: Any = None,
        background_tuner: Optional[BackgroundTuner] = None,
        inline_tune: bool = False,
        device_key: bool = False,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.max_len = int(max_len)
        self.db = tuning_db or TuningDB()
        self.mesh = mesh
        self.background = background_tuner
        self.inline_tune = inline_tune
        self.device_key = device_key
        self.cache = PagedKVCache(cfg, n_blocks, self.max_len)
        self.degree = DegreeController(max_degree=max(2, n_blocks))
        self.stats = StreamStats()
        self._hot_tuned: set = set()

        # raw jitted primitives (shared by hot path, candidates, and the
        # scheduler's shadow replay); counted wrappers feed the stats the
        # regression tests assert on.  capacity is pinned so prefilled group
        # caches always match the pool's row layout.
        cap = self.max_len
        self._prefill_raw = jax.jit(
            lambda p, b: prefill_fn(p, b, cfg, capacity=cap)
        )
        self._decode_raw = jax.jit(_make_decode_rows(cfg))

        def counted_prefill(p, b):
            self.stats.prefill_calls += 1
            return self._prefill_raw(p, b)

        def counted_decode(p, pool, idx, toks):
            self.stats.decode_calls += 1
            return self._decode_raw(p, pool, idx, toks)

        self._prefill = counted_prefill
        self._decode = counted_decode
        self.prefill_op = self._make_prefill_op()
        self.decode_op = self._make_decode_op()
        self.sched_op = self._make_sched_op()

    # -- registry ops --------------------------------------------------------

    def _degree_domain(self, n: int, moe_pins: bool) -> Tuple[int, ...]:
        if moe_pins and self.cfg.family == "moe":
            return (1,)
        return tuple(d for d in (1, 2, 4) if d <= n and n % d == 0)

    def _make_prefill_op(self) -> AutotunedOp:
        cfg, mesh, cap = self.cfg, self.mesh, self.max_len
        prefill = self._prefill

        def instantiate(point):
            d = int(point.get("degree", 1))
            if d == 1:
                return lambda params, batch: prefill(params, batch)

            def chunked(params, batch):
                outs = [prefill(params, _batch_chunk(batch, i, d)) for i in range(d)]
                logits = jnp.concatenate([o[0] for o in outs], axis=0)
                return logits, _cache_concat([o[1] for o in outs])

            return chunked

        def shape_class(params, batch) -> BasicParams:
            # the exact group size keys the class (degree validity: chunk
            # counts must divide it); capacity keys the pool row layout
            return BasicParams.make(
                kernel="engine_prefill", arch=cfg.name,
                batch=int(batch["tokens"].shape[0]), capacity=cap,
                backend=jax.default_backend(), **mesh_bp_entries(mesh),
            )

        def traffic_class(params, batch) -> TrafficClass:
            B, plen = batch["tokens"].shape
            return TrafficClass.of("prefill", int(B), int(plen))

        def make_region(bp: BasicParams) -> ATRegion:
            # MoE prefill pins degree 1: capacity dispatch couples the group
            space = ParamSpace([
                PerfParam("degree", self._degree_domain(int(bp["batch"]), True))
            ])
            return ATRegion("engine_prefill", space, instantiate)

        spec = register_kernel(
            KernelSpec(
                name=f"engine_prefill/{cfg.name}",
                make_region=make_region,
                shape_class=shape_class,
                tags=("runtime", "serve", "engine"),
                traffic_class=traffic_class,
            ),
            replace=True,
        )
        return AutotunedOp(
            spec, db=self.db, tune=self.inline_tune, warm=False, monitor=False,
            device_key=self.device_key,
        )

    def _make_decode_op(self) -> AutotunedOp:
        cfg, mesh, cap = self.cfg, self.mesh, self.max_len
        decode = self._decode

        def instantiate(point):
            d = int(point.get("degree", 1))
            if d == 1:
                # len_hint is scheduler metadata for the traffic class only
                return lambda params, pool, idx, toks, len_hint=0: decode(
                    params, pool, idx, toks
                )

            def chunked(params, pool, idx, toks, len_hint=0):
                n = idx.shape[0] // d
                outs = []
                for i in range(d):
                    sl = slice(i * n, (i + 1) * n)
                    tok_i, pool = decode(params, pool, idx[sl], toks[sl])
                    outs.append(tok_i)
                return jnp.concatenate(outs, axis=0), pool

            return chunked

        def shape_class(params, pool, idx, toks, len_hint=0) -> BasicParams:
            return BasicParams.make(
                kernel="engine_decode", arch=cfg.name,
                bucket=int(idx.shape[0]), capacity=cap,
                backend=jax.default_backend(), **mesh_bp_entries(mesh),
            )

        def traffic_class(params, pool, idx, toks, len_hint=0) -> TrafficClass:
            # context bucketed on the scheduler's python-tracked max row
            # length: no device sync on the hot path
            return TrafficClass.of("decode", int(idx.shape[0]), max(1, int(len_hint)))

        def make_region(bp: BasicParams) -> ATRegion:
            # vmapped batch-1 rows are independent even for MoE: decode
            # chunks freely at any degree (unlike grouped prefill)
            space = ParamSpace([
                PerfParam("degree", self._degree_domain(int(bp["bucket"]), False))
            ])
            return ATRegion("engine_decode", space, instantiate)

        spec = register_kernel(
            KernelSpec(
                name=f"engine_decode/{cfg.name}",
                make_region=make_region,
                shape_class=shape_class,
                tags=("runtime", "serve", "engine"),
                traffic_class=traffic_class,
            ),
            replace=True,
        )
        return AutotunedOp(
            spec, db=self.db, tune=self.inline_tune, warm=False, monitor=False,
            device_key=self.device_key,
        )

    def _make_sched_op(self) -> AutotunedOp:
        cfg, mesh = self.cfg, self.mesh
        n_blocks = self.cache.n_blocks

        chunk_domain: Tuple[int, ...] = tuple(
            c for c in (2, 4, 1) if c <= n_blocks
        )
        if cfg.family == "moe":
            chunk_domain = (1,)  # grouped MoE prefill couples rows
        space = ParamSpace([
            PerfParam("prefill_chunk", chunk_domain),
            PerfParam("interleave", (1, 2)),
            PerfParam("admission", ("fcfs", "sjf")),
            PerfParam("max_in_flight", (n_blocks, max(1, n_blocks // 2))),
        ])

        def instantiate(point):
            # the "kernel body" is just the knob assignment — selection is
            # the product; tuning measures it through the shadow replay
            knobs = dict(point)
            return lambda snapshot: knobs

        def shape_class(snapshot) -> BasicParams:
            return BasicParams.make(
                kernel="serve_scheduler", arch=cfg.name, pool=n_blocks,
                capacity=self.max_len, backend=jax.default_backend(),
                **mesh_bp_entries(mesh),
            )

        def traffic_class(snapshot) -> TrafficClass:
            # the *queue state* is the traffic: waiting depth × prompt scale
            return TrafficClass.of(
                "stream",
                max(1, int(snapshot["waiting"])),
                max(1, int(snapshot["mean_plen"])),
            )

        def cost_factory(region, bp, args, kwargs):
            snapshot = args[0]

            def cost(point) -> float:
                # best-of-2 (the paper's repeat-and-take-stable methodology):
                # the first replay of a point can pay jit compiles for group
                # shapes no other point has produced yet, and the worker
                # thread shares the device with the live serve loop — a
                # single sample would hand the win to whichever point
                # happened to measure on a quiet step
                return min(
                    self._shadow_replay(snapshot, dict(point))
                    for _ in range(2)
                )

            return cost

        spec = register_kernel(
            KernelSpec(
                name=f"serve_scheduler/{cfg.name}",
                make_region=lambda bp: ATRegion("serve_scheduler", space, instantiate),
                shape_class=shape_class,
                cost_factory=cost_factory,
                tags=("runtime", "serve", "engine", "scheduler"),
                traffic_class=traffic_class,
            ),
            replace=True,
        )
        return AutotunedOp(
            spec, db=self.db, tune=self.inline_tune, warm=False, monitor=False,
            device_key=self.device_key,
        )

    # -- tuning hand-off (same contract as Server._resolve) ------------------

    def _resolve(self, op: AutotunedOp, *args: Any) -> OpState:
        if self.background is not None:
            # scheduler knobs jump the tuning queue: a tuned scheduler
            # reshapes every later batch, kernel degrees only their own class
            pri = 1 if op is self.sched_op else 0
            state = self.background.submit(
                op, *args, on_complete=self._on_tuned, priority=pri
            )
        else:
            before = op.states() if self.inline_tune else None
            state = op.resolve(*args)
            if (before is not None and state.tuned
                    and state.bp.fingerprint() not in before):
                self._hot_tuned.add(state.bp.fingerprint())
        if state.tuned or state.from_cache:
            self._on_tuned(state)
        return state

    def _on_tuned(self, state: OpState) -> None:
        """Mirror a degree winner into the DegreeController (the scheduler's
        demoted ``omp_set_num_threads`` policy); scheduler-knob states carry
        no degree and pass through untouched."""
        deg = state.region.selected.get("degree")
        if deg is not None and state.traffic is not None:
            self.degree.set_tuned(state.traffic.label, int(deg))

    @property
    def hot_path_cost_evaluations(self) -> int:
        total = 0
        for op in (self.prefill_op, self.decode_op, self.sched_op):
            for st in op.states().values():
                if st.bp.fingerprint() in self._hot_tuned:
                    total += st.cost_evaluations
        return total

    @property
    def traffic_classes_seen(self) -> List[str]:
        labels = set()
        for op in (self.prefill_op, self.decode_op, self.sched_op):
            for st in op.states().values():
                if st.traffic is not None:
                    labels.add(st.traffic.label)
        return sorted(labels)

    @property
    def tuned_scheduler_classes(self) -> List[str]:
        return sorted(
            st.traffic.label
            for st in self.sched_op.states().values()
            if st.traffic is not None and (st.tuned or st.from_cache)
        )

    # -- scheduling ----------------------------------------------------------

    def _knobs(
        self, waiting: Sequence[ServingRequest], active: Dict[int, _Active]
    ) -> Dict[str, Any]:
        pool = waiting or [a.req for a in active.values()]
        mean_plen = int(np.mean([len(r.prompt) for r in pool])) if pool else 1
        mean_mnt = int(np.mean([r.max_new_tokens for r in pool])) if pool else 1
        snapshot = {
            "waiting": max(1, len(waiting)),
            "mean_plen": max(1, mean_plen),
            "mean_mnt": max(1, mean_mnt),
        }
        state = self._resolve(self.sched_op, snapshot)
        return dict(state.region.selected)

    def _pick_group(
        self,
        waiting: List[ServingRequest],
        active: Dict[int, _Active],
        knobs: Dict[str, Any],
    ) -> List[ServingRequest]:
        """Pop the next prefill group: same exact prompt length (no padding
        → reference-exact logits), bounded by the chunk knob, the in-flight
        ceiling, and the allocator's free blocks."""
        room = min(
            int(knobs["prefill_chunk"]),
            int(knobs["max_in_flight"]) - len(active),
            self.cache.free,
        )
        if room < 1 or not waiting:
            return []
        if knobs["admission"] == "sjf":
            order = sorted(
                range(len(waiting)),
                key=lambda i: (waiting[i].max_new_tokens, waiting[i].arrival_s,
                               waiting[i].rid),
            )
        else:  # fcfs — waiting is already arrival-ordered
            order = list(range(len(waiting)))
        lead_plen = len(waiting[order[0]].prompt)
        chosen = []
        for i in order:
            if len(chosen) >= room:
                break
            if len(waiting[i].prompt) == lead_plen:
                chosen.append(i)
        group = [waiting[i] for i in chosen]
        for i in sorted(chosen, reverse=True):
            del waiting[i]
        return group

    # -- serve ---------------------------------------------------------------

    def serve(self, requests: Sequence[ServingRequest]) -> Dict[int, List[int]]:
        """Greedy-decode an open-loop trace; returns rid → generated tokens."""
        check_unique_rids(requests)
        for r in requests:
            need = len(r.prompt) + r.max_new_tokens - 1
            if need > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + "
                    f"{r.max_new_tokens} new tokens needs {need} KV slots "
                    f"> capacity {self.max_len}"
                )
        reqs = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        out: Dict[int, List[int]] = {}
        if not reqs:
            return out
        now = reqs[0].arrival_s
        t_start = now
        cursor = 0
        waiting: List[ServingRequest] = []
        active: Dict[int, _Active] = {}

        while cursor < len(reqs) or waiting or active:
            while cursor < len(reqs) and reqs[cursor].arrival_s <= now:
                waiting.append(reqs[cursor])
                cursor += 1
            if not waiting and not active:
                # nothing runnable: the open-loop clock jumps to the next
                # arrival instead of sleeping
                self.stats.idle_s += reqs[cursor].arrival_s - now
                now = reqs[cursor].arrival_s
                continue
            knobs = self._knobs(waiting, active)

            progressed = False
            group = self._pick_group(waiting, active, knobs)
            if group:
                now = self._prefill_step(group, active, out, now)
                progressed = True
            for _ in range(int(knobs["interleave"])):
                if not active:
                    break
                now = self._decode_step(active, out, now)
                progressed = True
            if not progressed:
                # waiting but no admission room and nothing decoding can
                # only mean a stuck ceiling; active==∅ implies room ≥ 1
                raise RuntimeError("scheduler stalled: no admissible work")
            self.stats.peak_in_flight = max(self.stats.peak_in_flight, len(active))
        self.stats.makespan_s += now - t_start
        return out

    def _prefill_step(
        self,
        group: List[ServingRequest],
        active: Dict[int, _Active],
        out: Dict[int, List[int]],
        now: float,
    ) -> float:
        plen = len(group[0].prompt)
        batch = build_batch_inputs(self.cfg, group, plen)
        pstate = self._resolve(self.prefill_op, self.params, batch)
        label = pstate.traffic.label if pstate.traffic else "prefill"
        t0 = time.perf_counter()
        with self.degree.region(label):
            logits, cache = pstate.region(self.params, batch)
            logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.stats.prefill_s += dt
        self.stats.prefill_steps += 1
        now += dt
        if pstate.selector is not None and pstate.selector.observe(dt):
            self._on_tuned(pstate)
        toks = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        survivors: List[ServingRequest] = []
        for i, r in enumerate(group):
            self.stats.ttft_s[r.rid] = now - r.arrival_s
            self.stats.tokens_out += 1
            if r.max_new_tokens <= 1:
                # done at first token: never allocates a block
                out[r.rid] = [int(toks[i])]
                self.stats.finish_s[r.rid] = now
            else:
                survivors.append(r)
        if survivors:
            for r in survivors:
                self.cache.allocate(r.rid)
            if len(survivors) < len(group):
                # drop the retired rows before scattering into the pool
                keep = np.asarray(
                    [i for i, r in enumerate(group) if r.max_new_tokens > 1],
                    np.int32,
                )
                cache = _take_rows(cache, keep)
            self.cache.insert([r.rid for r in survivors], cache)
            for i, r in enumerate(group):
                if r.max_new_tokens > 1:
                    active[r.rid] = _Active(
                        req=r, block=self.cache.block_of(r.rid),
                        gen=[int(toks[i])], last_tok=int(toks[i]),
                        ctx=plen,
                    )
        return now

    def _decode_step(
        self, active: Dict[int, _Active], out: Dict[int, List[int]], now: float
    ) -> float:
        act = list(active.values())
        A = len(act)
        bucket = bucket_pow2(A)
        # pad to the pow2 bucket by replicating row 0: replicas compute the
        # identical update, so duplicate scatter indices write equal values
        # (well-defined) and the compile cache stays per-bucket, not per-A
        idx = [a.block for a in act] + [act[0].block] * (bucket - A)
        toks = [a.last_tok for a in act] + [act[0].last_tok] * (bucket - A)
        idx_arr = jnp.asarray(idx, jnp.int32)
        tok_arr = jnp.asarray(toks, jnp.int32)
        len_hint = max(a.ctx for a in act)
        dstate = self._resolve(
            self.decode_op, self.params, self.cache.pool, idx_arr, tok_arr,
            len_hint,
        )
        label = dstate.traffic.label if dstate.traffic else "decode"
        t0 = time.perf_counter()
        with self.degree.region(label):
            new_tok, pool = dstate.region(
                self.params, self.cache.pool, idx_arr, tok_arr, len_hint
            )
            new_tok.block_until_ready()
        dt = time.perf_counter() - t0
        self.cache.pool = pool
        self.stats.decode_s += dt
        self.stats.decode_steps += 1
        now += dt
        if dstate.selector is not None and dstate.selector.observe(dt):
            self._on_tuned(dstate)
        new_np = np.asarray(new_tok)[:A]
        for a, t in zip(act, new_np):
            a.gen.append(int(t))
            a.last_tok = int(t)
            a.ctx += 1
            self.stats.tokens_out += 1
            if len(a.gen) >= a.req.max_new_tokens:
                out[a.req.rid] = a.gen
                self.stats.finish_s[a.req.rid] = now
                self.cache.release(a.req.rid)
                del active[a.req.rid]
        return now

    # -- scheduler-knob cost: measured shadow replay -------------------------

    def _shadow_replay(self, snapshot: Dict[str, int], knobs: Dict[str, Any]) -> float:
        """Cost of one knob assignment: replay a deterministic mini-trace
        shaped like the snapshot's traffic class through the raw jitted
        primitives (no op dispatch, no degree bracket, fresh pool) on a
        virtual clock.  Runs on the BackgroundTuner's worker thread; cost =
        virtual makespan + p99 TTFT, so knobs that starve admissions or
        waste decode slots both lose.
        """
        plen = max(1, min(int(snapshot["mean_plen"]), self.max_len - 6))
        n = int(min(max(2, snapshot["waiting"]), 4))
        rng = np.random.default_rng(
            np.random.SeedSequence([plen, n, 0x5C4ED])
        )
        mini: List[ServingRequest] = []
        for i in range(n):
            mnt = max(1, min(int(snapshot["mean_mnt"]) + 2 * (i % 2), 5))
            prompt = rng.integers(
                0, self.cfg.vocab_size - 1, size=plen
            ).astype(np.int32)
            mini.append(ServingRequest(rid=i, prompt=prompt, max_new_tokens=mnt))

        shadow = PagedKVCache(self.cfg, self.cache.n_blocks, self.max_len)
        waiting = list(mini)
        active: Dict[int, _Active] = {}
        now = 0.0
        ttft: List[float] = []
        while waiting or active:
            room = min(
                int(knobs["prefill_chunk"]),
                int(knobs["max_in_flight"]) - len(active),
                shadow.free,
            )
            if waiting and room >= 1:
                if knobs["admission"] == "sjf":
                    waiting.sort(key=lambda r: (r.max_new_tokens, r.rid))
                group, waiting = waiting[:room], waiting[room:]
                batch = build_batch_inputs(self.cfg, group, plen)
                t0 = time.perf_counter()
                logits, cache = self._prefill_raw(self.params, batch)
                logits.block_until_ready()
                now += time.perf_counter() - t0
                toks = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
                survivors = [r for r in group if r.max_new_tokens > 1]
                ttft.extend(now for _ in group)
                if survivors:
                    for r in survivors:
                        shadow.allocate(r.rid)
                    if len(survivors) < len(group):
                        keep = np.asarray(
                            [i for i, r in enumerate(group)
                             if r.max_new_tokens > 1], np.int32,
                        )
                        cache = _take_rows(cache, keep)
                    shadow.insert([r.rid for r in survivors], cache)
                    for i, r in enumerate(group):
                        if r.max_new_tokens > 1:
                            active[r.rid] = _Active(
                                req=r, block=shadow.block_of(r.rid),
                                gen=[int(toks[i])], last_tok=int(toks[i]),
                                ctx=plen,
                            )
            for _ in range(int(knobs["interleave"])):
                if not active:
                    break
                act = list(active.values())
                A = len(act)
                bucket = bucket_pow2(A)
                idx = [a.block for a in act] + [act[0].block] * (bucket - A)
                tk = [a.last_tok for a in act] + [act[0].last_tok] * (bucket - A)
                t0 = time.perf_counter()
                new_tok, shadow.pool = self._decode_raw(
                    self.params, shadow.pool,
                    jnp.asarray(idx, jnp.int32), jnp.asarray(tk, jnp.int32),
                )
                new_tok.block_until_ready()
                now += time.perf_counter() - t0
                new_np = np.asarray(new_tok)[:A]
                for a, t in zip(act, new_np):
                    a.gen.append(int(t))
                    a.last_tok = int(t)
                    if len(a.gen) >= a.req.max_new_tokens:
                        shadow.release(a.req.rid)
                        del active[a.req.rid]
        p99 = float(np.percentile(np.asarray(ttft), 99)) if ttft else 0.0
        return now + p99


# ---------------------------------------------------------------------------
# vmapped batch-1 decode over gathered pool rows
# ---------------------------------------------------------------------------


def _make_decode_rows(cfg: ModelConfig):
    """The engine's decode kernel: gather rows → vmap(decode_fn) → scatter.

    Each gathered row is exactly the model's batch-1 cache (scalar ``len``
    per row under vmap), so heterogeneous positions advance independently —
    the capability the shared-scalar ``cache["len"]`` denies the static
    server's batched decode.
    """

    def decode_rows(params, pool, idx, toks):
        rows = {k: v[idx] for k, v in pool.items()}

        def body(tok, row):
            b: Dict[str, Any] = {"tokens": tok[None, None]}
            if cfg.family == "vlm":
                pos = jnp.broadcast_to(row["len"].astype(jnp.int32), (1, 1))
                b["positions"] = jnp.broadcast_to(pos, (3, 1, 1))
            logits, new_row = decode_fn(params, b, row, cfg)
            return logits[0], new_row

        logits, new_rows = jax.vmap(body)(toks, rows)
        new_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        new_pool = {k: pool[k].at[idx].set(new_rows[k]) for k in pool}
        return new_tok, new_pool

    return decode_rows


def _take_rows(cache: Dict[str, Any], keep: np.ndarray) -> Dict[str, Any]:
    """Select a row subset of a batched cache dict along each leaf's batch
    axis (scalar leaves pass through)."""
    out = {}
    for k, v in cache.items():
        ax = cache_batch_axis(k, getattr(v, "ndim", 0))
        out[k] = v if ax is None else jnp.take(v, jnp.asarray(keep), axis=ax)
    return out
