"""Continuous-batching streaming engine (docs/serving.md).

:class:`~repro.runtime.serve.Server` batches a request *list*: compose a
fixed-size group, prefill it once, decode every row to the group's max —
padded tail rows and short requests ride along as waste, and a request that
arrives mid-batch waits for the whole batch to finish.  This module replaces
that with the engine shape every production LLM server converged on
(Orca-style iteration-level scheduling, vLLM-style paged KV):

* an **admission queue** consumes :class:`~repro.data.pipeline.ServingRequest`
  with open-loop ``arrival_s`` timestamps (``bursty_open_loop_trace``);
* an **iteration-level scheduler** composes every step from interleaved
  prefill and decode work and retires a finished request *that step* — no
  row ever decodes past its own ``max_new_tokens``;
* a **paged KV cache**: a block pool with a free-list
  :class:`BlockAllocator` and a ``block_table`` (rid → block).  Blocks here
  are sequence-granular — one block holds one request's whole KV row at
  fixed capacity, the honest granularity for a cache dict whose layout the
  model owns — so decode batches compose by *index gather/scatter* into the
  pool instead of the ``_cache_chunk``/``_cache_concat`` copy round-trips.

The paper's posture carries over intact.  Prefill groups and decode gathers
dispatch through registry ops (``engine_prefill`` / ``engine_decode``) whose
candidate family is the chunking **degree**, bracketed by the
:class:`~repro.core.degree.DegreeController`'s set-on-entry/restore-on-exit
protocol.  New here: the *scheduler itself* is a tuned kernel
(``serve_scheduler``) — prefill chunk size, prefill/decode interleave ratio,
admission policy, max in-flight and (when the queue is bounded) the shed
policy form a :class:`~repro.core.params.ParamSpace` keyed per
:class:`~repro.core.traffic.TrafficClass` of the *queue state* (phase
``stream``), searched off the hot path by the
:class:`~repro.runtime.background_tuner.BackgroundTuner` with a measured
shadow replay as the cost.  The DegreeController is thereby demoted from
"the serving policy" to one policy among the scheduler's knobs.

Decode composes heterogeneous positions by ``jax.vmap`` of the batch-1
decode step over gathered pool rows: ``cache["len"]`` is scalar per row, so
every request advances at its own position, and
:func:`~repro.models.attention.decode_attention` masks unwritten slots with
``-inf`` — extra pool capacity is numerically inert, which is what makes the
engine bit-match the one-request-at-a-time reference (the conformance test).
MoE is the one asymmetry: capacity-bounded dispatch couples rows *within a
prefill group* (prefill chunk pins to 1), but vmapped batch-1 decode rows
are independent, so MoE decode chunks freely — a capability the static
server never had.

**Hardening** (PR 8, docs/serving.md failure-mode table).  By default
(``hardened=True``) no input trace, resource state, or per-request failure
crashes or wedges the engine; every request retires exactly once with a
:class:`RequestResult` status in ``{ok, timed_out, shed, error}``:

* **deadlines** — a request past its ``deadline_s`` (or the engine-level
  ``default_ttl_s``) retires ``timed_out``, queued or in flight, instead of
  holding a KV block;
* **preemption with recompute** — when the pool is exhausted and a strictly
  higher-priority admission is blocked, the lowest-priority in-flight
  request is evicted: block released, requeued at the queue front with its
  already-generated tokens as *replay* state.  On re-admission the prompt
  prefills again and the replay tokens force the decode trajectory, so the
  final output is bit-identical to the uninterrupted run; ``max_preemptions``
  bounds re-eviction of the same request (anti-livelock);
* **load shedding** — with ``queue_limit`` set, the queue is bounded by a
  shed policy (``reject-new`` | ``drop-oldest`` | ``deadline-aware``) that
  joins the tuned scheduler knobs;
* **fault isolation** — a prefill/decode step that raises is retried one
  request at a time; a request that still raises retires ``error`` (block
  released) and the engine continues.  A watchdog counts scheduler
  iterations with no retire/admit/decode progress and raises
  :class:`EngineStalled` with a state dump after ``watchdog_limit`` of them
  — loud failure instead of a silent spin;
* **chaos** — :class:`~repro.runtime.chaos.ChaosInjector` hooks (step
  faults, pool pressure, virtual delays) make every path above a
  deterministic CI test.

``hardened=False`` restores the pre-hardening contract (validation errors
and step faults raise to the caller) — the overload benchmark runs that
configuration against the same adversarial trace to demonstrate the crash
the hardened engine survives.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ATRegion,
    AutotunedOp,
    BasicParams,
    DegreeController,
    KernelSpec,
    ParamSpace,
    PerfParam,
    TrafficClass,
    TuningDB,
    bucket_pow2,
    register_kernel,
)
from repro.core.autotuned import OpState
from repro.data.pipeline import ServingRequest
from repro.obs.trace import current_tracer
from repro.distributed.sharding import mesh_bp_entries
from repro.models import cache_batch_axis, decode_fn, init_cache, prefill_fn
from repro.models.config import ModelConfig
from repro.runtime.background_tuner import BackgroundTuner
from repro.runtime.serve import (
    _batch_chunk,
    _cache_concat,
    build_batch_inputs,
    check_unique_rids,
)


# ---------------------------------------------------------------------------
# Typed engine failures
# ---------------------------------------------------------------------------


class KVPoolExhausted(RuntimeError):
    """The block pool has no free block.

    Subclasses ``RuntimeError`` so pre-hardening callers (and tests) that
    catch the bare exhaustion error keep working; carries the pool stats the
    scheduler needs to decide between waiting, shedding, and preempting.
    """

    def __init__(self, n_blocks: int, in_use: int) -> None:
        super().__init__(
            f"KV block pool exhausted ({in_use}/{n_blocks} blocks in use); "
            "the scheduler must bound admissions by allocator.free"
        )
        self.n_blocks = int(n_blocks)
        self.in_use = int(in_use)

    @property
    def free(self) -> int:
        return self.n_blocks - self.in_use


class EngineStalled(RuntimeError):
    """Watchdog: no retire/admit/decode progress for ``watchdog_limit``
    consecutive scheduler iterations — fail loudly with a state dump
    instead of spinning forever."""


# ---------------------------------------------------------------------------
# Paged KV cache
# ---------------------------------------------------------------------------


class BlockAllocator:
    """Free-list allocator over a fixed pool of KV blocks."""

    def __init__(self, n_blocks: int) -> None:
        if n_blocks < 1:
            raise ValueError("n_blocks must be >= 1")
        self.n_blocks = int(n_blocks)
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.peak_in_use = 0

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_blocks - len(self._free)

    def allocate(self) -> int:
        if not self._free:
            raise KVPoolExhausted(self.n_blocks, self.in_use)
        block = self._free.pop()
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return block

    def release(self, block: int) -> None:
        # the allocator stays strict (double-free of a *block* is always a
        # bookkeeping bug); rid-level idempotence lives in PagedKVCache
        if not (0 <= block < self.n_blocks) or block in self._free:
            raise ValueError(f"release of invalid or free block {block}")
        self._free.append(block)


class PagedKVCache:
    """A block pool of per-request KV rows plus the rid → block table.

    Every leaf of the model's cache dict for batch 1 at fixed ``capacity``
    is stacked under a leading ``(n_blocks,)`` axis; the scalar ``len`` leaf
    becomes ``(n_blocks,)`` so each block carries its own position.  Insert
    scatters prefilled rows into allocated blocks; decode gathers rows by
    block index, steps them, and scatters the updated rows back — all under
    one jit, with no split/concat copies of the full cache.
    """

    def __init__(self, cfg: ModelConfig, n_blocks: int, capacity: int) -> None:
        self.cfg = cfg
        self.capacity = int(capacity)
        self.allocator = BlockAllocator(n_blocks)
        self.block_table: Dict[int, int] = {}
        row = jax.eval_shape(lambda: init_cache(cfg, 1, capacity))
        self.pool: Dict[str, jnp.ndarray] = {
            k: jnp.zeros((n_blocks,) + tuple(v.shape), v.dtype)
            for k, v in row.items()
        }
        self._insert_jit = jax.jit(_insert_rows)

    @property
    def n_blocks(self) -> int:
        return self.allocator.n_blocks

    @property
    def free(self) -> int:
        return self.allocator.free

    def allocate(self, rid: int) -> int:
        if rid in self.block_table:
            raise ValueError(f"rid {rid} already holds block {self.block_table[rid]}")
        block = self.allocator.allocate()
        self.block_table[rid] = block
        return block

    def release(self, rid: int) -> None:
        """Release ``rid``'s block.  Idempotent: releasing a rid that holds
        no block is a no-op, so every retirement path (finish, timeout,
        shed, error, preempt) can release unconditionally without tracking
        who already did."""
        block = self.block_table.pop(rid, None)
        if block is not None:
            self.allocator.release(block)

    def block_of(self, rid: int) -> int:
        return self.block_table[rid]

    def insert(self, rids: Sequence[int], cache: Dict[str, Any]) -> None:
        """Scatter the rows of a freshly prefilled group cache into blocks.

        ``cache`` has batch ``len(rids)`` and this pool's exact capacity;
        row ``i`` lands in ``rids[i]``'s allocated block.
        """
        slots = jnp.asarray([self.block_table[r] for r in rids], jnp.int32)
        self.pool = self._insert_jit(self.pool, cache, slots)


def _insert_rows(pool, cache, slots):
    """pool[slots[i]] <- row i of the batched group cache (per leaf)."""
    out = {}
    B = slots.shape[0]
    for k, v in pool.items():
        if k == "len":
            ln = jnp.broadcast_to(cache["len"], (B,)).astype(v.dtype)
            out[k] = v.at[slots].set(ln)
            continue
        ax = cache_batch_axis(k, cache[k].ndim)
        rows = jnp.moveaxis(cache[k], ax, 0)
        # restore the inner batch-1 axis the pool rows keep (row = the
        # model's own batch-1 cache layout, so decode_fn applies unchanged)
        rows = jnp.expand_dims(rows, ax + 1)
        out[k] = v.at[slots].set(rows.astype(v.dtype))
    return out


# ---------------------------------------------------------------------------
# Engine stats
# ---------------------------------------------------------------------------


@dataclass
class StreamStats:
    tokens_out: int = 0          # tokens delivered to real requests, only
    prefill_steps: int = 0       # scheduler iterations that ran a prefill
    decode_steps: int = 0        # scheduler iterations' decode micro-steps
    prefill_calls: int = 0       # underlying jitted prefill invocations
    decode_calls: int = 0        # underlying jitted gather-step invocations
    prefill_s: float = 0.0
    decode_s: float = 0.0
    idle_s: float = 0.0          # virtual-clock time with nothing runnable
    makespan_s: float = 0.0      # arrival of first request -> last retire
    peak_in_flight: int = 0
    ttft_s: Dict[int, float] = field(default_factory=dict)
    finish_s: Dict[int, float] = field(default_factory=dict)
    # hardening counters (all zero on a clean trace)
    timeouts: int = 0            # requests retired past deadline
    sheds: int = 0               # requests shed by admission control
    errors: int = 0              # requests retired by fault isolation
    duplicates: int = 0          # duplicate-rid arrivals ignored
    preempted: int = 0           # KV-block evictions for priority admissions
    step_faults: int = 0         # prefill/decode steps that raised
    knob_faults: int = 0         # scheduler-knob resolutions that raised

    @property
    def tok_per_s(self) -> float:
        return self.tokens_out / self.makespan_s if self.makespan_s else 0.0

    def ttft_percentile(self, q: float) -> float:
        if not self.ttft_s:
            return 0.0
        return float(np.percentile(np.asarray(list(self.ttft_s.values())), q))

    def as_metrics(self) -> Dict[str, float]:
        """Flat numeric snapshot for the metrics registry
        (:func:`repro.obs.metrics.snapshot_stats` protocol)."""
        return {
            "tokens_out": self.tokens_out,
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            "prefill_calls": self.prefill_calls,
            "decode_calls": self.decode_calls,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "idle_s": self.idle_s,
            "makespan_s": self.makespan_s,
            "peak_in_flight": self.peak_in_flight,
            "requests_finished": len(self.finish_s),
            "timeouts": self.timeouts,
            "sheds": self.sheds,
            "errors": self.errors,
            "duplicates": self.duplicates,
            "preempted": self.preempted,
            "step_faults": self.step_faults,
            "knob_faults": self.knob_faults,
            "tok_per_s": self.tok_per_s,
            "ttft_p50_s": self.ttft_percentile(50),
            "ttft_p99_s": self.ttft_percentile(99),
        }


@dataclass
class RequestResult:
    """Terminal record of one request — exactly one per admitted rid."""

    rid: int
    status: str  # "ok" | "timed_out" | "shed" | "error"
    tokens: List[int] = field(default_factory=list)  # delivered (may be partial)
    detail: str = ""


#: terminal statuses a request can retire with (the property-test alphabet)
REQUEST_STATUSES = ("ok", "timed_out", "shed", "error")


@dataclass
class _Waiting:
    """One queued request plus its hardening state."""

    req: ServingRequest
    # tokens already delivered before a preemption: on re-admission they
    # force the decode trajectory (recompute), so output stays bit-identical
    resume: List[int] = field(default_factory=list)
    preemptions: int = 0
    deadline: Optional[float] = None  # absolute virtual-clock deadline


@dataclass
class _Active:
    """One in-flight request: its block, generated tokens, current context."""

    req: ServingRequest
    block: int
    gen: List[int]
    last_tok: int
    ctx: int  # tokens currently in the row's KV (plen + decodes done)
    replay: List[int] = field(default_factory=list)  # forced recompute tokens
    preemptions: int = 0
    deadline: Optional[float] = None


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

# scheduler-knob vocabulary: max requests per prefill group, decode
# micro-steps per scheduler iteration, queue ordering, admission ceiling,
# bounded-queue shed policy
SCHED_KNOBS = (
    "prefill_chunk", "interleave", "admission", "max_in_flight", "shed_policy",
)

#: bounded-queue shed policies (the `shed_policy` knob's full domain)
SHED_POLICIES = ("reject-new", "drop-oldest", "deadline-aware")

# virtual-clock advance per no-progress iteration while the watchdog counts
_STALL_TICK_S = 1e-3
# shadow-replay cost penalty per shed request (keeps "shed everything"
# from looking like a great makespan)
_SHED_COST_S = 0.05


class StreamingEngine:
    """Continuous-batching server over a paged KV pool.

    ``serve(requests)`` replays an open-loop trace on a virtual clock: the
    clock advances by each step's *measured* wall time and jumps over idle
    gaps, so time-to-first-token percentiles are deterministic-shaped and
    CI-safe (no sleeps) while still reflecting real step costs.

    After ``serve`` returns, ``self.results`` maps every admitted rid to its
    :class:`RequestResult`; the return value stays rid → tokens for the
    ``ok`` subset (the pre-hardening contract).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        n_blocks: int = 8,
        max_len: int = 128,
        tuning_db: Optional[TuningDB] = None,
        mesh: Any = None,
        background_tuner: Optional[BackgroundTuner] = None,
        inline_tune: bool = False,
        device_key: bool = False,
        hardened: bool = True,
        queue_limit: Optional[int] = None,
        shed_policy: Optional[str] = None,
        default_ttl_s: Optional[float] = None,
        max_preemptions: int = 3,
        watchdog_limit: int = 200,
        chaos: Any = None,
        timer: Any = None,
        tracer: Any = None,
    ) -> None:
        if shed_policy is not None and shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"shed_policy must be one of {SHED_POLICIES}, got {shed_policy!r}"
            )
        if queue_limit is not None and queue_limit < 1:
            raise ValueError("queue_limit must be >= 1")
        self.cfg = cfg
        self.params = params
        self.max_len = int(max_len)
        self.db = tuning_db or TuningDB()
        self.mesh = mesh
        self.background = background_tuner
        self.inline_tune = inline_tune
        self.device_key = device_key
        self.hardened = bool(hardened)
        self.queue_limit = queue_limit
        self.shed_policy = shed_policy  # pin; None lets the tuner choose
        self.default_ttl_s = default_ttl_s
        self.max_preemptions = int(max_preemptions)
        self.watchdog_limit = int(watchdog_limit)
        self.chaos = chaos
        # observability: ``timer`` is the *measurement* clock (step wall
        # times feeding the virtual clock) — inject e.g. a TickTimer for
        # byte-identical deterministic traces; ``tracer`` pins a Tracer to
        # this engine (falls back to the process-wide current_tracer())
        self._timer = timer if timer is not None else time.perf_counter
        self.tracer = tracer
        self.cache = PagedKVCache(cfg, n_blocks, self.max_len)
        self.degree = DegreeController(max_degree=max(2, n_blocks))
        self.stats = StreamStats()
        self.results: Dict[int, RequestResult] = {}
        self.duplicate_rids: List[int] = []
        self._delivered: Set[int] = set()
        self._hot_tuned: set = set()

        # raw jitted primitives (shared by hot path, candidates, and the
        # scheduler's shadow replay); counted wrappers feed the stats the
        # regression tests assert on.  capacity is pinned so prefilled group
        # caches always match the pool's row layout.
        cap = self.max_len
        self._prefill_raw = jax.jit(
            lambda p, b: prefill_fn(p, b, cfg, capacity=cap)
        )
        self._decode_raw = jax.jit(_make_decode_rows(cfg))

        def counted_prefill(p, b):
            self.stats.prefill_calls += 1
            return self._prefill_raw(p, b)

        def counted_decode(p, pool, idx, toks):
            self.stats.decode_calls += 1
            return self._decode_raw(p, pool, idx, toks)

        self._prefill = counted_prefill
        self._decode = counted_decode
        self.prefill_op = self._make_prefill_op()
        self.decode_op = self._make_decode_op()
        self.sched_op = self._make_sched_op()
        # last-resort knobs when the tuning path itself fails (hardened):
        # sequential admission, full pool, no reordering, shed newest
        self._fallback_knobs: Dict[str, Any] = {
            "prefill_chunk": 1,
            "interleave": 1,
            "admission": "fcfs",
            "max_in_flight": self.cache.n_blocks,
            "shed_policy": self.shed_policy or "reject-new",
        }

    def _tr(self):
        """Active tracer for engine events (pinned beats process-global)."""
        return self.tracer if self.tracer is not None else current_tracer()

    # -- registry ops --------------------------------------------------------

    def _degree_domain(self, n: int, moe_pins: bool) -> Tuple[int, ...]:
        if moe_pins and self.cfg.family == "moe":
            return (1,)
        return tuple(d for d in (1, 2, 4) if d <= n and n % d == 0)

    def _make_prefill_op(self) -> AutotunedOp:
        cfg, mesh, cap = self.cfg, self.mesh, self.max_len
        prefill = self._prefill

        def instantiate(point):
            d = int(point.get("degree", 1))
            if d == 1:
                return lambda params, batch: prefill(params, batch)

            def chunked(params, batch):
                outs = [prefill(params, _batch_chunk(batch, i, d)) for i in range(d)]
                logits = jnp.concatenate([o[0] for o in outs], axis=0)
                return logits, _cache_concat([o[1] for o in outs])

            return chunked

        def shape_class(params, batch) -> BasicParams:
            # the exact group size keys the class (degree validity: chunk
            # counts must divide it); capacity keys the pool row layout
            return BasicParams.make(
                kernel="engine_prefill", arch=cfg.name,
                batch=int(batch["tokens"].shape[0]), capacity=cap,
                backend=jax.default_backend(), **mesh_bp_entries(mesh),
            )

        def traffic_class(params, batch) -> TrafficClass:
            B, plen = batch["tokens"].shape
            return TrafficClass.of("prefill", int(B), int(plen))

        def make_region(bp: BasicParams) -> ATRegion:
            # MoE prefill pins degree 1: capacity dispatch couples the group
            space = ParamSpace([
                PerfParam("degree", self._degree_domain(int(bp["batch"]), True))
            ])
            return ATRegion("engine_prefill", space, instantiate)

        spec = register_kernel(
            KernelSpec(
                name=f"engine_prefill/{cfg.name}",
                make_region=make_region,
                shape_class=shape_class,
                tags=("runtime", "serve", "engine"),
                traffic_class=traffic_class,
            ),
            replace=True,
        )
        return AutotunedOp(
            spec, db=self.db, tune=self.inline_tune, warm=False, monitor=False,
            device_key=self.device_key,
        )

    def _make_decode_op(self) -> AutotunedOp:
        cfg, mesh, cap = self.cfg, self.mesh, self.max_len
        decode = self._decode

        def instantiate(point):
            d = int(point.get("degree", 1))
            if d == 1:
                # len_hint is scheduler metadata for the traffic class only
                return lambda params, pool, idx, toks, len_hint=0: decode(
                    params, pool, idx, toks
                )

            def chunked(params, pool, idx, toks, len_hint=0):
                n = idx.shape[0] // d
                outs = []
                for i in range(d):
                    sl = slice(i * n, (i + 1) * n)
                    tok_i, pool = decode(params, pool, idx[sl], toks[sl])
                    outs.append(tok_i)
                return jnp.concatenate(outs, axis=0), pool

            return chunked

        def shape_class(params, pool, idx, toks, len_hint=0) -> BasicParams:
            return BasicParams.make(
                kernel="engine_decode", arch=cfg.name,
                bucket=int(idx.shape[0]), capacity=cap,
                backend=jax.default_backend(), **mesh_bp_entries(mesh),
            )

        def traffic_class(params, pool, idx, toks, len_hint=0) -> TrafficClass:
            # context bucketed on the scheduler's python-tracked max row
            # length: no device sync on the hot path
            return TrafficClass.of("decode", int(idx.shape[0]), max(1, int(len_hint)))

        def make_region(bp: BasicParams) -> ATRegion:
            # vmapped batch-1 rows are independent even for MoE: decode
            # chunks freely at any degree (unlike grouped prefill)
            space = ParamSpace([
                PerfParam("degree", self._degree_domain(int(bp["bucket"]), False))
            ])
            return ATRegion("engine_decode", space, instantiate)

        spec = register_kernel(
            KernelSpec(
                name=f"engine_decode/{cfg.name}",
                make_region=make_region,
                shape_class=shape_class,
                tags=("runtime", "serve", "engine"),
                traffic_class=traffic_class,
            ),
            replace=True,
        )
        return AutotunedOp(
            spec, db=self.db, tune=self.inline_tune, warm=False, monitor=False,
            device_key=self.device_key,
        )

    def _make_sched_op(self) -> AutotunedOp:
        cfg, mesh = self.cfg, self.mesh
        n_blocks = self.cache.n_blocks

        chunk_domain: Tuple[int, ...] = tuple(
            c for c in (2, 4, 1) if c <= n_blocks
        )
        if cfg.family == "moe":
            chunk_domain = (1,)  # grouped MoE prefill couples rows
        if self.shed_policy is not None:
            shed_domain: Tuple[str, ...] = (self.shed_policy,)
        elif self.queue_limit is not None:
            shed_domain = SHED_POLICIES
        else:
            # unbounded queue never sheds: a 1-point domain keeps the
            # search product (and the measured shadow replays) small
            shed_domain = ("reject-new",)
        space = ParamSpace([
            PerfParam("prefill_chunk", chunk_domain),
            PerfParam("interleave", (1, 2)),
            PerfParam("admission", ("fcfs", "sjf")),
            # dict.fromkeys dedupes while keeping order (a 1-block pool
            # would otherwise produce the duplicate domain (1, 1))
            PerfParam("max_in_flight",
                      tuple(dict.fromkeys((n_blocks, max(1, n_blocks // 2))))),
            PerfParam("shed_policy", shed_domain),
        ])

        def instantiate(point):
            # the "kernel body" is just the knob assignment — selection is
            # the product; tuning measures it through the shadow replay
            knobs = dict(point)
            return lambda snapshot: knobs

        def shape_class(snapshot) -> BasicParams:
            return BasicParams.make(
                kernel="serve_scheduler", arch=cfg.name, pool=n_blocks,
                capacity=self.max_len, backend=jax.default_backend(),
                **mesh_bp_entries(mesh),
            )

        def traffic_class(snapshot) -> TrafficClass:
            # the *queue state* is the traffic: waiting depth × prompt scale
            return TrafficClass.of(
                "stream",
                max(1, int(snapshot["waiting"])),
                max(1, int(snapshot["mean_plen"])),
            )

        def cost_factory(region, bp, args, kwargs):
            snapshot = args[0]

            def cost(point) -> float:
                # best-of-2 (the paper's repeat-and-take-stable methodology):
                # the first replay of a point can pay jit compiles for group
                # shapes no other point has produced yet, and the worker
                # thread shares the device with the live serve loop — a
                # single sample would hand the win to whichever point
                # happened to measure on a quiet step
                return min(
                    self._shadow_replay(snapshot, dict(point))
                    for _ in range(2)
                )

            return cost

        spec = register_kernel(
            KernelSpec(
                name=f"serve_scheduler/{cfg.name}",
                make_region=lambda bp: ATRegion("serve_scheduler", space, instantiate),
                shape_class=shape_class,
                cost_factory=cost_factory,
                tags=("runtime", "serve", "engine", "scheduler"),
                traffic_class=traffic_class,
            ),
            replace=True,
        )
        return AutotunedOp(
            spec, db=self.db, tune=self.inline_tune, warm=False, monitor=False,
            device_key=self.device_key,
        )

    # -- tuning hand-off (same contract as Server._resolve) ------------------

    def _resolve(self, op: AutotunedOp, *args: Any) -> OpState:
        if self.background is not None:
            # scheduler knobs jump the tuning queue: a tuned scheduler
            # reshapes every later batch, kernel degrees only their own class
            pri = 1 if op is self.sched_op else 0
            state = self.background.submit(
                op, *args, on_complete=self._on_tuned, priority=pri
            )
        else:
            before = op.states() if self.inline_tune else None
            state = op.resolve(*args)
            if (before is not None and state.tuned
                    and state.bp.fingerprint() not in before):
                self._hot_tuned.add(state.bp.fingerprint())
        if state.tuned or state.from_cache:
            self._on_tuned(state)
        return state

    def _on_tuned(self, state: OpState) -> None:
        """Mirror a degree winner into the DegreeController (the scheduler's
        demoted ``omp_set_num_threads`` policy); scheduler-knob states carry
        no degree and pass through untouched."""
        deg = state.region.selected.get("degree")
        if deg is not None and state.traffic is not None:
            self.degree.set_tuned(state.traffic.label, int(deg))

    @property
    def hot_path_cost_evaluations(self) -> int:
        total = 0
        for op in (self.prefill_op, self.decode_op, self.sched_op):
            for st in op.states().values():
                if st.bp.fingerprint() in self._hot_tuned:
                    total += st.cost_evaluations
        return total

    @property
    def traffic_classes_seen(self) -> List[str]:
        labels = set()
        for op in (self.prefill_op, self.decode_op, self.sched_op):
            for st in op.states().values():
                if st.traffic is not None:
                    labels.add(st.traffic.label)
        return sorted(labels)

    @property
    def tuned_scheduler_classes(self) -> List[str]:
        return sorted(
            st.traffic.label
            for st in self.sched_op.states().values()
            if st.traffic is not None and (st.tuned or st.from_cache)
        )

    # -- scheduling ----------------------------------------------------------

    def _knobs(
        self, waiting: Sequence[_Waiting], active: Dict[int, _Active]
    ) -> Dict[str, Any]:
        pool = [w.req for w in waiting] or [a.req for a in active.values()]
        mean_plen = int(np.mean([len(r.prompt) for r in pool])) if pool else 1
        mean_mnt = int(np.mean([r.max_new_tokens for r in pool])) if pool else 1
        snapshot = {
            "waiting": max(1, len(waiting)),
            "mean_plen": max(1, mean_plen),
            "mean_mnt": max(1, mean_mnt),
        }
        state = self._resolve(self.sched_op, snapshot)
        return dict(state.region.selected)

    def _safe_knobs(
        self, waiting: Sequence[_Waiting], active: Dict[int, _Active]
    ) -> Dict[str, Any]:
        """Hardened knob resolution: a raising or incomplete tuning path
        degrades to the conservative fallback knobs, never crashes serving."""
        if not self.hardened:
            return self._knobs(waiting, active)
        try:
            knobs = self._knobs(waiting, active)
        except Exception:
            self.stats.knob_faults += 1
            return dict(self._fallback_knobs)
        if all(k in knobs for k in
               ("prefill_chunk", "interleave", "admission", "max_in_flight")):
            return knobs
        self.stats.knob_faults += 1
        return dict(self._fallback_knobs)

    def _pick_group(
        self,
        waiting: List[_Waiting],
        active: Dict[int, _Active],
        knobs: Dict[str, Any],
    ) -> List[_Waiting]:
        """Pop the next prefill group: same exact prompt length (no padding
        → reference-exact logits), bounded by the chunk knob, the in-flight
        ceiling, and the allocator's free blocks.  Higher priority admits
        first; at equal priority the admission knob (fcfs/sjf) orders —
        all-zero priorities reduce to the pre-hardening order exactly."""
        room = min(
            int(knobs["prefill_chunk"]),
            int(knobs["max_in_flight"]) - len(active),
            self.cache.free,
        )
        if room < 1 or not waiting:
            return []
        if knobs["admission"] == "sjf":
            order = sorted(
                range(len(waiting)),
                key=lambda i: (-waiting[i].req.priority,
                               waiting[i].req.max_new_tokens,
                               waiting[i].req.arrival_s,
                               waiting[i].req.rid),
            )
        else:  # fcfs — stable sort keeps queue order within a priority level
            order = sorted(
                range(len(waiting)), key=lambda i: -waiting[i].req.priority
            )
        lead_plen = len(waiting[order[0]].req.prompt)
        chosen = []
        for i in order:
            if len(chosen) >= room:
                break
            if len(waiting[i].req.prompt) == lead_plen:
                chosen.append(i)
        group = [waiting[i] for i in chosen]
        for i in sorted(chosen, reverse=True):
            del waiting[i]
        return group

    # -- hardening helpers ---------------------------------------------------

    def _deadline_of(self, r: ServingRequest) -> Optional[float]:
        dl = getattr(r, "deadline_s", None)
        if dl is not None:
            return float(dl)
        if self.default_ttl_s is not None:
            return float(r.arrival_s) + float(self.default_ttl_s)
        return None

    def _retire(
        self,
        rid: int,
        status: str,
        tokens: Sequence[int],
        now: float,
        out: Dict[int, List[int]],
        detail: str = "",
    ) -> bool:
        """Terminal bookkeeping for one request — idempotent: the first
        retirement wins, every later attempt is a no-op.  Always releases
        the rid's block (cache.release is rid-idempotent)."""
        if rid in self.results:
            return False
        self.results[rid] = RequestResult(
            rid=rid, status=status, tokens=list(tokens), detail=detail
        )
        tr = self._tr()
        if tr is not None:
            # exactly one terminal instant per admitted rid, on the virtual
            # clock (the retire-uniqueness property test keys on this)
            tr.instant(
                "engine.retire", t=now, cat="engine", track="engine",
                rid=rid, status=status, tokens=len(tokens),
            )
        self.cache.release(rid)
        if status == "ok":
            out[rid] = list(tokens)
            self.stats.finish_s[rid] = now
        elif status == "timed_out":
            self.stats.timeouts += 1
        elif status == "shed":
            self.stats.sheds += 1
        elif status == "error":
            self.stats.errors += 1
        return True

    def _admit(
        self,
        r: ServingRequest,
        seen: Set[int],
        waiting: List[_Waiting],
        out: Dict[int, List[int]],
        now: float,
    ) -> None:
        """Hardened admission: malformed requests retire ``error`` on the
        spot; duplicate rids are counted and ignored (the first occurrence
        owns the rid's result slot)."""
        rid = r.rid
        if rid in seen:
            self.duplicate_rids.append(rid)
            self.stats.duplicates += 1
            return
        seen.add(rid)
        plen = len(r.prompt)
        mnt = int(r.max_new_tokens)
        if plen < 1:
            self._retire(rid, "error", [], now, out, detail="malformed: empty prompt")
            return
        if mnt < 1:
            self._retire(
                rid, "error", [], now, out,
                detail=f"malformed: max_new_tokens {mnt} < 1",
            )
            return
        need = plen + mnt - 1
        if need > self.max_len:
            self._retire(
                rid, "error", [], now, out,
                detail=(f"malformed: prompt {plen} + {mnt} new tokens needs "
                        f"{need} KV slots > capacity {self.max_len}"),
            )
            return
        tr = self._tr()
        if tr is not None:
            tr.instant(
                "engine.admit", t=now, cat="engine", track="engine",
                rid=rid, plen=plen, max_new_tokens=mnt,
                queue_wait_s=round(max(0.0, now - float(r.arrival_s)), 9),
            )
        waiting.append(_Waiting(req=r, deadline=self._deadline_of(r)))

    def _expire_deadlines(
        self,
        waiting: List[_Waiting],
        active: Dict[int, _Active],
        out: Dict[int, List[int]],
        now: float,
    ) -> None:
        for w in list(waiting):
            if w.deadline is not None and now >= w.deadline:
                waiting.remove(w)
                self._retire(
                    w.req.rid, "timed_out", w.resume, now, out,
                    detail=f"deadline {w.deadline:.4f}s passed in queue",
                )
        for rid in list(active.keys()):
            a = active[rid]
            if a.deadline is not None and now >= a.deadline:
                del active[rid]
                self._retire(
                    rid, "timed_out", a.gen, now, out,
                    detail=f"deadline {a.deadline:.4f}s passed in flight",
                )

    def _shed(
        self,
        waiting: List[_Waiting],
        out: Dict[int, List[int]],
        now: float,
        policy: str,
    ) -> None:
        while len(waiting) > self.queue_limit:
            if policy == "drop-oldest":
                i = 0
            elif policy == "deadline-aware":
                # least slack first: about to miss its deadline anyway;
                # undeadlined requests (infinite slack) shed newest-first
                i = min(
                    range(len(waiting)),
                    key=lambda j: (
                        waiting[j].deadline if waiting[j].deadline is not None
                        else float("inf"),
                        -waiting[j].req.arrival_s,
                        -waiting[j].req.rid,
                    ),
                )
            else:  # reject-new
                i = len(waiting) - 1
            w = waiting.pop(i)
            self._retire(
                w.req.rid, "shed", w.resume, now, out,
                detail=f"queue over limit {self.queue_limit} ({policy})",
            )

    def _maybe_preempt(
        self, waiting: List[_Waiting], active: Dict[int, _Active],
        now: float = 0.0,
    ) -> bool:
        """Evict the lowest-priority in-flight request when the pool is
        exhausted and a strictly higher-priority admission is blocked.  The
        victim requeues at the front with its generated tokens as replay
        state; ``max_preemptions`` evictions make it non-evictable
        (anti-livelock)."""
        if not waiting or not active or self.cache.free > 0:
            return False
        cand_pri = max(int(w.req.priority) for w in waiting)
        eligible = [
            a for a in active.values() if a.preemptions < self.max_preemptions
        ]
        if not eligible:
            return False
        victim = min(
            eligible,
            key=lambda a: (int(a.req.priority), -a.req.arrival_s, -a.req.rid),
        )
        if cand_pri <= int(victim.req.priority):
            return False
        rid = victim.req.rid
        tr = self._tr()
        if tr is not None:
            tr.instant(
                "engine.preempt", t=now, cat="engine", track="engine",
                rid=rid, priority=int(victim.req.priority),
                preemptions=victim.preemptions + 1,
            )
        del active[rid]
        self.cache.release(rid)
        waiting.insert(0, _Waiting(
            req=victim.req,
            resume=list(victim.gen),
            preemptions=victim.preemptions + 1,
            deadline=victim.deadline,
        ))
        self.stats.preempted += 1
        return True

    def _idle_advance(
        self,
        now: float,
        reqs: Sequence[ServingRequest],
        cursor: int,
        waiting: Sequence[_Waiting],
        active: Dict[int, _Active],
    ) -> float:
        """No progress this iteration (hardened): jump the virtual clock to
        the nearest future event (arrival or deadline) so timeouts and
        admissions stay reachable; a fixed tick when there is none."""
        targets: List[float] = []
        if cursor < len(reqs):
            targets.append(reqs[cursor].arrival_s)
        targets.extend(w.deadline for w in waiting if w.deadline is not None)
        targets.extend(
            a.deadline for a in active.values() if a.deadline is not None
        )
        future = [t for t in targets if t > now]
        nxt = max(min(future) if future else now, now + _STALL_TICK_S)
        self.stats.idle_s += nxt - now
        return nxt

    def _state_dump(
        self,
        waiting: Sequence[_Waiting],
        active: Dict[int, _Active],
        now: float,
        idle_iters: int,
    ) -> str:
        return (
            f"engine stalled: no progress for {idle_iters} iterations "
            f"(watchdog_limit={self.watchdog_limit}) at t={now:.4f}s | "
            f"waiting={[w.req.rid for w in waiting]} "
            f"active={sorted(active)} "
            f"free_blocks={self.cache.free}/{self.cache.n_blocks} "
            f"block_table={dict(self.cache.block_table)} "
            f"retired={len(self.results)} "
            f"chaos_holding={getattr(self.chaos, 'holding', 0)}"
        )

    # -- serve ---------------------------------------------------------------

    def serve(self, requests: Sequence[ServingRequest]) -> Dict[int, List[int]]:
        """Greedy-decode an open-loop trace; returns rid → generated tokens
        for the ``ok`` requests (``self.results`` has every terminal
        status)."""
        self.results = {}
        self.duplicate_rids = []
        self._delivered = set()
        if not self.hardened:
            # pre-hardening contract: malformed input raises to the caller
            check_unique_rids(requests)
            for r in requests:
                need = len(r.prompt) + r.max_new_tokens - 1
                if need > self.max_len:
                    raise ValueError(
                        f"request {r.rid}: prompt {len(r.prompt)} + "
                        f"{r.max_new_tokens} new tokens needs {need} KV slots "
                        f"> capacity {self.max_len}"
                    )
        reqs = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        out: Dict[int, List[int]] = {}
        if not reqs:
            return out
        now = reqs[0].arrival_s
        t_start = now
        cursor = 0
        waiting: List[_Waiting] = []
        active: Dict[int, _Active] = {}
        seen: Set[int] = set()
        idle_iters = 0

        while cursor < len(reqs) or waiting or active:
            while cursor < len(reqs) and reqs[cursor].arrival_s <= now:
                r = reqs[cursor]
                cursor += 1
                if self.hardened:
                    self._admit(r, seen, waiting, out, now)
                else:
                    waiting.append(_Waiting(req=r))
            if self.chaos is not None:
                self.chaos.tick(self.cache)
            if self.hardened:
                self._expire_deadlines(waiting, active, out, now)
            if not waiting and not active:
                if cursor < len(reqs):
                    # nothing runnable: the open-loop clock jumps to the
                    # next arrival instead of sleeping
                    self.stats.idle_s += reqs[cursor].arrival_s - now
                    now = reqs[cursor].arrival_s
                    continue
                break  # everything retired; chaos may still hold blocks
            n_retired = len(self.results)
            knobs = self._safe_knobs(waiting, active)
            if self.hardened and self.queue_limit is not None:
                policy = self.shed_policy or str(
                    knobs.get("shed_policy", "reject-new")
                )
                self._shed(waiting, out, now, policy)
            if self.hardened:
                self._maybe_preempt(waiting, active, now)

            progressed = False
            group = self._pick_group(waiting, active, knobs)
            if group:
                now = self._prefill_step(group, active, waiting, out, now)
                progressed = True
            for _ in range(int(knobs["interleave"])):
                if not active:
                    break
                now = self._decode_step(active, out, now)
                progressed = True
            if len(self.results) > n_retired:
                progressed = True  # sheds/timeouts/errors are retirements
            self.stats.peak_in_flight = max(
                self.stats.peak_in_flight, len(active)
            )
            if progressed:
                idle_iters = 0
            else:
                if not self.hardened:
                    # waiting but no admission room and nothing decoding can
                    # only mean a stuck ceiling; active==∅ implies room ≥ 1
                    raise RuntimeError("scheduler stalled: no admissible work")
                idle_iters += 1
                if idle_iters > self.watchdog_limit:
                    raise EngineStalled(
                        self._state_dump(waiting, active, now, idle_iters)
                    )
                now = self._idle_advance(now, reqs, cursor, waiting, active)
        if self.chaos is not None:
            self.chaos.drain(self.cache)
        self.stats.makespan_s += now - t_start
        tr = self._tr()
        if tr is not None:
            tr.complete(
                "engine.serve", t_start, now, cat="engine", track="engine",
                requests=len(reqs), retired=len(self.results),
                tokens_out=self.stats.tokens_out,
            )
        return out

    # -- prefill -------------------------------------------------------------

    def _prefill_step(
        self,
        group: List[_Waiting],
        active: Dict[int, _Active],
        waiting: List[_Waiting],
        out: Dict[int, List[int]],
        now: float,
    ) -> float:
        if not self.hardened:
            return self._prefill_exec(group, active, waiting, out, now)
        try:
            return self._prefill_exec(group, active, waiting, out, now)
        except Exception:
            self.stats.step_faults += 1
            # undo partial state: blocks allocated to members that never
            # activated (cache.release is rid-idempotent)
            for w in group:
                if w.req.rid not in active:
                    self.cache.release(w.req.rid)
            # isolate: retry each not-yet-settled member on its own; a
            # member that raises again is the implicated request
            for w in group:
                rid = w.req.rid
                if (rid in self.results or rid in active
                        or any(q.req.rid == rid for q in waiting)):
                    continue
                try:
                    now = self._prefill_exec([w], active, waiting, out, now)
                except Exception as exc:
                    self._retire(
                        rid, "error", w.resume, now, out,
                        detail=f"prefill fault: {type(exc).__name__}: {exc}",
                    )
            return now

    def _prefill_exec(
        self,
        group: List[_Waiting],
        active: Dict[int, _Active],
        waiting: List[_Waiting],
        out: Dict[int, List[int]],
        now: float,
    ) -> float:
        reqs = [w.req for w in group]
        plen = len(reqs[0].prompt)
        batch = build_batch_inputs(self.cfg, reqs, plen)
        pstate = self._resolve(self.prefill_op, self.params, batch)
        label = pstate.traffic.label if pstate.traffic else "prefill"
        if self.chaos is not None:
            self.chaos.before_step("prefill", [r.rid for r in reqs])
        t0 = self._timer()
        with self.degree.region(label):
            logits, cache = pstate.region(self.params, batch)
            logits.block_until_ready()
        dt = self._timer() - t0
        self.stats.prefill_s += dt
        self.stats.prefill_steps += 1
        t_v0 = now
        now += dt
        if self.chaos is not None:
            now += self.chaos.step_delay()
        tr = self._tr()
        if tr is not None:
            tr.complete(
                "engine.prefill", t_v0, now, cat="engine", track="engine",
                rids=[r.rid for r in reqs], batch=len(reqs), plen=plen,
                label=label,
            )
        if pstate.selector is not None and pstate.selector.observe(dt):
            self._on_tuned(pstate)
        toks = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        # a resumed (preempted) request forces its first delivered token:
        # greedy decode reproduces it anyway, forcing guarantees bit-match
        first_toks: Dict[int, int] = {}
        for i, w in enumerate(group):
            r = w.req
            tok0 = int(w.resume[0]) if w.resume else int(toks[i])
            first_toks[r.rid] = tok0
            if r.rid not in self._delivered:
                self._delivered.add(r.rid)
                self.stats.ttft_s[r.rid] = now - r.arrival_s
                self.stats.tokens_out += 1
            if r.max_new_tokens <= 1:
                # done at first token: never allocates a block
                self._retire(r.rid, "ok", [tok0], now, out)
        keep_idx: List[int] = []
        activated: List[_Waiting] = []
        for i, w in enumerate(group):
            if w.req.max_new_tokens <= 1:
                continue
            try:
                self.cache.allocate(w.req.rid)
            except KVPoolExhausted:
                if not self.hardened:
                    raise
                # pool raced away (e.g. chaos squeeze between pick and
                # allocate): requeue at the front with recompute state
                resume = list(w.resume) if w.resume else [first_toks[w.req.rid]]
                waiting.insert(0, _Waiting(
                    req=w.req, resume=resume,
                    preemptions=w.preemptions, deadline=w.deadline,
                ))
                continue
            keep_idx.append(i)
            activated.append(w)
        if activated:
            if len(keep_idx) < len(group):
                # drop the retired/deferred rows before scattering
                cache = _take_rows(cache, np.asarray(keep_idx, np.int32))
            self.cache.insert([w.req.rid for w in activated], cache)
            for w in activated:
                r = w.req
                tok0 = first_toks[r.rid]
                active[r.rid] = _Active(
                    req=r, block=self.cache.block_of(r.rid),
                    gen=[tok0], last_tok=tok0, ctx=plen,
                    replay=list(w.resume[1:]),
                    preemptions=w.preemptions, deadline=w.deadline,
                )
        return now

    # -- decode --------------------------------------------------------------

    def _decode_step(
        self, active: Dict[int, _Active], out: Dict[int, List[int]], now: float
    ) -> float:
        if not self.hardened:
            return self._decode_exec(active, out, now)
        try:
            return self._decode_exec(active, out, now)
        except Exception:
            self.stats.step_faults += 1
            # isolate: step each row on its own; a row that raises again is
            # the implicated request (its KV pool state is untouched — the
            # jitted step is functional, the pool only swaps on success)
            for rid in list(active.keys()):
                if rid not in active:
                    continue
                try:
                    now = self._decode_exec(active, out, now, only=[rid])
                except Exception as exc:
                    a = active.pop(rid)
                    self._retire(
                        rid, "error", a.gen, now, out,
                        detail=f"decode fault: {type(exc).__name__}: {exc}",
                    )
            return now

    def _decode_exec(
        self,
        active: Dict[int, _Active],
        out: Dict[int, List[int]],
        now: float,
        only: Optional[Sequence[int]] = None,
    ) -> float:
        rids = [
            r for r in (list(active.keys()) if only is None else only)
            if r in active
        ]
        act = [active[r] for r in rids]
        A = len(act)
        if A == 0:
            return now
        bucket = bucket_pow2(A)
        # pad to the pow2 bucket by replicating row 0: replicas compute the
        # identical update, so duplicate scatter indices write equal values
        # (well-defined) and the compile cache stays per-bucket, not per-A
        idx = [a.block for a in act] + [act[0].block] * (bucket - A)
        toks = [a.last_tok for a in act] + [act[0].last_tok] * (bucket - A)
        idx_arr = jnp.asarray(idx, jnp.int32)
        tok_arr = jnp.asarray(toks, jnp.int32)
        len_hint = max(a.ctx for a in act)
        dstate = self._resolve(
            self.decode_op, self.params, self.cache.pool, idx_arr, tok_arr,
            len_hint,
        )
        label = dstate.traffic.label if dstate.traffic else "decode"
        if self.chaos is not None:
            self.chaos.before_step("decode", rids)
        t0 = self._timer()
        with self.degree.region(label):
            new_tok, pool = dstate.region(
                self.params, self.cache.pool, idx_arr, tok_arr, len_hint
            )
            new_tok.block_until_ready()
        dt = self._timer() - t0
        self.cache.pool = pool
        self.stats.decode_s += dt
        self.stats.decode_steps += 1
        t_v0 = now
        now += dt
        if self.chaos is not None:
            now += self.chaos.step_delay()
        tr = self._tr()
        if tr is not None:
            tr.complete(
                "engine.decode", t_v0, now, cat="engine", track="engine",
                rids=rids, batch=A, bucket=bucket, label=label,
            )
        if dstate.selector is not None and dstate.selector.observe(dt):
            self._on_tuned(dstate)
        new_np = np.asarray(new_tok)[:A]
        for a, t in zip(act, new_np):
            if a.replay:
                # recompute of an already-delivered token (post-preemption):
                # force the original trajectory, don't re-count delivery
                tok = int(a.replay.pop(0))
            else:
                tok = int(t)
                self.stats.tokens_out += 1
            a.gen.append(tok)
            a.last_tok = tok
            a.ctx += 1
            if len(a.gen) >= a.req.max_new_tokens:
                self._retire(a.req.rid, "ok", a.gen, now, out)
                del active[a.req.rid]
        return now

    # -- scheduler-knob cost: measured shadow replay -------------------------

    def _shadow_replay(self, snapshot: Dict[str, int], knobs: Dict[str, Any]) -> float:
        """Cost of one knob assignment: replay a deterministic mini-trace
        shaped like the snapshot's traffic class through the raw jitted
        primitives (no op dispatch, no degree bracket, fresh pool) on a
        virtual clock.  Runs on the BackgroundTuner's worker thread; cost =
        virtual makespan + p99 TTFT + a fixed penalty per shed request, so
        knobs that starve admissions, waste decode slots, or shed their way
        to a short makespan all lose.
        """
        plen = max(1, min(int(snapshot["mean_plen"]), self.max_len - 6))
        n = int(min(max(2, snapshot["waiting"]), 4))
        rng = np.random.default_rng(
            np.random.SeedSequence([plen, n, 0x5C4ED])
        )
        mini: List[ServingRequest] = []
        for i in range(n):
            mnt = max(1, min(int(snapshot["mean_mnt"]) + 2 * (i % 2), 5))
            prompt = rng.integers(
                0, self.cfg.vocab_size - 1, size=plen
            ).astype(np.int32)
            # alternating finite deadlines give the deadline-aware shed
            # policy something to distinguish itself on
            mini.append(ServingRequest(
                rid=i, prompt=prompt, max_new_tokens=mnt,
                deadline_s=0.05 * (i + 1) if i % 2 else None,
            ))

        shadow = PagedKVCache(self.cfg, self.cache.n_blocks, self.max_len)
        waiting = list(mini)
        shed = 0
        if self.queue_limit is not None:
            # bound the shadow queue below the mini-trace size so the shed
            # policies produce genuinely different traces (and costs)
            limit = max(1, min(int(self.queue_limit), n - 1))
            policy = str(knobs.get("shed_policy", "reject-new"))
            while len(waiting) > limit:
                if policy == "drop-oldest":
                    j = 0
                elif policy == "deadline-aware":
                    j = min(
                        range(len(waiting)),
                        key=lambda q: (
                            waiting[q].deadline_s
                            if waiting[q].deadline_s is not None
                            else float("inf"),
                            -waiting[q].rid,
                        ),
                    )
                else:  # reject-new
                    j = len(waiting) - 1
                waiting.pop(j)
                shed += 1
        active: Dict[int, _Active] = {}
        now = 0.0
        ttft: List[float] = []
        while waiting or active:
            room = min(
                int(knobs["prefill_chunk"]),
                int(knobs["max_in_flight"]) - len(active),
                shadow.free,
            )
            if waiting and room >= 1:
                if knobs["admission"] == "sjf":
                    waiting.sort(key=lambda r: (r.max_new_tokens, r.rid))
                group, waiting = waiting[:room], waiting[room:]
                batch = build_batch_inputs(self.cfg, group, plen)
                t0 = time.perf_counter()
                logits, cache = self._prefill_raw(self.params, batch)
                logits.block_until_ready()
                now += time.perf_counter() - t0
                toks = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
                survivors = [r for r in group if r.max_new_tokens > 1]
                ttft.extend(now for _ in group)
                if survivors:
                    for r in survivors:
                        shadow.allocate(r.rid)
                    if len(survivors) < len(group):
                        keep = np.asarray(
                            [i for i, r in enumerate(group)
                             if r.max_new_tokens > 1], np.int32,
                        )
                        cache = _take_rows(cache, keep)
                    shadow.insert([r.rid for r in survivors], cache)
                    for i, r in enumerate(group):
                        if r.max_new_tokens > 1:
                            active[r.rid] = _Active(
                                req=r, block=shadow.block_of(r.rid),
                                gen=[int(toks[i])], last_tok=int(toks[i]),
                                ctx=plen,
                            )
            for _ in range(int(knobs["interleave"])):
                if not active:
                    break
                act = list(active.values())
                A = len(act)
                bucket = bucket_pow2(A)
                idx = [a.block for a in act] + [act[0].block] * (bucket - A)
                tk = [a.last_tok for a in act] + [act[0].last_tok] * (bucket - A)
                t0 = time.perf_counter()
                new_tok, shadow.pool = self._decode_raw(
                    self.params, shadow.pool,
                    jnp.asarray(idx, jnp.int32), jnp.asarray(tk, jnp.int32),
                )
                new_tok.block_until_ready()
                now += time.perf_counter() - t0
                new_np = np.asarray(new_tok)[:A]
                for a, t in zip(act, new_np):
                    a.gen.append(int(t))
                    a.last_tok = int(t)
                    if len(a.gen) >= a.req.max_new_tokens:
                        shadow.release(a.req.rid)
                        del active[a.req.rid]
        p99 = float(np.percentile(np.asarray(ttft), 99)) if ttft else 0.0
        return now + p99 + _SHED_COST_S * shed


# ---------------------------------------------------------------------------
# vmapped batch-1 decode over gathered pool rows
# ---------------------------------------------------------------------------


def _make_decode_rows(cfg: ModelConfig):
    """The engine's decode kernel: gather rows → vmap(decode_fn) → scatter.

    Each gathered row is exactly the model's batch-1 cache (scalar ``len``
    per row under vmap), so heterogeneous positions advance independently —
    the capability the shared-scalar ``cache["len"]`` denies the static
    server's batched decode.
    """

    def decode_rows(params, pool, idx, toks):
        rows = {k: v[idx] for k, v in pool.items()}

        def body(tok, row):
            b: Dict[str, Any] = {"tokens": tok[None, None]}
            if cfg.family == "vlm":
                pos = jnp.broadcast_to(row["len"].astype(jnp.int32), (1, 1))
                b["positions"] = jnp.broadcast_to(pos, (3, 1, 1))
            logits, new_row = decode_fn(params, b, row, cfg)
            return logits[0], new_row

        logits, new_rows = jax.vmap(body)(toks, rows)
        new_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        new_pool = {k: pool[k].at[idx].set(new_rows[k]) for k in pool}
        return new_tok, new_pool

    return decode_rows


def _take_rows(cache: Dict[str, Any], keep: np.ndarray) -> Dict[str, Any]:
    """Select a row subset of a batched cache dict along each leaf's batch
    axis (scalar leaves pass through)."""
    out = {}
    for k, v in cache.items():
        ax = cache_batch_axis(k, getattr(v, "ndim", 0))
        out[k] = v if ax is None else jnp.take(v, jnp.asarray(keep), axis=ax)
    return out
