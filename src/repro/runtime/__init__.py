from .train import TrainLoopConfig, Trainer, SimulatedFailure
from .serve import Server, ServeStats
from .background_tuner import BackgroundTuner

__all__ = [
    "TrainLoopConfig",
    "Trainer",
    "SimulatedFailure",
    "Server",
    "ServeStats",
    "BackgroundTuner",
]
