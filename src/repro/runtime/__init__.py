from .train import TrainLoopConfig, Trainer, SimulatedFailure
from .serve import Server, ServeStats
from .engine import (
    BlockAllocator,
    EngineStalled,
    KVPoolExhausted,
    PagedKVCache,
    RequestResult,
    StreamStats,
    StreamingEngine,
)
from .background_tuner import BackgroundTuner
from .chaos import ChaosError, ChaosInjector, ChaosStats

__all__ = [
    "TrainLoopConfig",
    "Trainer",
    "SimulatedFailure",
    "Server",
    "ServeStats",
    "BlockAllocator",
    "EngineStalled",
    "KVPoolExhausted",
    "PagedKVCache",
    "RequestResult",
    "StreamStats",
    "StreamingEngine",
    "BackgroundTuner",
    "ChaosError",
    "ChaosInjector",
    "ChaosStats",
]
