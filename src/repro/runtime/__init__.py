from .train import TrainLoopConfig, Trainer, SimulatedFailure
from .serve import Server

__all__ = ["TrainLoopConfig", "Trainer", "SimulatedFailure", "Server"]
