from .train import TrainLoopConfig, Trainer, SimulatedFailure
from .serve import Server, ServeStats
from .engine import BlockAllocator, PagedKVCache, StreamStats, StreamingEngine
from .background_tuner import BackgroundTuner

__all__ = [
    "TrainLoopConfig",
    "Trainer",
    "SimulatedFailure",
    "Server",
    "ServeStats",
    "BlockAllocator",
    "PagedKVCache",
    "StreamStats",
    "StreamingEngine",
    "BackgroundTuner",
]
