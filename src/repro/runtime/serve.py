"""Batched serving loop: prefill + greedy decode with a KV/state cache.

A deliberately small continuous-batching server: requests are grouped into
fixed-size batches (padding prompts to a shared length), prefilled once, then
decoded step-by-step.  Both the prefill and decode paths are registry ops
(:mod:`repro.core.registry`), built once per (batch, length) shape class —
serving-side AOT candidate generation, matching the paper's no-runtime-codegen
discipline.  Their candidate families are single-point for now: every region
candidate must be semantically identical (greedy outputs are part of the
serving contract), and no output-preserving serving PP exists yet; traffic-
class PPs land here once an attention-masked prefill makes padding free.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AutotunedOp,
    BasicParams,
    KernelSpec,
    ParamSpace,
    PerfParam,
    TuningDB,
    register_kernel,
)
from repro.data.pipeline import ServingRequest
from repro.models import decode_fn, prefill_fn
from repro.models.config import ModelConfig

@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class Server:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        batch_size: int = 4,
        max_len: int = 128,
        tuning_db: Optional[TuningDB] = None,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.db = tuning_db or TuningDB()
        self._prefill = jax.jit(lambda p, b: prefill_fn(p, b, cfg))
        self._decode = jax.jit(lambda p, b, c: decode_fn(p, b, c, cfg))
        self.prefill_op = self._make_prefill_op()
        self.decode_op = self._make_decode_op()
        self.stats = ServeStats()

    # -- registry ops ----------------------------------------------------------

    def _make_prefill_op(self) -> AutotunedOp:
        cfg, prefill = self.cfg, self._prefill

        def instantiate(point):
            return lambda params, batch: prefill(params, batch)

        def shape_class(params, batch) -> BasicParams:
            B, plen = batch["tokens"].shape
            return BasicParams.make(
                kernel="serve_prefill", arch=cfg.name, batch=int(B),
                plen=int(plen), backend=jax.default_backend(),
            )

        spec = register_kernel(
            KernelSpec(
                name=f"serve_prefill/{cfg.name}",
                make_region=lambda bp: _region(
                    "serve_prefill", [PerfParam("impl", ("jit",))], instantiate
                ),
                shape_class=shape_class,
                tags=("runtime", "serve"),
            ),
            replace=True,
        )
        return AutotunedOp(spec, db=self.db, tune=False, warm=False, monitor=False)

    def _make_decode_op(self) -> AutotunedOp:
        cfg, decode = self.cfg, self._decode

        def instantiate(point):
            return lambda params, batch, cache: decode(params, batch, cache)

        def shape_class(params, batch, cache) -> BasicParams:
            return BasicParams.make(
                kernel="serve_decode", arch=cfg.name,
                batch=int(batch["tokens"].shape[0]),
                backend=jax.default_backend(),
            )

        spec = register_kernel(
            KernelSpec(
                name=f"serve_decode/{cfg.name}",
                make_region=lambda bp: _region(
                    "serve_decode", [PerfParam("impl", ("jit",))], instantiate
                ),
                shape_class=shape_class,
                tags=("runtime", "serve"),
            ),
            replace=True,
        )
        return AutotunedOp(spec, db=self.db, tune=False, warm=False, monitor=False)

    # -- batching --------------------------------------------------------------

    def _batch_inputs(self, group: Sequence[ServingRequest], plen: int) -> Dict[str, Any]:
        B = len(group)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(group):
            toks[i, -len(r.prompt):] = r.prompt[:plen]
        batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (B, self.cfg.n_vision_tokens, self.cfg.d_model), jnp.bfloat16
            )
            pos = jnp.broadcast_to(jnp.arange(plen, dtype=jnp.int32), (B, plen))
            batch["positions"] = jnp.broadcast_to(pos, (3, B, plen))
        if self.cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encoder_len, self.cfg.d_model), jnp.bfloat16
            )
        return batch

    def run(self, requests: Sequence[ServingRequest]) -> Dict[int, List[int]]:
        """Greedy-decode every request; returns rid -> generated token ids."""
        out: Dict[int, List[int]] = {}
        for i in range(0, len(requests), self.batch_size):
            group = list(requests[i : i + self.batch_size])
            while len(group) < self.batch_size:  # pad the tail batch
                group.append(group[-1])
            plen = max(len(r.prompt) for r in group)
            batch = self._batch_inputs(group, plen)

            t0 = time.perf_counter()
            logits, cache = self.prefill_op(self.params, batch)
            logits.block_until_ready()
            self.stats.prefill_s += time.perf_counter() - t0

            n_steps = max(r.max_new_tokens for r in group)
            gen = [[] for _ in group]
            t0 = time.perf_counter()
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for step in range(n_steps):
                for gi in range(len(group)):
                    gen[gi].append(int(next_tok[gi]))
                dbatch: Dict[str, Any] = {"tokens": next_tok[:, None]}
                if self.cfg.family == "vlm":
                    p = cache["len"]
                    pos = jnp.broadcast_to(p, (len(group), 1)).astype(jnp.int32)
                    dbatch["positions"] = jnp.broadcast_to(pos, (3, len(group), 1))
                logits, cache = self.decode_op(self.params, dbatch, cache)
                next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            jax.block_until_ready(next_tok)
            self.stats.decode_s += time.perf_counter() - t0
            self.stats.tokens_out += n_steps * len(group)

            for gi, r in enumerate(group[: len(requests[i : i + self.batch_size])]):
                out[r.rid] = gen[gi][: r.max_new_tokens]
        return out


def _region(name: str, params: list, instantiate):
    from repro.core import ATRegion

    return ATRegion(name, ParamSpace(params), instantiate)
