"""Batched serving loop with traffic-class autotuning (docs/serving.md).

A deliberately small continuous-batching server: requests are grouped into
fixed-size batches (padding prompts to a shared length), prefilled once, then
decoded step-by-step.  Both the prefill and decode paths are registry ops
(:mod:`repro.core.registry`) whose shape class is extended by a
:class:`~repro.core.traffic.TrafficClass` — batch bucket × sequence bucket ×
phase — and by the mesh fingerprint, so every traffic class on every mesh
factorization tunes independently.

The candidate family is the serving **degree**: the batch is split into
``degree`` chunks executed sequentially and re-concatenated.  Rows are
independent in every family except MoE (capacity-bounded dispatch couples
the batch, so MoE serves degree 1 only), which makes every candidate
semantically identical — the greedy-output serving contract holds across
switches.  Degree trades peak activation memory against per-call launch
overhead, the thread-grain trade of docs/design.md §2.

Tuning never runs on the request hot path: pass a
:class:`~repro.runtime.background_tuner.BackgroundTuner` and unseen classes
are tuned on its worker thread while the hot path serves the safe
precompiled default, hot-swapping to the winner when it lands.
``inline_tune=True`` restores pay-as-you-go tuning (the benchmark baseline);
the default is no tuning at all, exactly the pre-traffic-class behaviour.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AutotunedOp,
    BasicParams,
    DegreeController,
    KernelSpec,
    ParamSpace,
    PerfParam,
    ProgramMember,
    ProgramResult,
    ProgramSpec,
    TrafficClass,
    TuningDB,
    register_kernel,
)
from repro.core.autotuned import OpState
from repro.data.pipeline import ServingRequest
from repro.distributed.sharding import mesh_bp_entries
from repro.models import cache_batch_axis, decode_fn, prefill_fn
from repro.models.config import ModelConfig
from repro.runtime.background_tuner import BackgroundTuner


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    # invocations of the underlying jitted prefill/decode callables (a
    # degree-d chunked candidate counts d) — the regression tests' witness
    # that the loop runs exactly the decodes it needs, no trailing waste
    prefill_calls: int = 0
    decode_calls: int = 0
    batch_latencies: List[float] = field(default_factory=list)

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0

    def latency_percentile(self, q: float) -> float:
        """q-th percentile of per-batch wall time (seconds); 0 when empty."""
        if not self.batch_latencies:
            return 0.0
        return float(np.percentile(np.asarray(self.batch_latencies), q))


# Which axis of each model input carries the batch dimension (positions is
# (3, B, L): axis 1).  Cache leaves vary per name — stacked per-layer leaves
# are (layers, B, ...), hybrid tail leaves are (B, ...) — so they go through
# models.cache_batch_axis; scalars ("len") are shared across chunks.
_BATCH_AXIS = {"tokens": 0, "vision_embeds": 0, "frames": 0, "positions": 1}


def _slice_axis(x, axis: int, i: int, n: int):
    if x.shape[axis] % n:
        raise ValueError(
            f"cannot split axis {axis} of shape {tuple(x.shape)} into {n} "
            f"equal chunks ({x.shape[axis]} % {n} != 0)"
        )
    size = x.shape[axis] // n
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(i * size, (i + 1) * size)
    return x[tuple(idx)]


def _batch_chunk(batch: Dict[str, Any], i: int, n: int) -> Dict[str, Any]:
    return {k: _slice_axis(v, _BATCH_AXIS.get(k, 0), i, n) for k, v in batch.items()}


def _cache_chunk(cache: Dict[str, Any], i: int, n: int) -> Dict[str, Any]:
    out = {}
    for k, v in cache.items():
        ax = cache_batch_axis(k, getattr(v, "ndim", 0))
        out[k] = v if ax is None else _slice_axis(v, ax, i, n)
    return out


def _cache_concat(chunks: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    out = {}
    for k, v in chunks[0].items():
        ax = cache_batch_axis(k, getattr(v, "ndim", 0))
        out[k] = v if ax is None else jnp.concatenate([c[k] for c in chunks], axis=ax)
    return out


def check_unique_rids(requests: Sequence[ServingRequest]) -> None:
    """Duplicate rids would silently overwrite each other in the rid-keyed
    result dict; fail fast instead (shared by Server and StreamingEngine)."""
    seen: set = set()
    for r in requests:
        if r.rid in seen:
            raise ValueError(f"duplicate request rid {r.rid!r} in trace")
        seen.add(r.rid)


def check_well_formed(requests: Sequence[ServingRequest]) -> None:
    """The static server's strict contract: a malformed request is a caller
    bug and fails fast with a named reason instead of an opaque shape error
    deep inside a jitted prefill.  (The hardened StreamingEngine instead
    absorbs these per-request with an ``error`` retirement.)"""
    for r in requests:
        if len(r.prompt) < 1:
            raise ValueError(f"request {r.rid}: empty prompt")
        if r.max_new_tokens < 1:
            raise ValueError(
                f"request {r.rid}: max_new_tokens must be >= 1, "
                f"got {r.max_new_tokens}"
            )


def build_batch_inputs(
    cfg: ModelConfig, group: Sequence[ServingRequest], plen: int
) -> Dict[str, Any]:
    """Model inputs for one prefill group, prompts left-padded to ``plen``."""
    B = len(group)
    toks = np.zeros((B, plen), np.int32)
    for i, r in enumerate(group):
        toks[i, -len(r.prompt):] = r.prompt[:plen]
    batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros(
            (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
        )
        pos = jnp.broadcast_to(jnp.arange(plen, dtype=jnp.int32), (B, plen))
        batch["positions"] = jnp.broadcast_to(pos, (3, B, plen))
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.zeros(
            (B, cfg.encoder_len, cfg.d_model), jnp.bfloat16
        )
    return batch


class Server:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        batch_size: int = 4,
        max_len: int = 128,
        tuning_db: Optional[TuningDB] = None,
        mesh: Any = None,
        background_tuner: Optional[BackgroundTuner] = None,
        inline_tune: bool = False,
        device_key: bool = False,
        drift_monitor: Optional[Any] = None,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.db = tuning_db or TuningDB()
        self.mesh = mesh
        self.background = background_tuner
        self.inline_tune = inline_tune
        # fleet integration (docs/fleet.md): device_key namespaces every
        # traffic class under the host's DeviceFingerprint so a shared
        # fleet DB never hands this server a foreign host's final; the
        # DriftMonitor rides the serve loop's existing run-time
        # observations and mirrors its selections into the
        # DegreeController exactly like tuned winners do.
        self.device_key = device_key
        self.drift = drift_monitor
        if self.drift is not None and self.drift.on_apply is None:
            self.drift.on_apply = self._on_tuned
        self.degree = DegreeController(max_degree=batch_size)
        self.stats = ServeStats()
        # count at the Python wrapper, not inside jit: traced code only runs
        # at compile time, so an in-graph counter would freeze at 1.
        # capacity=max_len gives decode real KV headroom: with the old
        # default (capacity == prompt length) the cache was born full and
        # every decode write clamped onto the last prompt slot.
        raw_prefill = jax.jit(
            lambda p, b: prefill_fn(
                p, b, cfg, capacity=max(max_len, b["tokens"].shape[1])
            )
        )
        raw_decode = jax.jit(lambda p, b, c: decode_fn(p, b, c, cfg))

        def counted_prefill(p, b):
            self.stats.prefill_calls += 1
            return raw_prefill(p, b)

        def counted_decode(p, b, c):
            self.stats.decode_calls += 1
            return raw_decode(p, b, c)

        self._prefill = counted_prefill
        self._decode = counted_decode
        self.prefill_op = self._make_prefill_op()
        self.decode_op = self._make_decode_op()
        self._hot_tuned: set = set()  # fingerprints tuned inline on a serve call
        self.joint_result: Optional[ProgramResult] = None

    # -- degree candidate family -----------------------------------------------

    def _degree_domain(self) -> Tuple[int, ...]:
        """Serving degrees: batch-chunk counts that keep outputs identical.

        MoE capacity-bounded dispatch couples rows across the batch (which
        tokens drop depends on the whole group), so MoE only ever serves the
        whole batch at once.
        """
        if self.cfg.family == "moe":
            return (1,)
        return tuple(
            d for d in (1, 2, 4) if d <= self.batch_size and self.batch_size % d == 0
        )

    def _degree_space(self) -> ParamSpace:
        return ParamSpace([PerfParam("degree", self._degree_domain())])

    # -- registry ops ----------------------------------------------------------

    def _make_prefill_op(self) -> AutotunedOp:
        cfg, prefill, mesh = self.cfg, self._prefill, self.mesh

        def instantiate(point):
            d = int(point.get("degree", 1))
            if d == 1:
                return lambda params, batch: prefill(params, batch)

            def chunked(params, batch):
                outs = [
                    prefill(params, _batch_chunk(batch, i, d)) for i in range(d)
                ]
                logits = jnp.concatenate([o[0] for o in outs], axis=0)
                return logits, _cache_concat([o[1] for o in outs])

            return chunked

        # the exact serving batch (not just the traffic bucket) is part of
        # the key: the degree domain is "divisors of batch_size", so two
        # servers whose batch sizes share a pow2 bucket must not share a
        # tuned winner — a degree that doesn't divide the batch is invalid
        batch_size = self.batch_size

        def shape_class(params, batch) -> BasicParams:
            # mesh entries are computed per call, not baked at construction:
            # with mesh=None the active activation_sharding context decides,
            # so a resharded server keys fresh entries instead of reusing
            # winners measured under the old factorization
            return BasicParams.make(
                kernel="serve_prefill", arch=cfg.name, batch=batch_size,
                backend=jax.default_backend(), **mesh_bp_entries(mesh),
            )

        def traffic_class(params, batch) -> TrafficClass:
            B, plen = batch["tokens"].shape
            return TrafficClass.of("prefill", int(B), int(plen))

        spec = register_kernel(
            KernelSpec(
                name=f"serve_prefill/{cfg.name}",
                make_region=lambda bp: _region(
                    "serve_prefill", self._degree_space(), instantiate
                ),
                shape_class=shape_class,
                tags=("runtime", "serve"),
                traffic_class=traffic_class,
            ),
            replace=True,
        )
        return AutotunedOp(
            spec, db=self.db, tune=self.inline_tune, warm=False, monitor=False,
            device_key=self.device_key,
        )

    def _make_decode_op(self) -> AutotunedOp:
        cfg, decode, mesh = self.cfg, self._decode, self.mesh

        def instantiate(point):
            d = int(point.get("degree", 1))
            if d == 1:
                return lambda params, batch, cache: decode(params, batch, cache)

            def chunked(params, batch, cache):
                outs = [
                    decode(params, _batch_chunk(batch, i, d), _cache_chunk(cache, i, d))
                    for i in range(d)
                ]
                logits = jnp.concatenate([o[0] for o in outs], axis=0)
                return logits, _cache_concat([o[1] for o in outs])

            return chunked

        batch_size = self.batch_size  # see _make_prefill_op: degree validity

        def shape_class(params, batch, cache) -> BasicParams:
            return BasicParams.make(  # per-call mesh: see _make_prefill_op
                kernel="serve_decode", arch=cfg.name, batch=batch_size,
                backend=jax.default_backend(), **mesh_bp_entries(mesh),
            )

        def traffic_class(params, batch, cache) -> TrafficClass:
            # decode classes bucket by context length (the KV len at decode
            # start): chunking economics differ between short- and
            # long-context decode, so they must not share a winner
            return TrafficClass.of(
                "decode",
                int(batch["tokens"].shape[0]),
                max(1, int(cache["len"])),
            )

        spec = register_kernel(
            KernelSpec(
                name=f"serve_decode/{cfg.name}",
                make_region=lambda bp: _region(
                    "serve_decode", self._degree_space(), instantiate
                ),
                shape_class=shape_class,
                tags=("runtime", "serve"),
                traffic_class=traffic_class,
            ),
            replace=True,
        )
        return AutotunedOp(
            spec, db=self.db, tune=self.inline_tune, warm=False, monitor=False,
            device_key=self.device_key,
        )

    # -- tuning hand-off -------------------------------------------------------

    def _resolve(self, op: AutotunedOp, *args: Any) -> OpState:
        """State for this call's traffic class: background submit or inline."""
        if self.background is not None:
            state = self.background.submit(op, *args, on_complete=self._on_tuned)
        else:
            before = op.states() if self.inline_tune else None
            state = op.resolve(*args)
            # attribution decided synchronously (thread idents recycle): a
            # state this very resolve just tuned was tuned on the serve path
            if (before is not None and state.tuned
                    and state.bp.fingerprint() not in before):
                self._hot_tuned.add(state.bp.fingerprint())
        if state.tuned or state.from_cache:  # winner already known (DB hit /
            self._on_tuned(state)            # inline tune): mirror its degree
        return state

    def _on_tuned(self, state: OpState) -> None:
        """Mirror the live selection's degree into the DegreeController so
        the serve loop's region entries switch to it (and restore max on
        exit).  Called when a winner lands (background or inline/DB) and
        again after a RuntimeSelector demotion re-selects."""
        deg = state.region.selected.get("degree")
        if deg is not None and state.traffic is not None:
            self.degree.set_tuned(state.traffic.label, int(deg))

    @property
    def hot_path_cost_evaluations(self) -> int:
        """Tuning cost evaluations paid inside a :meth:`run` call.

        The acceptance bar for background tuning: stays 0 — every evaluation
        happens on the BackgroundTuner's worker thread.
        """
        total = 0
        for op in (self.prefill_op, self.decode_op):
            for st in op.states().values():
                if st.bp.fingerprint() in self._hot_tuned:
                    total += st.cost_evaluations
        return total

    @property
    def traffic_classes_seen(self) -> List[str]:
        labels = set()
        for op in (self.prefill_op, self.decode_op):
            for st in op.states().values():
                if st.traffic is not None:
                    labels.add(st.traffic.label)
        return sorted(labels)

    # -- whole-program joint AT (docs/program.md) ------------------------------

    def _decode_batch(self, tok, cache) -> Dict[str, Any]:
        """One decode-step input batch for the just-sampled tokens."""
        d: Dict[str, Any] = {"tokens": tok[:, None]}
        if self.cfg.family == "vlm":
            p = cache["len"]
            pos = jnp.broadcast_to(p, (tok.shape[0], 1)).astype(jnp.int32)
            d["positions"] = jnp.broadcast_to(pos, (3, tok.shape[0], 1))
        return d

    def serve_program(
        self, requests: Sequence[ServingRequest], decode_steps: int = 4
    ) -> ProgramSpec:
        """The serve step as a joint problem: prefill degree × decode degree.

        The two phases share the KV-cache layout and the host's memory
        headroom, so their best chunking degrees are coupled — the joint
        cost is one *full* serve step (prefill + ``decode_steps`` decodes)
        measured end to end, and the winner hot-applies through each
        region's ``select`` plus the DegreeController mirror.
        """
        group = list(requests[: self.batch_size])
        if not group:
            raise ValueError("serve_program needs at least one request")
        while len(group) < self.batch_size:
            group.append(group[-1])
        plen = max(len(r.prompt) for r in group)
        batch = self._batch_inputs(group, plen)
        params = self.params
        pstate = self.prefill_op.resolve_deferred(params, batch)
        logits, cache = pstate.region(params, batch)
        tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        dstate = self.decode_op.resolve_deferred(
            params, self._decode_batch(tok0, cache), cache
        )
        members = [
            ProgramMember("prefill", pstate.region, bp=pstate.bp),
            ProgramMember("decode", dstate.region, bp=dstate.bp),
        ]

        def build(assignment):
            pfn = pstate.region.candidate(assignment["prefill"])
            dfn = dstate.region.candidate(assignment["decode"])

            def thunk():
                lg, ca = pfn(params, batch)
                tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                for _ in range(decode_steps):
                    lg, ca = dfn(params, self._decode_batch(tok, ca), ca)
                    tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                return lg

            return thunk

        def on_apply(assignment):
            # winners land in the DegreeController exactly like per-phase
            # tuning results do, so run()'s set/restore bracket adopts them
            self._on_tuned(pstate)
            self._on_tuned(dstate)

        if self.device_key:
            # the program fingerprint is device-namespaced like every other
            # DB key on this server: a joint winner measured on one host
            # must not be recalled on a different one (docs/fleet.md)
            from repro.fleet.fingerprint import device_bp_entries

            device_extra = device_bp_entries()
        else:
            device_extra = {}
        return ProgramSpec(
            f"serve_step/{self.cfg.name}", members, db=self.db, build=build,
            on_apply=on_apply,
            extra={
                "batch": self.batch_size, "plen": int(plen),
                "steps": int(decode_steps), "backend": jax.default_backend(),
                **mesh_bp_entries(self.mesh), **device_extra,
            },
        )

    def joint_tune(
        self,
        requests: Sequence[ServingRequest],
        decode_steps: int = 4,
        cap: Optional[int] = 16,
        k: Optional[int] = None,
        force: bool = False,
    ) -> ProgramResult:
        """Joint before-execution AT of one full serve step (docs/program.md)."""
        program = self.serve_program(requests, decode_steps=decode_steps)
        self.joint_result = program.tune(k=k, cap=cap, force=force)
        return self.joint_result

    # -- batching --------------------------------------------------------------

    def _batch_inputs(self, group: Sequence[ServingRequest], plen: int) -> Dict[str, Any]:
        return build_batch_inputs(self.cfg, group, plen)

    def run(self, requests: Sequence[ServingRequest]) -> Dict[int, List[int]]:
        """Greedy-decode every request; returns rid -> generated token ids."""
        check_unique_rids(requests)
        check_well_formed(requests)
        out: Dict[int, List[int]] = {}
        for i in range(0, len(requests), self.batch_size):
            real = list(requests[i : i + self.batch_size])
            group = list(real)
            while len(group) < self.batch_size:  # pad the tail batch
                group.append(group[-1])
            plen = max(len(r.prompt) for r in group)
            batch = self._batch_inputs(group, plen)

            t_batch = time.perf_counter()
            pstate = self._resolve(self.prefill_op, self.params, batch)
            plabel = pstate.traffic.label if pstate.traffic else "prefill"
            t0 = time.perf_counter()
            with self.degree.region(plabel):
                # dispatch through the resolved region directly: re-resolving
                # per call would recompute the BP fingerprint on the hot path
                logits, cache = pstate.region(self.params, batch)
                logits.block_until_ready()
            prefill_elapsed = time.perf_counter() - t0
            self.stats.prefill_s += prefill_elapsed
            if pstate.selector is not None:
                # run-time layer: one observation per region call, so a
                # regressed winner demotes to the next-best precompiled one
                if pstate.selector.observe(prefill_elapsed):
                    self._on_tuned(pstate)  # keep the controller in sync
            if self.drift is not None:
                # the fleet drift watch rides the same observation; the
                # call args are captured so a scheduled re-tune measures
                # candidates on a real batch (docs/fleet.md)
                self.drift.observe(
                    self.prefill_op, pstate, prefill_elapsed,
                    (self.params, batch),
                )

            n_steps = max(r.max_new_tokens for r in group)
            gen = [[] for _ in group]
            t0 = time.perf_counter()
            # the prefill's argmax IS generated token #1: only n_steps - 1
            # decode calls remain (the old loop ran n_steps and discarded
            # the final decode's sample — one wasted full step per group)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for gi in range(len(group)):
                gen[gi].append(int(next_tok[gi]))

            if n_steps > 1:
                dbatch = self._decode_batch(next_tok, cache)
                dstate = self._resolve(self.decode_op, self.params, dbatch, cache)
                dlabel = dstate.traffic.label if dstate.traffic else "decode"
                step_times: List[float] = []
                # one set/restore per group, not per token: the label (and
                # the executed candidate) is fixed for the whole decode loop
                with self.degree.region(dlabel):
                    for step in range(n_steps - 1):
                        ts = time.perf_counter()
                        logits, cache = dstate.region(self.params, dbatch, cache)
                        logits.block_until_ready()
                        step_times.append(time.perf_counter() - ts)
                        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                        for gi in range(len(group)):
                            gen[gi].append(int(next_tok[gi]))
                        dbatch = self._decode_batch(next_tok, cache)
                jax.block_until_ready(next_tok)
                if dstate.selector is not None and step_times:
                    # the observation must be unit-compatible with the tuned
                    # per-call trial cost: median of the *bare* region-call
                    # times (the loop's per-token python overhead excluded),
                    # one DB observation per group, never per token
                    if dstate.selector.observe(float(np.median(step_times))):
                        self._on_tuned(dstate)  # keep the controller in sync
                if self.drift is not None and step_times:
                    self.drift.observe(
                        self.decode_op, dstate, float(np.median(step_times)),
                        (self.params, dbatch, cache),
                    )
            self.stats.decode_s += time.perf_counter() - t0
            # only tokens delivered to real requests count: padded tail rows
            # and steps past a request's own max_new_tokens are not output
            self.stats.tokens_out += sum(
                min(r.max_new_tokens, n_steps) for r in real
            )
            self.stats.batch_latencies.append(time.perf_counter() - t_batch)

            for gi, r in enumerate(real):
                out[r.rid] = gen[gi][: r.max_new_tokens]
        return out


def _region(name: str, space: ParamSpace, instantiate):
    from repro.core import ATRegion

    return ATRegion(name, space, instantiate)
