"""Batched serving loop: prefill + greedy decode with a KV/state cache.

A deliberately small continuous-batching server: requests are grouped into
fixed-size batches (padding prompts to a shared length), prefilled once, then
decoded step-by-step.  Both the prefill and decode executables are built once
per (batch, length) bucket — serving-side AOT candidate generation, matching
the paper's no-runtime-codegen discipline.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import ServingRequest
from repro.models import decode_fn, prefill_fn
from repro.models.config import ModelConfig


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class Server:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        batch_size: int = 4,
        max_len: int = 128,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self._prefill = jax.jit(lambda p, b: prefill_fn(p, b, cfg))
        self._decode = jax.jit(lambda p, b, c: decode_fn(p, b, c, cfg))
        self.stats = ServeStats()

    def _batch_inputs(self, group: Sequence[ServingRequest], plen: int) -> Dict[str, Any]:
        B = len(group)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(group):
            toks[i, -len(r.prompt):] = r.prompt[:plen]
        batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (B, self.cfg.n_vision_tokens, self.cfg.d_model), jnp.bfloat16
            )
            pos = jnp.broadcast_to(jnp.arange(plen, dtype=jnp.int32), (B, plen))
            batch["positions"] = jnp.broadcast_to(pos, (3, B, plen))
        if self.cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encoder_len, self.cfg.d_model), jnp.bfloat16
            )
        return batch

    def run(self, requests: Sequence[ServingRequest]) -> Dict[int, List[int]]:
        """Greedy-decode every request; returns rid -> generated token ids."""
        out: Dict[int, List[int]] = {}
        for i in range(0, len(requests), self.batch_size):
            group = list(requests[i : i + self.batch_size])
            while len(group) < self.batch_size:  # pad the tail batch
                group.append(group[-1])
            plen = max(len(r.prompt) for r in group)
            batch = self._batch_inputs(group, plen)

            t0 = time.perf_counter()
            logits, cache = self._prefill(self.params, batch)
            logits.block_until_ready()
            self.stats.prefill_s += time.perf_counter() - t0

            n_steps = max(r.max_new_tokens for r in group)
            gen = [[] for _ in group]
            t0 = time.perf_counter()
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for step in range(n_steps):
                for gi in range(len(group)):
                    gen[gi].append(int(next_tok[gi]))
                dbatch: Dict[str, Any] = {"tokens": next_tok[:, None]}
                if self.cfg.family == "vlm":
                    p = cache["len"]
                    pos = jnp.broadcast_to(p, (len(group), 1)).astype(jnp.int32)
                    dbatch["positions"] = jnp.broadcast_to(pos, (3, len(group), 1))
                logits, cache = self._decode(self.params, dbatch, cache)
                next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            jax.block_until_ready(next_tok)
            self.stats.decode_s += time.perf_counter() - t0
            self.stats.tokens_out += n_steps * len(group)

            for gi, r in enumerate(group[: len(requests[i : i + self.batch_size])]):
                out[r.rid] = gen[gi][: r.max_new_tokens]
        return out
