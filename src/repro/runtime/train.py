"""Fault-tolerant training loop with run-time AT integration.

Large-scale behaviours implemented here and exercised by tests:

* **checkpoint/restart** — atomic saves every N steps; on start the loop
  restores the latest checkpoint and replays the data stream from that step
  (the dataset is pure in (seed, step)), so an interrupted run converges to
  bit-identical losses (test_runtime.py asserts this).
* **failure injection** — ``failure_hook(step)`` may raise
  :class:`SimulatedFailure`; ``run()`` treats it exactly like a node loss:
  tear down step state, restore, continue.
* **straggler mitigation = FIBER run-time AT** — the jitted train step for
  every microbatch degree is AOT-precompiled (ppOpen-AT's pre-generated
  subroutines); a :class:`repro.core.tuner.RuntimeSelector` watches measured
  step times and re-selects the next-best precompiled degree when the
  current one regresses ≥ tolerance — a free switch, as the paper's Fig-12
  measures for ``omp_set_num_threads``.
* **gradient accumulation degree** — the PP: the global batch is split into
  ``n_microbatches`` scanned chunks; more microbatches = less activation
  memory, more sequential steps (the thread-grain trade, docs/design.md §2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import (
    ATRegion,
    AutotunedOp,
    BasicParams,
    KernelSpec,
    ParamSpace,
    PerfParam,
    ProgramMember,
    ProgramResult,
    ProgramSpec,
    TuningDB,
    register_kernel,
)
from repro.models import param_specs, train_loss
from repro.models.config import ModelConfig
from repro.models.spec import init_params
from repro.optim import AdamWConfig, adamw_init, adamw_update


class SimulatedFailure(RuntimeError):
    """Stand-in for a node loss / preemption in tests and drills."""


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    save_every: int = 50
    keep_checkpoints: int = 3
    n_microbatches: int = 1
    microbatch_candidates: Sequence[int] = (1, 2, 4)
    straggler_tolerance: float = 3.0
    seed: int = 0
    # whole-program joint AT (docs/program.md): tune (microbatch degree ×
    # remat directive) against the *measured full train step* before the
    # loop starts, instead of pinning the configured degree.  The two knobs
    # are the paper's pair — remat is the directive change, the microbatch
    # degree the thread-count analogue — and they interact (both trade
    # activation memory against time), which is why they are tuned jointly.
    joint_tune: bool = False
    joint_cap: Optional[int] = 16
    joint_k: Optional[int] = None
    remat_candidates: Sequence[str] = ("none", "full")
    # fleet device keying (docs/fleet.md): namespace the train step's BP —
    # and the joint program fingerprint — under the host's
    # DeviceFingerprint, so a fleet-shared TuningDB never hands this host a
    # degree/remat winner measured on different hardware.
    device_key: bool = False


def make_train_step(
    cfg: ModelConfig, opt_cfg: AdamWConfig, n_microbatches: int
) -> Callable:
    """Build the pure train step for one microbatch degree."""

    def step_fn(params, opt_state, batch):
        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(lambda p: train_loss(p, batch, cfg))(
                params
            )
        else:
            def split(x):
                b = x.shape[0]
                if x.ndim >= 2 and x.shape[0] == 3 and b == 3:  # mrope positions
                    return None
                return x.reshape((n_microbatches, b // n_microbatches) + x.shape[1:])

            # positions (3, B, S) needs batch-axis split on axis 1
            def split_leaf(path_x):
                return path_x

            micro = {}
            for k, v in batch.items():
                if k == "positions" and v.ndim == 3 and v.shape[0] == 3:
                    micro[k] = jnp.moveaxis(
                        v.reshape(3, n_microbatches, -1, v.shape[-1]), 1, 0
                    )
                else:
                    micro[k] = v.reshape(
                        (n_microbatches, v.shape[0] // n_microbatches) + v.shape[1:]
                    )

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                g_acc, loss_acc = carry
                loss, grads = jax.value_and_grad(
                    lambda p: train_loss(p, mb, cfg)
                )(params)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, loss_acc + loss), None

            (gsum, losssum), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
            loss = losssum / n_microbatches
        params, opt_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step_fn


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        loop_cfg: TrainLoopConfig,
        tuning_db: Optional[TuningDB] = None,
    ) -> None:
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.loop = loop_cfg
        self.db = tuning_db or TuningDB()
        self.ckpt = (
            CheckpointManager(
                loop_cfg.ckpt_dir, loop_cfg.save_every, loop_cfg.keep_checkpoints
            )
            if loop_cfg.ckpt_dir
            else None
        )
        self.straggler_events = 0
        self.restarts = 0

        # The train step is a registry op like any kernel: the microbatch
        # degree is its PP (run-time layer), and its shape class is fixed by
        # (arch, candidate degrees).  The configured degree is pinned rather
        # than wall-clock-tuned so restarted runs stay bit-deterministic;
        # joint_tune replaces the pin with a whole-program search whose cost
        # is the measured full step.  The remat directive lives in a mutable
        # cell so a joint winner hot-applies without rebuilding the region
        # (the region is invalidated instead, see _on_joint_apply).
        degrees = tuple(loop_cfg.microbatch_candidates)
        self._step_remat = cfg.remat
        bp = BasicParams.make(arch=cfg.name, kind="train_runtime", micro=degrees)
        if loop_cfg.device_key:
            from repro.fleet.fingerprint import device_bp_entries

            bp = bp.with_entries(**device_bp_entries())
        spec = register_kernel(
            KernelSpec(
                name=f"train_step/{cfg.name}",
                make_region=lambda _bp: ATRegion(
                    name="train_step",
                    space=ParamSpace([PerfParam("n_micro", degrees)]),
                    instantiate=lambda pt: jax.jit(
                        make_train_step(
                            cfg.with_(remat=self._step_remat), opt_cfg,
                            pt["n_micro"],
                        )
                    ),
                ),
                shape_class=lambda *a, **k: bp,
                tags=("runtime",),
            ),
            replace=True,
        )
        self.op = AutotunedOp(
            spec,
            db=self.db,
            tune=False,
            warm=False,
            monitor=False,  # the loop times steps itself (it also tracks
            # straggler_events), feeding the selector directly
            tolerance=loop_cfg.straggler_tolerance,
        )
        self.bp = bp
        self._state = self.op.select({"n_micro": loop_cfg.n_microbatches})
        self.region = self._state.region
        self.joint_result: Optional[ProgramResult] = None

    # -- whole-program joint AT (docs/program.md) --------------------------------

    def train_program(self, params, opt_state, batch) -> ProgramSpec:
        """The train step as a joint tuning problem: micro × remat.

        ``micro`` is the live train region (so the joint winner hot-applies
        straight through ``region.select``); ``remat`` is the directive
        member.  The program's cost builds one fresh jitted step per joint
        assignment and measures it end to end — per-knob greedy tuning
        cannot see that both knobs compete for the same activation memory.
        """
        cfg, opt_cfg, loop = self.cfg, self.opt_cfg, self.loop
        remats = tuple(loop.remat_candidates)
        remat_region = ATRegion(
            "train_remat",
            ParamSpace([PerfParam("remat", remats)]),
            instantiate=lambda pt: jax.jit(
                make_train_step(
                    cfg.with_(remat=pt["remat"]), opt_cfg, loop.n_microbatches
                )
            ),
        )
        if cfg.remat in remats:
            remat_region.select({"remat": cfg.remat})  # untuned baseline
        members = [
            ProgramMember("micro", self.region, bp=self.bp),
            ProgramMember(
                "remat", remat_region,
                bp=BasicParams.make(
                    arch=cfg.name, kind="train_remat", remat=remats
                ),
            ),
        ]

        def build(assignment):
            step = jax.jit(
                make_train_step(
                    cfg.with_(remat=assignment["remat"]["remat"]),
                    opt_cfg,
                    int(assignment["micro"]["n_micro"]),
                )
            )

            def thunk():
                _, _, metrics = step(params, opt_state, batch)
                return metrics["loss"]

            return thunk

        tokens = batch.get("tokens")
        extra = {
            "arch": cfg.name,
            "backend": jax.default_backend(),
            "batch": int(tokens.shape[0]) if tokens is not None else 0,
            "seq": int(tokens.shape[1]) if tokens is not None else 0,
        }
        if loop.device_key:  # device-namespaced program fingerprint
            from repro.fleet.fingerprint import device_bp_entries

            extra.update(device_bp_entries())
        return ProgramSpec(
            f"train_step/{cfg.name}", members, db=self.db, build=build,
            on_apply=self._on_joint_apply, extra=extra,
        )

    def _on_joint_apply(self, assignment) -> None:
        """Mirror the joint winner's remat directive into the live step.

        The micro member *is* the live region, so its ``select`` already
        landed; the remat directive lives in the instantiate closure, so
        adopting it invalidates the region's compiled candidates (they were
        built under the old directive) — the next step pays one rebuild,
        every later switch is a dict lookup again.
        """
        remat = assignment.get("remat", {}).get("remat")
        if remat is not None and remat != self._step_remat:
            self._step_remat = remat
            self.region.invalidate()

    def joint_tune(self, dataset, key: Optional[jax.Array] = None,
                   force: bool = False,
                   state: Optional[Tuple[Any, Any]] = None) -> ProgramResult:
        """Joint before-execution AT of the whole train step.

        A final winner recorded under the program fingerprint short-circuits
        to a hot apply (zero evaluations, the cross-run cache); otherwise
        the :class:`~repro.core.program.JointSearch` measures full steps.
        ``state`` reuses an already-initialized ``(params, opt_state)`` pair
        (``run()`` passes its own) instead of materializing a second copy.
        """
        key = key if key is not None else jax.random.PRNGKey(self.loop.seed)
        batch = {k: jnp.asarray(v) for k, v in dataset.batch(0).items()}
        params, opt_state = state if state is not None else self.init_state(key)
        program = self.train_program(params, opt_state, batch)
        self.joint_result = program.tune(
            k=self.loop.joint_k, cap=self.loop.joint_cap, force=force
        )
        return self.joint_result

    # -- state ------------------------------------------------------------------

    def init_state(self, key: jax.Array) -> Tuple[Any, Any]:
        params = init_params(key, param_specs(self.cfg))
        opt_state = adamw_init(params, self.opt_cfg)
        return params, opt_state

    # -- main loop ---------------------------------------------------------------

    def run(
        self,
        dataset,
        key: Optional[jax.Array] = None,
        failure_hook: Optional[Callable[[int], None]] = None,
        max_restarts: int = 3,
    ) -> Dict[str, List[float]]:
        key = key if key is not None else jax.random.PRNGKey(self.loop.seed)
        params, opt_state = self.init_state(key)
        if self.loop.joint_tune and self.joint_result is None:
            self.joint_tune(dataset, key, state=(params, opt_state))
        start = 0
        if self.ckpt is not None:
            restored = self.ckpt.restore_latest({"p": params, "o": opt_state})
            if restored is not None:
                start, tree = restored
                params, opt_state = tree["p"], tree["o"]

        selector = self._state.selector
        history: Dict[str, List[float]] = {"loss": [], "step_time": [], "step": []}
        step_times: List[float] = []

        step = start
        while step < self.loop.total_steps:
            try:
                if failure_hook is not None:
                    failure_hook(step)
                batch = {
                    k: jnp.asarray(v) for k, v in dataset.batch(step).items()
                }
                t0 = time.perf_counter()
                params, opt_state, metrics = self.region(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0

                step_times.append(dt)
                if len(step_times) > 32:
                    step_times.pop(0)
                med = float(np.median(step_times))
                if len(step_times) >= 8 and dt > self.loop.straggler_tolerance * med:
                    self.straggler_events += 1
                if selector.observe(dt):
                    pass  # re-selected a precompiled degree; next step uses it

                history["loss"].append(float(metrics["loss"]))
                history["step_time"].append(dt)
                history["step"].append(step)
                step += 1
                if self.ckpt is not None:
                    self.ckpt.maybe_save(step, {"p": params, "o": opt_state})
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > max_restarts:
                    raise
                # node loss: restore the latest checkpoint and resume
                params, opt_state = self.init_state(key)
                step = 0
                if self.ckpt is not None:
                    restored = self.ckpt.restore_latest({"p": params, "o": opt_state})
                    if restored is not None:
                        step, tree = restored
                        params, opt_state = tree["p"], tree["o"]
        if self.ckpt is not None:
            self.ckpt.maybe_save(step, {"p": params, "o": opt_state}, force=True)
        self._final_params = params
        return history
