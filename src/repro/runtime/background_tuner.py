"""Off-hot-path tuning orchestrator for serving traffic classes.

The paper tunes at install / before-execution time precisely so the run-time
layer never pays search cost.  A server cannot stop the world for
before-execution AT when an unseen traffic class arrives, so this module
moves that layer onto a worker thread:

1. the serve loop calls :meth:`BackgroundTuner.submit` for every batch — a
   shape-class/DB lookup only, **never** a cost evaluation;
2. an unseen class is enqueued once (deduplicated by BP fingerprint) and the
   caller keeps serving the region's safe precompiled default;
3. the worker pops the job, runs the op's search on the captured example
   arguments (:meth:`~repro.core.autotuned.AutotunedOp.tune_state`), warms
   the top-k candidates, and the winner lands via ``region.select`` — the
   same set-on-entry/restore-on-exit switch the
   :class:`~repro.core.tuner.RuntimeSelector` and
   :class:`~repro.core.degree.DegreeController` use, so the hot swap is a
   dict-lookup away from the next request, with zero compilation.

The worker's search is the op's default path — the staged tuning pipeline
(docs/tuning.md): a traffic class whose kernel already has a tuned sibling
class starts from that winner (a short refinement run instead of a full
sweep), and specs with a prescreen rank the space with the cheap
before-execution cost so only top-k survivors pay a measured evaluation.
:attr:`background_evaluations` counts the measured stage only;
:attr:`prescreen_evaluations` and :attr:`warm_started_labels` expose the
pipeline's bookkeeping for the operator and the throughput benchmark.

An optional ``on_complete`` callback lets the server mirror the tuned
degree into its :class:`~repro.core.degree.DegreeController` (the
``omp_set_num_threads`` bookkeeping) the moment a winner lands.

See docs/serving.md for the full lifecycle.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.autotuned import AutotunedOp, OpState
from repro.obs.trace import current_tracer


@dataclass
class TuneJob:
    op: AutotunedOp
    state: OpState
    args: tuple
    kwargs: dict
    label: str
    on_complete: Optional[Callable[[OpState], None]] = None
    # drift re-tune (docs/fleet.md): run even though the class is tuned,
    # fresh-measured, unselected and unfinalized; the winner (or None on
    # failure) goes to ``on_winner`` — the DriftMonitor's canary entry.
    retune: bool = False
    on_winner: Optional[Callable[[Optional[dict]], None]] = None
    # enqueue stamp (time.perf_counter): the job span reports queue wait
    submitted_s: float = 0.0


class BackgroundTuner:
    """Worker thread + queue that runs before-execution AT off the hot path.

    ``fleet`` (optional, docs/fleet.md): a
    :class:`~repro.fleet.FleetCoordinator` — every queued search is then
    sharded across the coordinator's in-process workers with the merge
    barrier landing results in the op's DB (the spawn backend cannot sit
    here: the op's measured cost closes over live arrays).  Sharding
    pays off for compile-dominated costs; concurrent *measured* timings
    on one device include cross-worker contention, so winners stay
    supervised by the run-time layer rather than trusted blindly.

    ``service`` (optional, docs/fleet.md): a
    :class:`~repro.fleet.ServiceClient` on the global tuning service.
    Before searching, the worker asks the service: an exact
    device-fingerprint **final** is adopted outright — merged into the
    op's DB and hot-swapped in with *zero* cost evaluations
    (:attr:`pulled_labels`); a **nearest** entry is merged so the op's
    existing warm-start machinery seeds the (much shorter) refinement
    run.  After a successful local search the winner is pushed back, so
    the next host skips the search entirely.  All service traffic is
    ``try_*`` best-effort — a dead or partitioned service degrades this
    tuner to exactly its local-only behaviour.
    """

    # the stop() sentinel must drain after every queued job regardless of
    # its priority, so it carries a key below any real submission
    _SENTINEL_KEY = 1 << 30

    def __init__(
        self,
        name: str = "repro-background-tuner",
        fleet: Optional[Any] = None,
        service: Optional[Any] = None,
    ) -> None:
        self.name = name
        self.fleet = fleet
        self.service = service
        self.pulled_labels: List[str] = []  # finals adopted from the service
        # (-priority, seq, job): higher priority pops first, FIFO within a
        # priority level.  seq breaks ties before the (unorderable) job.
        self._queue: "queue.PriorityQueue[Tuple[int, int, Optional[TuneJob]]]" \
            = queue.PriorityQueue()
        self._seq = 0
        self._cv = threading.Condition()
        self._inflight: set = set()  # BP fingerprints queued or tuning now
        self._failed: Dict[str, str] = {}  # fp -> label, search raised
        self._quarantined: Dict[str, str] = {}  # fp -> label, candidates quarantined
        self._thread: Optional[threading.Thread] = None
        self.completed: List[Tuple[str, OpState]] = []
        self.errors: List[Tuple[str, BaseException]] = []

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "BackgroundTuner":
        with self._cv:  # two racing first-submits must not spawn two workers
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._worker, name=self.name, daemon=True
                )
                self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        with self._cv:
            thread = self._thread
        if thread is not None and thread.is_alive():
            self._put(None, self._SENTINEL_KEY)
            thread.join(timeout)
            if thread.is_alive():
                # still draining a long tune: keep the handle so a later
                # start() cannot spawn a second worker on the same queue
                return
        with self._cv:
            if self._thread is thread:
                self._thread = None

    def _put(self, job: Optional[TuneJob], key: int) -> None:
        with self._cv:
            self._seq += 1
            seq = self._seq
        self._queue.put((key, seq, job))

    def __enter__(self) -> "BackgroundTuner":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # -- the serve-loop API --------------------------------------------------

    def submit(
        self,
        op: AutotunedOp,
        *args: Any,
        on_complete: Optional[Callable[[OpState], None]] = None,
        priority: int = 0,
        **kwargs: Any,
    ) -> OpState:
        """Resolve the call's shape class without tuning; queue tuning if new.

        Returns the state immediately — selected at the tuned winner when the
        DB already has one, at the safe default otherwise.  The caller's
        thread performs zero cost evaluations regardless of the op's ``tune``
        flag (``resolve_deferred`` never tunes).  A class whose search raised
        is not retried — it keeps serving the default and stays listed in
        :attr:`errors` / :attr:`failed_labels` for the operator.

        ``priority``: higher pops sooner (FIFO within a level).  The
        streaming engine submits scheduler-knob classes above kernel
        classes — a tuned scheduler reshapes every later batch, so it
        should win the queue.
        """
        self.start()
        state = op.resolve_deferred(*args, **kwargs)
        if state.tuned or state.from_cache:
            return state
        fp = state.bp.fingerprint()
        with self._cv:
            if fp in self._inflight or fp in self._failed:
                return state
            self._inflight.add(fp)
        label = state.traffic.label if state.traffic else op.spec.name
        self._put(TuneJob(op, state, args, kwargs, label, on_complete,
                          submitted_s=time.perf_counter()),
                  -priority)
        return state

    def submit_retune(
        self,
        op: AutotunedOp,
        state: OpState,
        args: tuple = (),
        kwargs: Optional[dict] = None,
        on_winner: Optional[Callable[[Optional[dict]], None]] = None,
    ) -> bool:
        """Queue a *fresh* re-measure of an already-tuned class.

        The DriftMonitor's off-hot-path re-tune: unlike :meth:`submit` this
        enqueues even though the class is tuned (that is the point — its
        winner drifted), clears any earlier failure mark (a re-tune is an
        explicit retry), and hands the challenger point to ``on_winner``
        instead of selecting it — the canary window decides the hot apply.
        Returns False when the class is already queued or tuning.
        """
        self.start()
        fp = state.bp.fingerprint()
        with self._cv:
            if fp in self._inflight:
                return False
            self._failed.pop(fp, None)
            self._inflight.add(fp)
        label = state.traffic.label if state.traffic else op.spec.name
        self._put(TuneJob(
            op, state, args, dict(kwargs or {}), label,
            retune=True, on_winner=on_winner,
            submitted_s=time.perf_counter(),
        ), 0)
        return True

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every queued class is tuned; False on timeout."""
        with self._cv:
            return self._cv.wait_for(lambda: not self._inflight, timeout)

    @property
    def pending(self) -> int:
        with self._cv:
            return len(self._inflight)

    @property
    def tuned_labels(self) -> List[str]:
        return [label for label, _ in self.completed]

    @property
    def failed_labels(self) -> List[str]:
        """Classes whose *search* failed — permanently serving the default.

        (:attr:`errors` can additionally hold ``on_complete`` callback
        exceptions; those classes are tuned and not listed here.)
        """
        with self._cv:
            return sorted(self._failed.values())

    @property
    def quarantined_labels(self) -> List[str]:
        """Classes whose search quarantined at least one candidate.

        The measurement guardrail (:meth:`~repro.core.tuner.Tuner.tune`)
        marks candidates whose cost raised or came back non-finite; the
        class itself may still have tuned fine on the surviving points.
        Surfaced here (next to :attr:`failed_labels`) so the operator sees
        broken candidates even when the search as a whole succeeded.
        """
        with self._cv:
            return sorted(self._quarantined.values())

    @property
    def background_evaluations(self) -> int:
        """Measured cost evaluations this tuner ran — all off the hot path."""
        return sum(state.cost_evaluations for _, state in self.completed)

    @property
    def prescreen_evaluations(self) -> int:
        """Cheap stage-1 scores (analytic / compile-only, never executed)."""
        return sum(state.prescreen_evaluations for _, state in self.completed)

    @property
    def warm_started_labels(self) -> List[str]:
        """Classes tuned as warm-started refinements of a sibling's winner."""
        return [label for label, st in self.completed if st.warm_seed is not None]

    # -- worker --------------------------------------------------------------

    def _worker(self) -> None:
        while True:
            _, _, job = self._queue.get()
            if job is None:
                return
            tr = current_tracer()
            if tr is None:
                self._handle(job)
                continue
            wait = (
                time.perf_counter() - job.submitted_s
                if job.submitted_s else 0.0
            )
            # the queue->tune->swap lifecycle span: queue wait rides as an
            # attr, the tune itself nests the tuner.tune / search.* spans,
            # and the hot swap is stamped by the outcome
            with tr.span(
                "bgtuner.job", cat="bgtuner", label=job.label,
                retune=job.retune, queue_wait_s=round(max(0.0, wait), 6),
            ) as attrs:
                attrs["outcome"] = self._handle(job)

    def _handle(self, job: TuneJob) -> str:
        """Run one job through the queue->tune->swap lifecycle; returns the
        outcome label (``tuned`` / ``adopted`` / ``retuned`` / ``failed``)."""
        fp = job.state.bp.fingerprint()
        outcome = "tuned"
        try:
            if job.retune:
                self._run_retune(job)
                outcome = "retuned"
            elif self._adopt_from_service(job):
                outcome = "adopted"  # the service's final landed; no search
            else:
                job.op.tune_state(
                    job.state, job.args, job.kwargs,
                    search=self._fleet_search(job),
                )
                self._push_to_service(job, fp)
        except BaseException as e:  # a bad class must not kill the worker
            self.errors.append((job.label, e))
            outcome = "failed"
            with self._cv:  # never retried: submit() skips failed classes
                if not job.retune:
                    self._failed[fp] = job.label
        else:
            if not job.retune:
                tr = current_tracer()
                if tr is not None:  # the winner is live from this point on
                    tr.instant(
                        "bgtuner.swap", cat="bgtuner", label=job.label,
                        outcome=outcome,
                    )
                self.completed.append((job.label, job.state))
                if job.on_complete is not None:
                    try:  # a callback bug is an error, not a failed tune
                        job.on_complete(job.state)
                    except BaseException as e:
                        self.errors.append((job.label, e))
        finally:
            try:  # guardrail bookkeeping must not kill the worker either
                if job.op.db.quarantined(job.state.bp):
                    with self._cv:
                        self._quarantined[fp] = job.label
            except BaseException:
                pass
            with self._cv:
                self._inflight.discard(fp)
                self._cv.notify_all()
        return outcome

    def _fleet_search(self, job: TuneJob):
        """This job's search override: fleet-sharded when a coordinator is set."""
        if self.fleet is None:
            return None
        return self.fleet.as_search(bp=job.state.bp, db=job.op.db)

    def _adopt_from_service(self, job: TuneJob) -> bool:
        """Pull before tuning: adopt a device-matched final, seed from nearest.

        Returns True when the service supplied an exact final — merged
        into the op's DB and hot-swapped in with zero cost evaluations.
        A ``nearest`` entry is merged (a warm-start seed for the search
        this worker is about to run) and False returned; a degraded or
        absent service is just False.
        """
        if self.service is None:
            return False
        state = job.state
        resp = self.service.try_pull(state.bp)
        if resp is None or resp.get("found") is None:
            return False
        job.op.db.merge({resp["fingerprint"]: resp["entry"]})
        if resp["found"] != "final":
            return False  # nearest: the merged entry seeds the warm start
        tuned = job.op.db.tuned_point(
            state.bp,
            space_signature=getattr(state.region, "space_signature", None),
        )
        if tuned is None:
            # raced a local demotion, or the service final was searched
            # under a different emitted space: search normally
            return False
        # mirror _build_state's cache-hit path: select, mark, re-rank
        state.region.select(tuned)
        state.from_cache = True
        # fleet-adoption provenance for the explain report: this class is
        # running a winner another host searched, not a local result
        job.op.db.record_event(
            state.bp, "adopted_from_service",
            fingerprint=resp["fingerprint"], found=str(resp["found"]),
        )
        from repro.core.tuner import RuntimeSelector

        state.selector = RuntimeSelector(
            state.region, state.bp, job.op.db,
            tolerance=job.op.tolerance, window=job.op.window,
        )
        self.pulled_labels.append(job.label)
        return True

    def _push_to_service(self, job: TuneJob, fp: str) -> None:
        """After a successful local search, publish the winner fleet-wide."""
        if self.service is not None:
            self.service.try_push(job.op.db, [fp])

    def _run_retune(self, job: TuneJob) -> None:
        winner: Optional[dict] = None
        try:
            winner = job.op.retune_state(job.state, job.args, job.kwargs)
        except BaseException as e:
            self.errors.append((job.label, e))
        if job.on_winner is not None:
            try:  # None signals a failed re-tune to the drift monitor
                job.on_winner(winner)
            except BaseException as e:
                self.errors.append((job.label, e))
