"""Seeded chaos harness for the streaming engine (docs/serving.md).

PR 7 made the fleet *transport* failure modes deterministic CI tests with
:class:`~repro.fleet.transport.FaultInjectionTransport`; this module applies
the same design one layer down, to the serve engine itself.  One seeded
``random.Random`` drives every injection, so a given ``(seed, call
sequence)`` replays exactly — the engine's overload and failure paths
(deadline expiry, KV-block preemption, load shedding, per-request fault
isolation) are exercised in CI with zero real networking, zero real sleeps,
and zero flaky randomness.

Injection points, mirroring the transport injector's fault menu:

* **step faults** (``step_fault_rate``) — :meth:`before_step` raises a
  transient :class:`ChaosError` before a prefill/decode step, simulating a
  kernel-step exception (an XLA launch failure, an OOM, a NaN guard).  The
  hardened engine retries the step one request at a time, so a transient
  fault costs a retry, never a request.
* **poisoned requests** (``poison_rids``) — any step containing a poisoned
  rid raises *deterministically*, simulating a request whose data reliably
  kills the kernel.  Isolation pins the blame: only the poisoned request
  retires with ``error`` status.
* **block-pool pressure** (``squeeze_rate``/``squeeze_hold``) — :meth:`tick`
  allocates pool blocks under sentinel rids and holds them for a bounded
  number of scheduler iterations, shrinking the free list under the live
  engine.  This forces the admission bound, :class:`KVPoolExhausted`
  handling, and priority preemption paths that a right-sized pool never
  reaches.
* **virtual delays** (``delay_rate``/``delay_s``) — :meth:`step_delay`
  returns extra *virtual* seconds to add to a step's measured wall time, so
  deadline expiry is reachable deterministically on the virtual clock
  (real steps on a smoke config are far faster than any realistic TTL).

Malformed requests and pathological arrival bursts are trace-level faults:
:func:`repro.data.pipeline.adversarial_trace` layers them over the bursty
open-loop trace from the same kind of seeded RNG.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Iterable, List, Sequence, Tuple


class ChaosError(RuntimeError):
    """An injected kernel-step failure.

    ``rids`` names the requests the fault is pinned to (poisoned requests);
    empty for transient faults, which blame nobody and pass on retry.
    """

    def __init__(self, message: str, rids: Sequence[int] = ()) -> None:
        super().__init__(message)
        self.rids: Tuple[int, ...] = tuple(rids)


@dataclass
class ChaosStats:
    """What the injector actually did — asserted by tests and benchmarks."""

    steps_seen: int = 0
    transient_faults: int = 0
    poison_faults: int = 0
    blocks_squeezed: int = 0
    blocks_released: int = 0
    delays: int = 0
    delay_s: float = 0.0
    ticks: int = 0

    @property
    def faults(self) -> int:
        return self.transient_faults + self.poison_faults

    def as_metrics(self) -> dict:
        """Flat numeric snapshot for the metrics registry
        (:func:`repro.obs.metrics.snapshot_stats` protocol)."""
        return {
            "steps_seen": self.steps_seen,
            "transient_faults": self.transient_faults,
            "poison_faults": self.poison_faults,
            "faults": self.faults,
            "blocks_squeezed": self.blocks_squeezed,
            "blocks_released": self.blocks_released,
            "delays": self.delays,
            "delay_s": self.delay_s,
            "ticks": self.ticks,
        }


class ChaosInjector:
    """Deterministic seeded fault injection around a StreamingEngine.

    The engine calls :meth:`tick` once per scheduler iteration (pool
    pressure evolves on iteration count, so a stalled engine still sees its
    stolen blocks come back), :meth:`before_step` immediately before each
    prefill/decode execution, and :meth:`step_delay` after each measured
    step.  All decisions come from one ``random.Random(seed)``.
    """

    # sentinel rids for squeezed blocks: disjoint from any real request rid
    _SQUEEZE_BASE = -1_000_000

    def __init__(
        self,
        seed: int = 0,
        step_fault_rate: float = 0.0,
        poison_rids: Iterable[int] = (),
        squeeze_rate: float = 0.0,
        squeeze_hold: int = 4,
        delay_rate: float = 0.0,
        delay_s: float = 0.02,
    ) -> None:
        self.step_fault_rate = float(step_fault_rate)
        self.poison_rids = frozenset(int(r) for r in poison_rids)
        self.squeeze_rate = float(squeeze_rate)
        self.squeeze_hold = int(squeeze_hold)
        self.delay_rate = float(delay_rate)
        self.delay_amount_s = float(delay_s)
        self._rng = random.Random(seed)
        self._seq = 0
        # (release_at_tick, sentinel_rid) for blocks currently held
        self._held: List[Tuple[int, int]] = []
        self.stats = ChaosStats()

    # -- engine hooks --------------------------------------------------------

    def before_step(self, kind: str, rids: Sequence[int]) -> None:
        """Maybe raise before a prefill/decode step.

        Poisoned rids raise deterministically (every time, so isolation can
        pin them); otherwise the seeded RNG draws one transient fault per
        step at ``step_fault_rate``.
        """
        self.stats.steps_seen += 1
        poisoned = sorted(self.poison_rids.intersection(int(r) for r in rids))
        if poisoned:
            self.stats.poison_faults += 1
            raise ChaosError(
                f"injected poison fault in {kind} step (rids {poisoned})",
                rids=poisoned,
            )
        if self.step_fault_rate and self._rng.random() < self.step_fault_rate:
            self.stats.transient_faults += 1
            raise ChaosError(f"injected transient fault in {kind} step")

    def step_delay(self) -> float:
        """Extra virtual seconds to charge the step that just ran."""
        if self.delay_rate and self._rng.random() < self.delay_rate:
            self.stats.delays += 1
            self.stats.delay_s += self.delay_amount_s
            return self.delay_amount_s
        return 0.0

    def tick(self, cache: Any) -> None:
        """Once per scheduler iteration: evolve block-pool pressure.

        Releases held blocks whose hold expired, then maybe squeezes a new
        one.  ``cache`` is the engine's :class:`PagedKVCache`; squeezed
        blocks go through its normal allocate/release bookkeeping under
        sentinel rids, so the engine's own invariants (free-list accounting,
        idempotent release) cover them too.
        """
        self.stats.ticks += 1
        still_held = []
        for release_at, rid in self._held:
            if self.stats.ticks >= release_at:
                cache.release(rid)
                self.stats.blocks_released += 1
            else:
                still_held.append((release_at, rid))
        self._held = still_held
        if (
            self.squeeze_rate
            and cache.free > 0
            and self._rng.random() < self.squeeze_rate
        ):
            self._seq += 1
            rid = self._SQUEEZE_BASE - self._seq
            cache.allocate(rid)
            self._held.append((self.stats.ticks + self.squeeze_hold, rid))
            self.stats.blocks_squeezed += 1

    def drain(self, cache: Any) -> None:
        """Release every still-held block (end of a serve run)."""
        for _, rid in self._held:
            cache.release(rid)
            self.stats.blocks_released += 1
        self._held = []

    @property
    def holding(self) -> int:
        return len(self._held)
