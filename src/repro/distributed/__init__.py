"""Distribution layer: meshes, logical-axis sharding rules, collective knobs."""
from .sharding import (
    ShardingRule,
    RULES,
    activation_sharding,
    constrain,
    current_rule,
    logical_to_spec,
    mesh_bp_entries,
    mesh_fingerprint,
    opt_state_sharding,
    param_sharding,
    spec_for,
    zero_spec,
)

__all__ = [
    "ShardingRule",
    "RULES",
    "activation_sharding",
    "constrain",
    "current_rule",
    "logical_to_spec",
    "mesh_bp_entries",
    "mesh_fingerprint",
    "opt_state_sharding",
    "param_sharding",
    "spec_for",
    "zero_spec",
]
