"""Logical-axis sharding: rules are data, and rule choice is a tunable PP.

Every parameter (:class:`repro.models.spec.ParamSpec`) and the key
activations carry *logical* axis names.  A :class:`ShardingRule` maps logical
names to mesh axes; applying a rule yields ``PartitionSpec`` s.  Because the
rule is an ordinary value, the before-execution tuner searches over rules the
same way the paper searches over loop variants — sharding layout is our
"directive position" at the distributed level (docs/design.md §2).

Divisibility guard: a dimension is only sharded if its size divides the mesh
axis product; otherwise that axis silently stays replicated (e.g. 8 KV heads
on a 16-way model axis).  This mirrors OpenMP threads idling when the loop is
shorter than the team.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisTarget = Union[None, str, Tuple[str, ...]]


def is_spec_leaf(x: Any) -> bool:
    """Duck-typed ParamSpec check (avoids a circular import with
    repro.models, whose layer modules import ``constrain`` from here)."""
    return hasattr(x, "shape") and hasattr(x, "logical_axes")


@dataclass(frozen=True)
class ShardingRule:
    """logical axis name -> mesh axis (or tuple of axes, or None)."""

    name: str
    mapping: Tuple[Tuple[str, AxisTarget], ...]

    @classmethod
    def make(cls, name: str, **mapping: AxisTarget) -> "ShardingRule":
        return cls(name, tuple(sorted(mapping.items())))

    def target(self, logical: Optional[str]) -> AxisTarget:
        if logical is None:
            return None
        return dict(self.mapping).get(logical)

    def asdict(self) -> Dict[str, AxisTarget]:
        return dict(self.mapping)


def _mesh_axis_size(mesh: Mesh, target: AxisTarget) -> int:
    if target is None:
        return 1
    if isinstance(target, str):
        return mesh.shape[target]
    n = 1
    for t in target:
        n *= mesh.shape[t]
    return n


def logical_to_spec(
    rule: ShardingRule,
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
) -> P:
    """PartitionSpec for one array, with divisibility guard per axis."""
    entries = []
    used: set = set()
    for size, logical in zip(shape, logical_axes):
        target = rule.target(logical)
        if target is None:
            entries.append(None)
            continue
        axes = (target,) if isinstance(target, str) else tuple(target)
        # drop axes absent from this mesh (e.g. "pod" on the single-pod mesh)
        # or already consumed by an earlier dim of this array
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        if not axes:
            entries.append(None)
            continue
        if size % _mesh_axis_size(mesh, axes) != 0:
            entries.append(None)  # replicate: "idle threads"
            continue
        used.update(axes)
        entries.append(axes[0] if len(axes) == 1 else axes)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_sharding(rule: ShardingRule, spec_tree: Any, mesh: Mesh) -> Any:
    """NamedShardings for a whole ParamSpec pytree."""

    def one(s) -> NamedSharding:
        return NamedSharding(mesh, logical_to_spec(rule, s.shape, s.logical_axes, mesh))

    return jax.tree.map(one, spec_tree, is_leaf=is_spec_leaf)


def spec_for(
    rule: ShardingRule,
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(rule, shape, logical_axes, mesh))


# ---------------------------------------------------------------------------
# Activation-sharding context (used by model code via `constrain`)
# ---------------------------------------------------------------------------

_ACTIVE: ContextVar[Optional[Tuple[Mesh, ShardingRule]]] = ContextVar(
    "repro_active_sharding", default=None
)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rule: ShardingRule):
    token = _ACTIVE.set((mesh, rule))
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def current_rule() -> Optional[ShardingRule]:
    ctx = _ACTIVE.get()
    return ctx[1] if ctx else None


def mesh_fingerprint(mesh: Optional[Mesh]) -> str:
    """Canonical string for a mesh factorization, e.g. ``"data2xmodel4"``.

    ``"host"`` when no mesh is given (single-host, unsharded serving).
    """
    if mesh is None:
        return "host"
    return "x".join(f"{a}{n}" for a, n in mesh.shape.items())


def mesh_bp_entries(mesh: Optional[Mesh] = None) -> Dict[str, str]:
    """BP entries keying tuned results to the mesh shape.

    A tuned winner is only valid under the factorization it was measured on
    — resharding from (data=16, model=16) to (data=32, model=8) changes
    collective paths and per-shard shapes, so each factorization gets its
    own TuningDB entries instead of silently reusing a stale winner.  When
    ``mesh`` is omitted, the mesh from the active :func:`activation_sharding`
    context (if any) is used.
    """
    if mesh is None:
        ctx = _ACTIVE.get()
        mesh = ctx[0] if ctx else None
    return {"mesh": mesh_fingerprint(mesh)}


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """`with_sharding_constraint` keyed by logical names; no-op outside a
    :func:`activation_sharding` context (so model code runs unsharded on CPU
    smoke tests unchanged)."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    mesh, rule = ctx
    if len(logical_axes) != x.ndim:
        raise ValueError(f"constrain: {logical_axes} vs rank {x.ndim}")
    spec = logical_to_spec(rule, x.shape, logical_axes, mesh)
    return lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# ZeRO-1 optimizer-state sharding
# ---------------------------------------------------------------------------


def zero_spec(
    rule: ShardingRule,
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    zero_axes: Tuple[str, ...] = ("data",),
) -> P:
    """Param spec + additionally shard the largest unsharded dim over
    ``zero_axes`` (ZeRO-1: optimizer state scattered over data parallels)."""
    base = logical_to_spec(rule, shape, logical_axes, mesh)
    entries = list(base) + [None] * (len(shape) - len(base))
    free = [a for a in zero_axes if mesh.shape.get(a, 1) > 1 and not _axis_used(entries, a)]
    if not free:
        return base
    zsize = int(np.prod([mesh.shape[a] for a in free]))
    # largest unsharded, divisible dim
    cand = [
        (shape[i], i)
        for i in range(len(shape))
        if entries[i] is None and shape[i] % zsize == 0 and shape[i] >= zsize
    ]
    if not cand:
        return base
    _, dim = max(cand)
    entries[dim] = free[0] if len(free) == 1 else tuple(free)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _axis_used(entries, axis: str) -> bool:
    for e in entries:
        if e == axis:
            return True
        if isinstance(e, tuple) and axis in e:
            return True
    return False


def opt_state_sharding(
    rule: ShardingRule,
    opt_spec_tree: Any,
    mesh: Mesh,
    zero_axes: Tuple[str, ...] = ("data",),
) -> Any:
    """NamedShardings for the optimizer-state spec tree (ZeRO-1)."""

    def one(s) -> NamedSharding:
        return NamedSharding(
            mesh, zero_spec(rule, s.shape, s.logical_axes, mesh, zero_axes)
        )

    return jax.tree.map(one, opt_spec_tree, is_leaf=is_spec_leaf)


# ---------------------------------------------------------------------------
# The candidate rule set (PP domain at the distributed level)
# ---------------------------------------------------------------------------

# Axis name conventions: mesh axes are "pod", "data", "model" (mesh.py);
# logical names are the ParamSpec vocabulary + activation names
# ("batch", "seq", "act_embed", "act_ffn", "act_heads", "act_kv", "act_vocab",
#  "act_experts", "act_rnn").

def _base(name: str, **over: AxisTarget) -> ShardingRule:
    mapping: Dict[str, AxisTarget] = {
        # params
        "vocab": "model",
        "embed": None,
        "embed_table": None,
        "q_heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "experts": "model",
        "rnn": "model",
        "state": None,
        "conv": None,
        "layers": None,
        "frames": None,
        # activations
        "batch": ("pod", "data"),
        "seq": None,
        "act_embed": None,
        "act_ffn": "model",
        "act_heads": "model",
        "act_kv": "model",
        "act_vocab": "model",
        "act_experts": "model",
        "act_rnn": "model",
        "kv_slots": None,
        "moe_capacity": None,
    }
    mapping.update(over)
    return ShardingRule.make(name, **mapping)


RULES: Dict[str, ShardingRule] = {
    # Pure tensor parallel on `model`, pure data parallel on `pod`+`data`.
    "tp": _base("tp"),
    # ZeRO-3/FSDP-style: weights additionally sharded over `data` on their
    # embed axis; XLA inserts all-gathers at use and reduce-scatters on grads.
    "fsdp_tp": _base("fsdp_tp", embed="data"),
    # FSDP over both data axes (multi-pod weight sharding; DCN all-gathers).
    "fsdp2_tp": _base("fsdp2_tp", embed=("pod", "data")),
    # Sequence parallelism for activations (long prefill): tokens sharded on
    # `model` along seq between attention/FFN regions.
    "tp_seq": _base("tp_seq", seq="model"),
    # Flash-decoding: the KV cache length dim sharded over `model` (softmax
    # over a sharded axis -> XLA inserts max/sum all-reduces).  The decode
    # answer when kv_heads < model-axis size (all 10 assigned archs).
    "tp_kvseq": _base("tp_kvseq", kv_slots="model"),
    # Expert parallel with data-sharded dispatch capacity: the MoE (E, C, d)
    # buffer partitions over (experts->model, capacity->data), turning the
    # dispatch into an all-to-all instead of a replicated all-reduce.
    "tp_ep": _base("tp_ep", moe_capacity=("pod", "data")),
    "fsdp_tp_ep": _base("fsdp_tp_ep", embed="data", moe_capacity=("pod", "data")),
}
