"""AdamW with warmup+cosine schedule, global-norm clipping, and two
distributed-optimization PPs:

* ``moment_dtype`` — fp32 (default) or bf16 second moments ("gradient
  compression" family; halves optimizer HBM, the fix that lets llama3-405b
  train_4k approach one pod, docs/design.md §6),
* ZeRO-1 state sharding is *not* done here — it is purely a sharding-rule
  concern (:func:`repro.distributed.sharding.opt_state_sharding`); the math
  below is sharding-oblivious, pjit moves the bytes.

Pure functions only; state is a pytree {m, v, count} matching params.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec, is_spec_leaf


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"  # "float32" | "bfloat16" (compression PP)


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = cfg.lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_init_specs(spec_tree: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    """Optimizer-state *specs* (for the dry-run: shapes, logical axes)."""
    mdt = jnp.dtype(cfg.moment_dtype)

    def one(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.logical_axes, dtype=mdt, init="zeros")

    tree = jax.tree.map(one, spec_tree, is_leaf=is_spec_leaf)
    return {
        "m": tree,
        "v": tree,
        "count": ParamSpec((), (), dtype=jnp.int32, init="zeros"),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(
    grads: Any,
    opt_state: Dict[str, Any],
    params: Any,
    cfg: AdamWConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step.  Returns (params, opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = lr_at(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    mdt = jnp.dtype(cfg.moment_dtype)

    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1**c
    bc2 = 1.0 - b2**c

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step_ + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b_, cc = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b_)
        new_v.append(cc)

    metrics = {"grad_norm": gnorm, "lr": lr}
    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "m": jax.tree.unflatten(treedef, new_m),
            "v": jax.tree.unflatten(treedef, new_v),
            "count": count,
        },
        metrics,
    )
