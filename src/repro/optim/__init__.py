from .adamw import (
    AdamWConfig,
    adamw_init,
    adamw_init_specs,
    adamw_update,
    global_norm,
    lr_at,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_init_specs",
    "adamw_update",
    "global_norm",
    "lr_at",
]
