"""llama4-scout-17b-a16e — MoE 16 experts top-1 [hf:meta-llama/Llama-4-Scout-17B-16E].

Text backbone only (the early-fusion image frontend is out of scope for the
LM shape cells; docs/design.md §4)."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    n_experts=16,
    top_k=1,
    rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    n_experts=4,
    top_k=1,
)
