"""granite-moe-1b-a400m — MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    top_k=8,
    rope_theta=10000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=48,
    vocab_size=256,
    n_experts=8,
    top_k=2,
    tie_embeddings=True,
)
