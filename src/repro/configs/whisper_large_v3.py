"""whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

Conv/mel frontend is a STUB: input_specs supplies (B, 1500, d_model) frame
embeddings.  32 encoder + 32 decoder layers, MHA (kv == heads), GELU MLP,
tied embeddings.  Assigned seq lengths apply to the decoder side."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    is_encoder_decoder=True,
    n_encoder_layers=32,
    encoder_len=1500,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-large-v3-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    is_encoder_decoder=True,
    n_encoder_layers=2,
    encoder_len=24,
    tie_embeddings=True,
)
