"""qwen2.5-32b — dense GQA with QKV bias [hf:Qwen/Qwen2.5-32B]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen2.5-32b-smoke",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=384,
    qkv_bias=True,
)
