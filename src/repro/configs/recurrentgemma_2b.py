"""recurrentgemma-2b — RG-LRU + local attention, 2:1 pattern [arXiv:2402.19427].

26 layers = 8 × (rec, rec, attn) + (rec, rec) tail.  MQA (kv=1) with
head_dim 256, sliding window 2048.  Sub-quadratic: long_500k runs."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    local_window=2048,
    rope_theta=10000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    n_layers=5,  # 1 full group + (rec, rec) tail — exercises both paths
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=192,
    vocab_size=256,
    head_dim=16,
    block_pattern=("rec", "rec", "attn"),
    lru_width=64,
    local_window=16,
    tie_embeddings=True,
)
