"""Assigned-architecture registry: 10 archs × 4 input shapes = 40 cells.

Every arch module exports ``FULL`` (the exact published config) and ``SMOKE``
(a reduced same-family config for CPU tests).  Shape cells follow the
assignment; skip rules (docs/design.md §4): ``long_500k`` only for sub-quadratic
families (ssm, hybrid).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

from . import (
    falcon_mamba_7b,
    granite_moe_1b_a400m,
    llama3_405b,
    llama4_scout_17b_a16e,
    qwen2_5_32b,
    qwen2_vl_2b,
    qwen3_0_6b,
    recurrentgemma_2b,
    tinyllama_1_1b,
    whisper_large_v3,
)

_MODULES = {
    "llama3-405b": llama3_405b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "qwen2.5-32b": qwen2_5_32b,
    "qwen3-0.6b": qwen3_0_6b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "whisper-large-v3": whisper_large_v3,
    "recurrentgemma-2b": recurrentgemma_2b,
    "falcon-mamba-7b": falcon_mamba_7b,
    "qwen2-vl-2b": qwen2_vl_2b,
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = _MODULES[arch]
    return mod.SMOKE if smoke else mod.FULL


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cells_for(arch: str) -> List[ShapeCell]:
    """The runnable shape cells for an arch, applying the skip rules."""
    cfg = get_config(arch)
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        cells.append(SHAPES["long_500k"])
    return cells


def all_cells() -> List[Tuple[str, ShapeCell]]:
    return [(arch, cell) for arch in ARCH_IDS for cell in cells_for(arch)]


def skipped_cells() -> List[Tuple[str, str, str]]:
    """(arch, shape, reason) for every assigned-but-skipped cell."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if not cfg.sub_quadratic:
            out.append(
                (arch, "long_500k", "pure full attention (needs sub-quadratic)")
            )
    return out
