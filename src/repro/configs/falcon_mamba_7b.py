"""falcon-mamba-7b — attention-free Mamba-1 [arXiv:2410.05355].

64 layers, d_model 4096, expand 2 (d_inner 8192), ssm_state 16, conv 4.
Sub-quadratic: long_500k runs.  n_heads/n_kv_heads are unused placeholders
(family=ssm has no attention)."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    d_conv=4,
    expand=2,
)

SMOKE = ModelConfig(
    name="falcon-mamba-7b-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=256,
    ssm_state=4,
    d_conv=4,
    expand=2,
)
