"""qwen2-vl-2b — VLM backbone with M-RoPE [arXiv:2409.12191].

Vision frontend is a STUB: input_specs supplies (B, 256, d_model) patch
embeddings occupying the first 256 positions, plus (3, B, S) M-RoPE position
ids (temporal/height/width)."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    mrope_sections=(16, 24, 24),
    n_vision_tokens=256,
    rope_theta=1000000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2-vl-2b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=32,
    mrope=True,
    mrope_sections=(4, 6, 6),
    n_vision_tokens=8,
    tie_embeddings=True,
)
