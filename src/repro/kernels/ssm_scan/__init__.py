from . import ops, ref
from .ssm_scan import ssm_scan, vmem_bytes
