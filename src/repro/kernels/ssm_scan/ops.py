"""Jitted wrapper + AT region for the selective-scan Pallas kernel."""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax

from repro.core import ATRegion, BasicParams, KernelSpec, ParamSpace, PerfParam, register_kernel
from repro.core.cost import roofline_prescreen

from .ref import ssm_scan_ref
from .ssm_scan import ssm_scan, vmem_bytes


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def scan(x, dt, A, Bc, Cc, D, block_d: int = 512, chunk: int = 128,
         interpret: bool = True):
    return ssm_scan(x, dt, A, Bc, Cc, D, block_d=block_d, chunk=chunk,
                    interpret=interpret)


def ssm_region(
    d_inner: int, seq_len: int, n_state: int, vmem_budget: int = 16 * 2**20
) -> ATRegion:
    d_blocks = tuple(
        b for b in (128, 256, 512, 1024, 2048) if b <= d_inner and d_inner % b == 0
    ) or (d_inner,)
    chunks = tuple(
        c for c in (32, 64, 128, 256, 512) if c <= seq_len and seq_len % c == 0
    ) or (seq_len,)
    space = ParamSpace(
        [PerfParam("block_d", d_blocks), PerfParam("chunk", chunks)],
        constraint=lambda p: vmem_bytes(p["block_d"], p["chunk"], n_state)
        <= vmem_budget,
    )

    def instantiate(point: Mapping[str, Any]):
        bd, ck = point["block_d"], point["chunk"]
        return lambda x, dt, A, Bc, Cc, D: scan(x, dt, A, Bc, Cc, D,
                                                block_d=bd, chunk=ck)

    return ATRegion("ssm_scan_pallas", space, instantiate, oracle=ssm_scan_ref)


def shape_class(x, dt, A, Bc, Cc, D) -> BasicParams:
    """(d_inner, seq, n_state) fix the candidate family; batch is dropped."""
    return BasicParams.make(
        kernel="ssm_scan",
        d_inner=int(x.shape[-1]),
        seq=int(x.shape[1]),
        n_state=int(A.shape[-1]),
        dtype=str(x.dtype),
        backend=jax.default_backend(),
    )


register_kernel(
    KernelSpec(
        "ssm_scan",
        make_region=lambda bp: ssm_region(bp["d_inner"], bp["seq"], bp["n_state"]),
        shape_class=shape_class,
        prescreen_factory=roofline_prescreen,
        tags=("pallas",),
    ),
    replace=True,
)
