"""Jitted wrapper + AT region for the selective-scan Pallas kernel."""
from __future__ import annotations

import functools
from typing import Any, Mapping, Optional, Sequence

import jax

from repro.core import ATRegion, BasicParams, KernelSpec, register_kernel
from repro.core.arch import ArchSpec, default_interpret, local_arch
from repro.core.emit import TileDim, TilePolicy, hint_prescreen

from .ref import ssm_scan_ref
from .ssm_scan import ssm_scan, vmem_bytes


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def scan(x, dt, A, Bc, Cc, D, block_d: int = 512, chunk: int = 128,
         interpret: Optional[bool] = None):
    if interpret is None:
        interpret = default_interpret()
    return ssm_scan(x, dt, A, Bc, Cc, D, block_d=block_d, chunk=chunk,
                    interpret=interpret)


def _traffic(bp: Mapping[str, Any], point: Mapping[str, Any]):
    s, d, n = bp["seq"], bp["d_inner"], bp["n_state"]
    flops = 12.0 * s * d * n
    bytes_ = (3.0 * s * d + d * n + 2.0 * s * n) * 4
    return flops, bytes_


SSM_POLICY = TilePolicy(
    kernel="ssm_scan",
    dims=lambda bp: (
        TileDim("block_d", bp["d_inner"], semantic="lane"),
        TileDim("chunk", bp["seq"], semantic="sequential"),
    ),
    vmem_model=lambda bp, p: vmem_bytes(p["block_d"], p["chunk"], bp["n_state"]),
    traffic_model=_traffic,
)


def ssm_region(
    d_inner: int, seq_len: int, n_state: int,
    vmem_budget: Optional[int] = None, arch: Optional[ArchSpec] = None,
    pinned: Sequence[Mapping[str, Any]] = (),
) -> ATRegion:
    arch = arch or local_arch()
    emitted = SSM_POLICY.emit(
        arch, {"d_inner": d_inner, "seq": seq_len, "n_state": n_state},
        pinned=pinned, vmem_budget=vmem_budget,
    )

    def instantiate(point: Mapping[str, Any]):
        bd, ck = point["block_d"], point["chunk"]
        return lambda x, dt, A, Bc, Cc, D: scan(x, dt, A, Bc, Cc, D,
                                                block_d=bd, chunk=ck)

    return ATRegion(
        "ssm_scan_pallas", emitted.space, instantiate, oracle=ssm_scan_ref,
        space_signature=emitted.signature, hints=emitted.hints, arch=arch,
    )


def shape_class(x, dt, A, Bc, Cc, D) -> BasicParams:
    """(d_inner, seq, n_state) fix the candidate family; batch is dropped."""
    return BasicParams.make(
        kernel="ssm_scan",
        d_inner=int(x.shape[-1]),
        seq=int(x.shape[1]),
        n_state=int(A.shape[-1]),
        dtype=str(x.dtype),
        backend=jax.default_backend(),
    )


register_kernel(
    KernelSpec(
        "ssm_scan",
        make_region=lambda bp: ssm_region(bp["d_inner"], bp["seq"], bp["n_state"]),
        shape_class=shape_class,
        prescreen_factory=hint_prescreen,
        tags=("pallas",),
    ),
    replace=True,
)
