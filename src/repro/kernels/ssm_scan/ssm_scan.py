"""Pallas TPU kernel for the Mamba selective scan — chunked recurrence.

TPU adaptation of the CUDA selective-scan kernel: instead of one thread
block per (batch, channel-tile) with warp-level parallel prefix (a GPU
shared-memory pattern), we use the *sequential-grid carry* idiom: grid
(B, d-blocks, chunks), the h-state lives in VMEM scratch and persists
across the chunk dimension (the fastest-varying one).  Inside a chunk a
``fori_loop`` steps the recurrence with everything VMEM-resident — the
(S, D, N) decay tensor never exists anywhere, in any memory.

Tunables: (block_d, chunk) — channel tile width and temporal chunk length.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_kernel(
    x_ref,   # (1, chunk, bd)
    dt_ref,  # (1, chunk, bd)
    b_ref,   # (1, chunk, N)
    c_ref,   # (1, chunk, N)
    a_ref,   # (bd, N)
    d_ref,   # (bd,)
    y_ref,   # (1, chunk, bd)
    h_ref,   # scratch (bd, N) fp32
    *,
    chunk: int,
):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...]  # (bd, N)

    def step(t, h):
        x_t = x_ref[0, t, :].astype(jnp.float32)   # (bd,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # (bd,)
        B_t = b_ref[0, t, :].astype(jnp.float32)   # (N,)
        C_t = c_ref[0, t, :].astype(jnp.float32)   # (N,)
        decay = jnp.exp(dt_t[:, None] * A)         # (bd, N)
        h = decay * h + (dt_t * x_t)[:, None] * B_t[None, :]
        y = jnp.sum(h * C_t[None, :], axis=-1)     # (bd,)
        y = y + x_t * d_ref[...]
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h


def ssm_scan(
    x: jnp.ndarray,   # (B, S, D)
    dt: jnp.ndarray,  # (B, S, D)
    A: jnp.ndarray,   # (D, N)
    Bc: jnp.ndarray,  # (B, S, N)
    Cc: jnp.ndarray,  # (B, S, N)
    D: jnp.ndarray,   # (D,)
    block_d: int = 512,
    chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    Bsz, S, Dd = x.shape
    N = A.shape[1]
    bd = min(block_d, Dd)
    ck = min(chunk, S)
    if Dd % bd or S % ck:
        raise ValueError(f"blocks ({bd},{ck}) must divide (D={Dd}, S={S})")
    grid = (Bsz, Dd // bd, S // ck)

    xd_spec = pl.BlockSpec((1, ck, bd), lambda b, d, c: (b, c, d))
    bn_spec = pl.BlockSpec((1, ck, N), lambda b, d, c: (b, c, 0))
    a_spec = pl.BlockSpec((bd, N), lambda b, d, c: (d, 0))
    dd_spec = pl.BlockSpec((bd,), lambda b, d, c: (d,))

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(_ssm_kernel, chunk=ck)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[xd_spec, xd_spec, bn_spec, bn_spec, a_spec, dd_spec],
        out_specs=xd_spec,
        out_shape=jax.ShapeDtypeStruct((Bsz, S, Dd), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bc, Cc, A, D)


def vmem_bytes(block_d: int, chunk: int, n_state: int) -> int:
    pad = lambda n: -(-n // 128) * 128
    io = 3 * chunk * pad(block_d) * 4  # x, dt, y
    bn = 2 * chunk * pad(n_state) * 4
    state = block_d * pad(n_state) * 4 * 2  # A + h scratch
    return io + bn + state
