"""Pure-jnp oracle for the Mamba selective-scan kernel.

Recurrence over already-projected per-step quantities (the kernel consumes
dt, B, C post-projection — the projections are plain matmuls XLA handles):

    h_t = exp(dt_t ⊗ A) ⊙ h_{t-1} + (dt_t · x_t) ⊗ B_t
    y_t = h_t · C_t + D ⊙ x_t
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def ssm_scan_ref(
    x: jnp.ndarray,   # (B, S, D)   post-conv, post-silu activations
    dt: jnp.ndarray,  # (B, S, D)   softplus'd step sizes
    A: jnp.ndarray,   # (D, N)      negative decay rates
    Bc: jnp.ndarray,  # (B, S, N)
    Cc: jnp.ndarray,  # (B, S, N)
    D: jnp.ndarray,   # (D,)
) -> jnp.ndarray:
    Bsz, S, Dd = x.shape
    N = A.shape[1]

    def step(h, inputs):
        x_t, dt_t, B_t, C_t = inputs
        decay = jnp.exp(dt_t[..., None] * A)  # (B, D, N)
        h = decay * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    h0 = jnp.zeros((Bsz, Dd, N), jnp.float32)
    xs = (
        x.transpose(1, 0, 2).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        Bc.transpose(1, 0, 2).astype(jnp.float32),
        Cc.transpose(1, 0, 2).astype(jnp.float32),
    )
    _, ys = lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2)  # (B, S, D)
    return (y + x.astype(jnp.float32) * D).astype(x.dtype)


def make_inputs(key, B=2, S=64, D=32, N=8):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, D), jnp.float32) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (D, N), jnp.float32) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cc = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    Dp = jax.random.normal(ks[5], (D,), jnp.float32)
    return x, dt, A, Bc, Cc, Dp
