"""Pallas TPU kernels for the compute hot spots (validated with
interpret=True on this CPU host; BlockSpec tiling targets TPU v5e VMEM).

* exb             — GKV exb_realspcal (the paper's §III tuning target)
* stress          — Seism3D update_stress (the paper's §IV target)
* flash_attention — causal GQA flash attention, VMEM-resident scores
* ssm_scan        — Mamba-1 selective scan, sequential-grid carry
* rglru_scan      — RG-LRU recurrence, sequential-grid carry

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper +
AT region over block shapes), ref.py (pure-jnp oracle).
"""

# Importing the subpackages registers each kernel's KernelSpec with the
# process-wide registry (repro.core.registry), which also lazy-imports this
# module on a name miss — so `autotuned("ssm_scan")` works either way.
from . import exb, flash_attention, rglru_scan, ssm_scan, stress  # noqa: E402,F401
