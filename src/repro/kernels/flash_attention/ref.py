"""Pure-jnp oracle for the flash attention Pallas kernel: materialized
causal GQA attention (identical math to repro.models.attention.full_attention,
duplicated here so the kernel package is self-contained)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, S, KV, hd)
    v: jnp.ndarray,
    causal: bool = True,
) -> jnp.ndarray:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None, None], s, -0.7 * jnp.finfo(jnp.float32).max)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return o.reshape(B, S, H, hd)
