from . import ops, ref
from .flash_attention import flash_attention, vmem_bytes
