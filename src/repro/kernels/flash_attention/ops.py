"""Jitted wrapper + AT region for the flash attention Pallas kernel."""
from __future__ import annotations

import functools
from typing import Any, Mapping, Optional, Sequence

import jax

from repro.core import ATRegion, BasicParams, KernelSpec, register_kernel
from repro.core.arch import ArchSpec, default_interpret, local_arch
from repro.core.emit import TileDim, TilePolicy, hint_prescreen

from .flash_attention import flash_attention, vmem_bytes
from .ref import attention_ref


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_kv", "causal", "interpret")
)
def attention(q, k, v, block_q: int = 512, block_kv: int = 512,
              causal: bool = True, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = default_interpret()
    return flash_attention(
        q, k, v, block_q=block_q, block_kv=block_kv, causal=causal,
        interpret=interpret,
    )


def _traffic(bp: Mapping[str, Any], point: Mapping[str, Any]):
    """(flops, bytes) of one call per (batch, head) — ranking only."""
    s, hd = bp["seq"], bp["hd"]
    flops = 4.0 * s * s * hd           # QK^T + PV, 2 flops per MAC
    bytes_ = 4.0 * s * hd * 4          # q, k, v, o at f32
    return flops, bytes_


FLASH_POLICY = TilePolicy(
    kernel="flash_attention",
    # both block dims feed the MXU in the scores dot, so they ladder from
    # the MXU/lane edge; padding is allowed — the kernel masks tail keys
    dims=lambda bp: (
        TileDim("block_q", bp["seq"], semantic="lane", allow_padding=True),
        TileDim("block_kv", bp["seq"], semantic="lane", allow_padding=True),
    ),
    vmem_model=lambda bp, p: vmem_bytes(p["block_q"], p["block_kv"], bp["hd"]),
    traffic_model=_traffic,
)


def flash_region(
    seq_len: int, head_dim: int, vmem_budget: Optional[int] = None,
    arch: Optional[ArchSpec] = None,
    pinned: Sequence[Mapping[str, Any]] = (),
) -> ATRegion:
    arch = arch or local_arch()
    emitted = FLASH_POLICY.emit(
        arch, {"seq": seq_len, "hd": head_dim},
        pinned=pinned, vmem_budget=vmem_budget,
    )

    def instantiate(point: Mapping[str, Any]):
        bq, bkv = point["block_q"], point["block_kv"]
        return lambda q, k, v: attention(q, k, v, block_q=bq, block_kv=bkv)

    return ATRegion(
        "flash_attention_pallas", emitted.space, instantiate,
        oracle=attention_ref, space_signature=emitted.signature,
        hints=emitted.hints, arch=arch,
    )


def shape_class(q, k, v) -> BasicParams:
    """Bucket a call: block candidates depend on (seq, head_dim), not on
    batch size or head counts, so those are dropped from the DB key."""
    return BasicParams.make(
        kernel="flash_attention",
        seq=int(q.shape[1]),
        hd=int(q.shape[3]),
        dtype=str(q.dtype),
        backend=jax.default_backend(),
    )


register_kernel(
    KernelSpec(
        "flash_attention",
        make_region=lambda bp: flash_region(bp["seq"], bp["hd"]),
        shape_class=shape_class,
        # staged pipeline stage 1: compile-only roofline ranking of the
        # emitted block-shape space, re-ranked with the emit-layer hints
        prescreen_factory=hint_prescreen,
        tags=("pallas",),
    ),
    replace=True,
)
