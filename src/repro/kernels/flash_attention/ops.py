"""Jitted wrapper + AT region for the flash attention Pallas kernel."""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax

from repro.core import ATRegion, BasicParams, KernelSpec, ParamSpace, PerfParam, register_kernel
from repro.core.cost import roofline_prescreen

from .flash_attention import flash_attention, vmem_bytes
from .ref import attention_ref


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_kv", "causal", "interpret")
)
def attention(q, k, v, block_q: int = 512, block_kv: int = 512,
              causal: bool = True, interpret: bool = True):
    return flash_attention(
        q, k, v, block_q=block_q, block_kv=block_kv, causal=causal,
        interpret=interpret,
    )


def flash_region(
    seq_len: int, head_dim: int, vmem_budget: int = 16 * 2**20
) -> ATRegion:
    blocks = tuple(
        b for b in (128, 256, 512, 1024, 2048) if b <= seq_len and seq_len % b == 0
    ) or (seq_len,)
    space = ParamSpace(
        [PerfParam("block_q", blocks), PerfParam("block_kv", blocks)],
        constraint=lambda p: vmem_bytes(p["block_q"], p["block_kv"], head_dim)
        <= vmem_budget,
    )

    def instantiate(point: Mapping[str, Any]):
        bq, bkv = point["block_q"], point["block_kv"]
        return lambda q, k, v: attention(q, k, v, block_q=bq, block_kv=bkv)

    return ATRegion("flash_attention_pallas", space, instantiate, oracle=attention_ref)


def shape_class(q, k, v) -> BasicParams:
    """Bucket a call: block candidates depend on (seq, head_dim), not on
    batch size or head counts, so those are dropped from the DB key."""
    return BasicParams.make(
        kernel="flash_attention",
        seq=int(q.shape[1]),
        hd=int(q.shape[3]),
        dtype=str(q.dtype),
        backend=jax.default_backend(),
    )


register_kernel(
    KernelSpec(
        "flash_attention",
        make_region=lambda bp: flash_region(bp["seq"], bp["hd"]),
        shape_class=shape_class,
        # staged pipeline stage 1: compile-only roofline ranking of the
        # block-shape space; only top-k survivors pay a measured run
        prescreen_factory=roofline_prescreen,
        tags=("pallas",),
    ),
    replace=True,
)
