"""Pallas TPU flash attention (forward) with tunable VMEM block shapes.

Grid (B, H, nq, nkv) — the last (fastest) grid dim walks KV blocks so the
online-softmax state lives in VMEM scratch across those steps (the standard
TPU flash layout: sequential grid = free accumulator carry).  Block shapes
(block_q, block_kv) are the AT knobs: q/k/v tiles must fit VMEM and the
MXU wants both ≥ 128.

GQA is handled in the index maps: the KV block index ignores the query-head
grid coordinate beyond h // G — no KV replication in HBM.

Compared to the XLA path (models.attention.flash_attention_xla), the score
block never leaves VMEM — on the tinyllama train cell the XLA path's score
round-trips are ~60 % of its memory-roofline term (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(
    q_ref,   # (1, block_q, 1, hd)
    k_ref,   # (1, block_kv, 1, hd)
    v_ref,   # (1, block_kv, 1, hd)
    o_ref,   # (1, block_q, 1, hd)
    m_ref,   # scratch (block_q,)
    l_ref,   # scratch (block_q,)
    acc_ref,  # scratch (block_q, hd)
    *,
    causal: bool,
    scale: float,
    block_q: int,
    block_kv: int,
    nkv: int,
    seq_len: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :]  # (bq, hd)
    k = k_ref[0, :, 0, :]  # (bkv, hd)
    v = v_ref[0, :, 0, :]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if nkv * block_kv > seq_len:
        # padded tail block: keys past the real sequence must not score
        col = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1) \
            + kj * block_kv
        s = jnp.where(col < seq_len, s, NEG_INF)
    if causal:
        off = qi * block_q - kj * block_kv
        mask = (
            jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0) + off
            >= jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        )
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kj == nkv - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_ref[...] / l_ref[...][:, None]
        ).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, S, KV, hd)
    v: jnp.ndarray,
    block_q: int = 512,
    block_kv: int = 512,
    causal: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq, bkv = min(block_q, S), min(block_kv, S)
    # non-dividing blocks tile past the sequence edge: pad q rows and kv
    # columns up to whole blocks (the kernel masks tail keys to NEG_INF;
    # tail query rows are garbage and sliced off below)
    nq, nkv = -(-S // bq), -(-S // bkv)
    Sq, Skv = nq * bq, nkv * bkv
    if Sq != S:
        q = jnp.pad(q, ((0, 0), (0, Sq - S), (0, 0), (0, 0)))
    if Skv != S:
        pad_kv = ((0, 0), (0, Skv - S), (0, 0), (0, 0))
        k = jnp.pad(k, pad_kv)
        v = jnp.pad(v, pad_kv)
    grid = (B, H, nq, nkv)

    q_spec = pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0))
    kv_spec = pl.BlockSpec((1, bkv, 1, hd), lambda b, h, i, j: (b, j, h // G, 0))
    o_spec = pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0))

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        scale=1.0 / math.sqrt(hd),
        block_q=bq,
        block_kv=bkv,
        nkv=nkv,
        seq_len=S,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            _scratch((bq,), jnp.float32),
            _scratch((bq,), jnp.float32),
            _scratch((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S] if Sq != S else out


def _scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def vmem_bytes(block_q: int, block_kv: int, hd: int) -> int:
    pad = lambda n: -(-n // 128) * 128
    q = block_q * pad(hd) * 2
    kv = 2 * block_kv * pad(hd) * 2
    s = block_q * pad(block_kv) * 4
    scr = block_q * 4 * 2 + block_q * pad(hd) * 4
    return q + kv + s + scr + block_q * pad(hd) * 2
