"""Pure-jnp oracle for the Seism3D update_stress kernel."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

DT = 5.0e-3

INPUT_NAMES = (
    "Sxx", "Syy", "Szz", "Sxy", "Sxz", "Syz",
    "dxVx", "dyVy", "dzVz", "dxVy", "dyVx", "dxVz", "dzVx", "dyVz", "dzVy",
    "lam", "rig",
)


def stress_ref(inp: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    rl, rm = inp["lam"], inp["rig"]
    rm2 = 2.0 * rm
    rlrm2 = rl + rm2
    d3 = inp["dxVx"] + inp["dyVy"] + inp["dzVz"]
    return {
        "Sxx": inp["Sxx"] + DT * (rlrm2 * d3 - rm2 * (inp["dyVy"] + inp["dzVz"])),
        "Syy": inp["Syy"] + DT * (rlrm2 * d3 - rm2 * (inp["dxVx"] + inp["dzVz"])),
        "Szz": inp["Szz"] + DT * (rlrm2 * d3 - rm2 * (inp["dxVx"] + inp["dyVy"])),
        "Sxy": inp["Sxy"] + DT * inp["rig"] * (inp["dxVy"] + inp["dyVx"]),
        "Sxz": inp["Sxz"] + DT * inp["rig"] * (inp["dxVz"] + inp["dzVx"]),
        "Syz": inp["Syz"] + DT * inp["rig"] * (inp["dyVz"] + inp["dzVy"]),
    }


def make_inputs(key: jax.Array, dims=(64, 64, 64)) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, len(INPUT_NAMES))
    out = {}
    for n, k in zip(INPUT_NAMES, ks):
        x = jax.random.normal(k, dims, jnp.float32)
        if n in ("lam", "rig"):
            x = 1.0 + jnp.abs(x)
        out[n] = x
    return out
