from . import ops, ref
from .stress import stress_pallas, vmem_bytes
