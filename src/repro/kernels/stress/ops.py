"""Jitted wrapper + AT region for the stress Pallas kernel."""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax

from repro.core import ATRegion, BasicParams, KernelSpec, ParamSpace, PerfParam, register_kernel
from repro.core.cost import roofline_prescreen

from .ref import stress_ref
from .stress import stress_pallas, vmem_bytes


@functools.partial(jax.jit, static_argnames=("block_k", "block_j", "interpret"))
def stress(inp, block_k: int = 8, block_j: int = 64, interpret: bool = True):
    return stress_pallas(inp, block_k=block_k, block_j=block_j, interpret=interpret)


def stress_region(dims=(64, 64, 64), vmem_budget: int = 16 * 2**20) -> ATRegion:
    nk, nj, ni = dims
    divs = lambda n: tuple(d for d in (1, 2, 4, 8, 16, 32, 64) if n % d == 0 and d <= n)
    space = ParamSpace(
        [PerfParam("block_k", divs(nk)), PerfParam("block_j", divs(nj))],
        constraint=lambda p: vmem_bytes(p["block_k"], p["block_j"], ni)
        <= vmem_budget,
    )

    def instantiate(point: Mapping[str, Any]):
        bk, bj = point["block_k"], point["block_j"]
        return lambda inp: stress(inp, block_k=bk, block_j=bj)

    return ATRegion("stress_pallas", space, instantiate, oracle=stress_ref)


def shape_class(inp) -> BasicParams:
    nk, nj, ni = next(iter(inp.values())).shape
    return BasicParams.make(
        kernel="stress",
        nk=int(nk),
        nj=int(nj),
        ni=int(ni),
        dtype=str(next(iter(inp.values())).dtype),
        backend=jax.default_backend(),
    )


register_kernel(
    KernelSpec(
        "stress",
        make_region=lambda bp: stress_region(dims=(bp["nk"], bp["nj"], bp["ni"])),
        shape_class=shape_class,
        prescreen_factory=roofline_prescreen,
        tags=("pallas",),
    ),
    replace=True,
)
