"""Jitted wrapper + AT region for the stress Pallas kernel."""
from __future__ import annotations

import functools
from typing import Any, Mapping, Optional, Sequence

import jax

from repro.core import ATRegion, BasicParams, KernelSpec, register_kernel
from repro.core.arch import ArchSpec, default_interpret, local_arch
from repro.core.emit import TileDim, TilePolicy, hint_prescreen

from .ref import stress_ref
from .stress import stress_pallas, vmem_bytes


@functools.partial(jax.jit, static_argnames=("block_k", "block_j", "interpret"))
def stress(inp, block_k: int = 8, block_j: int = 64,
           interpret: Optional[bool] = None):
    if interpret is None:
        interpret = default_interpret()
    return stress_pallas(inp, block_k=block_k, block_j=block_j, interpret=interpret)


def _traffic(bp: Mapping[str, Any], point: Mapping[str, Any]):
    nk, nj, ni = bp["nk"], bp["nj"], bp["ni"]
    cells = float(nk * nj * ni)
    return 30.0 * cells, 2.0 * cells * 4 * 9   # 9 stress/strain fields


STRESS_POLICY = TilePolicy(
    kernel="stress",
    # both block dims are pure grid splits of the outer loops (the paper's
    # Seism3D update_stress nest); the inner ni stays whole per program
    dims=lambda bp: (
        TileDim("block_k", bp["nk"], semantic="grid"),
        TileDim("block_j", bp["nj"], semantic="grid"),
    ),
    vmem_model=lambda bp, p: vmem_bytes(p["block_k"], p["block_j"], bp["ni"]),
    traffic_model=_traffic,
)


def stress_region(
    dims=(64, 64, 64), vmem_budget: Optional[int] = None,
    arch: Optional[ArchSpec] = None,
    pinned: Sequence[Mapping[str, Any]] = (),
) -> ATRegion:
    nk, nj, ni = dims
    arch = arch or local_arch()
    emitted = STRESS_POLICY.emit(
        arch, {"nk": nk, "nj": nj, "ni": ni},
        pinned=pinned, vmem_budget=vmem_budget,
    )

    def instantiate(point: Mapping[str, Any]):
        bk, bj = point["block_k"], point["block_j"]
        return lambda inp: stress(inp, block_k=bk, block_j=bj)

    return ATRegion(
        "stress_pallas", emitted.space, instantiate, oracle=stress_ref,
        space_signature=emitted.signature, hints=emitted.hints, arch=arch,
    )


def shape_class(inp) -> BasicParams:
    nk, nj, ni = next(iter(inp.values())).shape
    return BasicParams.make(
        kernel="stress",
        nk=int(nk),
        nj=int(nj),
        ni=int(ni),
        dtype=str(next(iter(inp.values())).dtype),
        backend=jax.default_backend(),
    )


register_kernel(
    KernelSpec(
        "stress",
        make_region=lambda bp: stress_region(dims=(bp["nk"], bp["nj"], bp["ni"])),
        shape_class=shape_class,
        prescreen_factory=hint_prescreen,
        tags=("pallas",),
    ),
    replace=True,
)
