"""Pallas TPU kernel for Seism3D ``update_stress``.

Grid over (k-blocks, j-blocks); the contiguous i dimension stays inside the
block as the VPU lane axis (the Fortran innermost loop — never split, per
the paper's Fig-14 lesson).  Tunables (block_k, block_j) are the directive
position / grain: one program instance per (bk × bj × i) tile.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DT, INPUT_NAMES


def _stress_kernel(*refs):
    i = {name: r for name, r in zip(INPUT_NAMES, refs[: len(INPUT_NAMES)])}
    o = refs[len(INPUT_NAMES):]
    rl = i["lam"][...]
    rm = i["rig"][...]
    rm2 = 2.0 * rm
    rlrm2 = rl + rm2
    dxVx, dyVy, dzVz = i["dxVx"][...], i["dyVy"][...], i["dzVz"][...]
    d3 = dxVx + dyVy + dzVz
    o[0][...] = i["Sxx"][...] + DT * (rlrm2 * d3 - rm2 * (dyVy + dzVz))
    o[1][...] = i["Syy"][...] + DT * (rlrm2 * d3 - rm2 * (dxVx + dzVz))
    o[2][...] = i["Szz"][...] + DT * (rlrm2 * d3 - rm2 * (dxVx + dyVy))
    o[3][...] = i["Sxy"][...] + DT * rm * (i["dxVy"][...] + i["dyVx"][...])
    o[4][...] = i["Sxz"][...] + DT * rm * (i["dxVz"][...] + i["dzVx"][...])
    o[5][...] = i["Syz"][...] + DT * rm * (i["dyVz"][...] + i["dzVy"][...])


def stress_pallas(
    inp: Dict[str, jnp.ndarray],
    block_k: int = 8,
    block_j: int = 64,
    interpret: bool = True,
) -> Dict[str, jnp.ndarray]:
    nk, nj, ni = inp["Sxx"].shape
    if nk % block_k or nj % block_j:
        raise ValueError(f"blocks ({block_k},{block_j}) must divide ({nk},{nj})")
    grid = (nk // block_k, nj // block_j)
    spec = pl.BlockSpec((block_k, block_j, ni), lambda a, b: (a, b, 0))
    out_shape = [jax.ShapeDtypeStruct((nk, nj, ni), jnp.float32)] * 6
    fn = pl.pallas_call(
        _stress_kernel,
        grid=grid,
        in_specs=[spec] * len(INPUT_NAMES),
        out_specs=[spec] * 6,
        out_shape=out_shape,
        interpret=interpret,
    )
    outs = fn(*[inp[n] for n in INPUT_NAMES])
    return dict(zip(("Sxx", "Syy", "Szz", "Sxy", "Sxz", "Syz"), outs))


def vmem_bytes(block_k: int, block_j: int, ni: int) -> int:
    pad_i = -(-ni // 128) * 128
    return (len(INPUT_NAMES) + 6) * block_k * block_j * pad_i * 4
