"""Jitted wrapper + AT region for the RG-LRU Pallas kernel."""
from __future__ import annotations

import functools
from typing import Any, Mapping, Optional, Sequence

import jax

from repro.core import ATRegion, BasicParams, KernelSpec, register_kernel
from repro.core.arch import ArchSpec, default_interpret, local_arch
from repro.core.emit import TileDim, TilePolicy, hint_prescreen

from .ref import rglru_scan_ref
from .rglru_scan import rglru_scan, vmem_bytes


@functools.partial(jax.jit, static_argnames=("block_w", "chunk", "interpret"))
def scan(x, r, i, lam, block_w: int = 512, chunk: int = 128,
         interpret: Optional[bool] = None):
    if interpret is None:
        interpret = default_interpret()
    return rglru_scan(x, r, i, lam, block_w=block_w, chunk=chunk,
                      interpret=interpret)


def _traffic(bp: Mapping[str, Any], point: Mapping[str, Any]):
    s, w = bp["seq"], bp["width"]
    flops = 8.0 * s * w
    bytes_ = 4.0 * s * w * 4           # x, r, i, out at f32
    return flops, bytes_


RGLRU_POLICY = TilePolicy(
    kernel="rglru_scan",
    dims=lambda bp: (
        TileDim("block_w", bp["width"], semantic="lane"),
        TileDim("chunk", bp["seq"], semantic="sequential"),
    ),
    vmem_model=lambda bp, p: vmem_bytes(p["block_w"], p["chunk"]),
    traffic_model=_traffic,
)


def rglru_region(
    width: int, seq_len: int, vmem_budget: Optional[int] = None,
    arch: Optional[ArchSpec] = None,
    pinned: Sequence[Mapping[str, Any]] = (),
) -> ATRegion:
    arch = arch or local_arch()
    emitted = RGLRU_POLICY.emit(
        arch, {"width": width, "seq": seq_len},
        pinned=pinned, vmem_budget=vmem_budget,
    )

    def instantiate(point: Mapping[str, Any]):
        bw, ck = point["block_w"], point["chunk"]
        return lambda x, r, i, lam: scan(x, r, i, lam, block_w=bw, chunk=ck)

    return ATRegion(
        "rglru_scan_pallas", emitted.space, instantiate,
        oracle=rglru_scan_ref, space_signature=emitted.signature,
        hints=emitted.hints, arch=arch,
    )


def shape_class(x, r, i, lam) -> BasicParams:
    """(width, seq) fix the candidate family; batch is dropped."""
    return BasicParams.make(
        kernel="rglru_scan",
        width=int(x.shape[-1]),
        seq=int(x.shape[1]),
        dtype=str(x.dtype),
        backend=jax.default_backend(),
    )


register_kernel(
    KernelSpec(
        "rglru_scan",
        make_region=lambda bp: rglru_region(bp["width"], bp["seq"]),
        shape_class=shape_class,
        prescreen_factory=hint_prescreen,
        tags=("pallas",),
    ),
    replace=True,
)
