"""Jitted wrapper + AT region for the RG-LRU Pallas kernel."""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax

from repro.core import ATRegion, BasicParams, KernelSpec, ParamSpace, PerfParam, register_kernel
from repro.core.cost import roofline_prescreen

from .ref import rglru_scan_ref
from .rglru_scan import rglru_scan, vmem_bytes


@functools.partial(jax.jit, static_argnames=("block_w", "chunk", "interpret"))
def scan(x, r, i, lam, block_w: int = 512, chunk: int = 128, interpret: bool = True):
    return rglru_scan(x, r, i, lam, block_w=block_w, chunk=chunk,
                      interpret=interpret)


def rglru_region(
    width: int, seq_len: int, vmem_budget: int = 16 * 2**20
) -> ATRegion:
    w_blocks = tuple(
        b for b in (128, 256, 512, 1024, 2560) if b <= width and width % b == 0
    ) or (width,)
    chunks = tuple(
        c for c in (32, 64, 128, 256, 512) if c <= seq_len and seq_len % c == 0
    ) or (seq_len,)
    space = ParamSpace(
        [PerfParam("block_w", w_blocks), PerfParam("chunk", chunks)],
        constraint=lambda p: vmem_bytes(p["block_w"], p["chunk"]) <= vmem_budget,
    )

    def instantiate(point: Mapping[str, Any]):
        bw, ck = point["block_w"], point["chunk"]
        return lambda x, r, i, lam: scan(x, r, i, lam, block_w=bw, chunk=ck)

    return ATRegion("rglru_scan_pallas", space, instantiate, oracle=rglru_scan_ref)


def shape_class(x, r, i, lam) -> BasicParams:
    """(width, seq) fix the candidate family; batch is dropped."""
    return BasicParams.make(
        kernel="rglru_scan",
        width=int(x.shape[-1]),
        seq=int(x.shape[1]),
        dtype=str(x.dtype),
        backend=jax.default_backend(),
    )


register_kernel(
    KernelSpec(
        "rglru_scan",
        make_region=lambda bp: rglru_region(bp["width"], bp["seq"]),
        shape_class=shape_class,
        prescreen_factory=roofline_prescreen,
        tags=("pallas",),
    ),
    replace=True,
)
