"""Pure-jnp oracle for the RG-LRU scan kernel (post-gate quantities)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

C_FACTOR = 8.0


def rglru_scan_ref(
    x: jnp.ndarray,    # (B, S, W)  conv'd inputs
    r: jnp.ndarray,    # (B, S, W)  recurrence gate, in (0,1)
    i: jnp.ndarray,    # (B, S, W)  input gate, in (0,1)
    lam: jnp.ndarray,  # (W,)       Λ parameter
) -> jnp.ndarray:
    softplus_neg_lam = jax.nn.softplus(-lam.astype(jnp.float32))

    def step(h, inputs):
        x_t, r_t, i_t = inputs
        a = jnp.exp(-C_FACTOR * r_t * softplus_neg_lam)
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_t * x_t)
        return h, h

    B, S, W = x.shape
    h0 = jnp.zeros((B, W), jnp.float32)
    xs = (
        x.transpose(1, 0, 2).astype(jnp.float32),
        r.transpose(1, 0, 2).astype(jnp.float32),
        i.transpose(1, 0, 2).astype(jnp.float32),
    )
    _, hs = lax.scan(step, h0, xs)
    return hs.transpose(1, 0, 2).astype(x.dtype)


def make_inputs(key, B=2, S=64, W=32):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, S, W), jnp.float32)
    r = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, W), jnp.float32))
    i = jax.nn.sigmoid(jax.random.normal(ks[2], (B, S, W), jnp.float32))
    u = jax.random.uniform(ks[3], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u / (1 - u))
    return x, r, i, lam
