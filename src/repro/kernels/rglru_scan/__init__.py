from . import ops, ref
from .rglru_scan import rglru_scan, vmem_bytes
