"""Pallas TPU kernel for the RG-LRU recurrence — sequential-grid carry.

Same chunked idiom as the selective scan (grid (B, w-blocks, chunks), h in
VMEM scratch across chunk steps) but with a diagonal state (no N dim), so
each fori step is pure VPU elementwise on a (block_w,) lane vector.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

C_FACTOR = 8.0


def _rglru_kernel(
    x_ref, r_ref, i_ref,  # (1, chunk, bw)
    lam_ref,              # (bw,)
    y_ref,                # (1, chunk, bw)
    h_ref,                # scratch (bw,) fp32
    *,
    chunk: int,
):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    splam = jax.nn.softplus(-lam_ref[...].astype(jnp.float32))  # (bw,)

    def step(t, h):
        x_t = x_ref[0, t, :].astype(jnp.float32)
        r_t = r_ref[0, t, :].astype(jnp.float32)
        i_t = i_ref[0, t, :].astype(jnp.float32)
        a = jnp.exp(-C_FACTOR * r_t * splam)
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_t * x_t)
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def rglru_scan(
    x: jnp.ndarray,   # (B, S, W)
    r: jnp.ndarray,
    i: jnp.ndarray,
    lam: jnp.ndarray,  # (W,)
    block_w: int = 512,
    chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, S, W = x.shape
    bw = min(block_w, W)
    ck = min(chunk, S)
    if W % bw or S % ck:
        raise ValueError(f"blocks ({bw},{ck}) must divide (W={W}, S={S})")
    grid = (B, W // bw, S // ck)

    spec = pl.BlockSpec((1, ck, bw), lambda b, w, c: (b, c, w))
    lam_spec = pl.BlockSpec((bw,), lambda b, w, c: (w,))

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(_rglru_kernel, chunk=ck)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec, lam_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, W), x.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(x, r, i, lam)


def vmem_bytes(block_w: int, chunk: int) -> int:
    pad = lambda n: -(-n // 128) * 128
    return 4 * chunk * pad(block_w) * 4 + 2 * pad(block_w) * 4
