from . import ops, ref
from .exb import exb_pallas, vmem_bytes
