"""Jitted wrapper + AT region for the exb Pallas kernel.

``exb_region()`` brackets the kernel's (block_iv, block_iz) family exactly
like the paper brackets the Fortran loop nest — the candidate family is
emitted from the architecture model (core/emit.py), with a VMEM-feasibility
constraint standing in for "enough iterations per thread" (docs/design.md
§2), and an analytic cost model for install-time AT on a host without the
target hardware.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import ATRegion, BasicParams, KernelSpec, register_kernel
from repro.core.arch import ArchSpec, default_interpret, local_arch
from repro.core.cost import TPU_V5E, HardwareSpec
from repro.core.emit import TileDim, TilePolicy

from .exb import exb_pallas, vmem_bytes
from .ref import exb_ref


@functools.partial(jax.jit, static_argnames=("block_iv", "block_iz", "interpret"))
def exb(inp: Dict[str, jnp.ndarray], block_iv: int = 1, block_iz: int = 16,
        interpret: Optional[bool] = None):
    if interpret is None:
        interpret = default_interpret()
    return exb_pallas(inp, block_iv=block_iv, block_iz=block_iz, interpret=interpret)


def _traffic(bp: Mapping[str, Any], point: Mapping[str, Any]):
    iv, iz, mx, my = bp["iv"], bp["iz"], bp["mx"], bp["my"]
    flops = 24.0 * iv * iz * mx * my
    # 3-D fields are re-streamed once per iv-block row (index_map reuse)
    bytes_ = 6.0 * iv * iz * mx * my * 4 \
        + 8.0 * iz * mx * my * 4 * (iv // point["block_iv"])
    return flops, bytes_


EXB_POLICY = TilePolicy(
    kernel="exb",
    dims=lambda bp: (
        TileDim("block_iv", bp["iv"], semantic="grid"),
        TileDim("block_iz", bp["iz"], semantic="grid"),
    ),
    vmem_model=lambda bp, p: vmem_bytes(
        p["block_iv"], p["block_iz"], bp["mx"], bp["my"]
    ),
    traffic_model=_traffic,
)


def exb_region(
    dims=(16, 16, 128, 65), vmem_budget: Optional[int] = None,
    arch: Optional[ArchSpec] = None,
    pinned: Sequence[Mapping[str, Any]] = (),
) -> ATRegion:
    iv, iz, mx, my = dims
    arch = arch or local_arch()
    emitted = EXB_POLICY.emit(
        arch, {"iv": iv, "iz": iz, "mx": mx, "my": my},
        pinned=pinned, vmem_budget=vmem_budget,
    )

    def instantiate(point: Mapping[str, Any]):
        biv, biz = point["block_iv"], point["block_iz"]
        return lambda inp: exb(inp, block_iv=biv, block_iz=biz)

    return ATRegion(
        "exb_pallas", emitted.space, instantiate, oracle=exb_ref,
        space_signature=emitted.signature, hints=emitted.hints, arch=arch,
    )


def analytic_cost(
    point: Mapping[str, Any],
    dims=(16, 16, 128, 65),
    hw: HardwareSpec = TPU_V5E,
    grid_overhead_s: float = 1.5e-6,
) -> float:
    """Install-time cost model: HBM-stream time + per-program overhead.

    The kernel is memory-bound (arithmetic intensity ≈ 24 flops / 56 bytes),
    so cost ≈ bytes/BW + n_programs × launch overhead; finer grids pipeline
    better but pay overhead — the same trade the FX100 thread count makes.
    """
    iv, iz, mx, my = dims
    biv, biz = point["block_iv"], point["block_iz"]
    n_programs = (iv // biv) * (iz // biz)
    bytes_hbm = 6 * iv * iz * mx * my * 4 + 8 * iz * mx * my * 4 * (iv // biv)
    # 3-D fields are re-streamed once per iv-block row (index_map reuse)
    return bytes_hbm / hw.hbm_bandwidth + n_programs * grid_overhead_s


def shape_class(inp) -> BasicParams:
    iz, mx, my = inp["ex_re"].shape
    return BasicParams.make(
        kernel="exb",
        iv=int(inp["vl"].shape[0]),
        iz=int(iz),
        mx=int(mx),
        my=int(my),
        dtype=str(inp["ex_re"].dtype),
        backend=jax.default_backend(),
    )


def _bp_dims(bp: BasicParams):
    return (bp["iv"], bp["iz"], bp["mx"], bp["my"])


def _analytic_factory(region, bp, args, kwargs):
    return lambda point: analytic_cost(point, dims=_bp_dims(bp))


register_kernel(
    KernelSpec(
        "exb",
        make_region=lambda bp: exb_region(dims=_bp_dims(bp)),
        shape_class=shape_class,
        # install-layer AT on a host without the target hardware: the
        # memory-bound analytic model replaces wall-clock measurement, and
        # doubles as the staged prescreen — stage 1 ranks exactly, so the
        # measured-finals stage only confirms the top-k
        cost_factory=_analytic_factory,
        prescreen_factory=_analytic_factory,
        tags=("pallas",),
    ),
    replace=True,
)
