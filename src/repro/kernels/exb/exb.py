"""Pallas TPU kernel for GKV ``exb_realspcal`` with an Exchange-style
(grid × block) candidate family.

The paper's directive-position transform maps onto Pallas as: loop levels
OUTSIDE the kernel become grid dimensions (one program instance per tile,
pipelined HBM→VMEM), loop levels INSIDE the block are VPU-vectorized.  The
tunable pair (block_iv, block_iz) plays (directive position × thread count):

* block_iv=1,  block_iz=1  → grid (16,16): directive on iz, max grain count
  (the paper's Fig-1 structure);
* block_iv=1,  block_iz=16 → grid (16,1): directive on iv (Fig 4 — the
  paper's winner on FX100);
* block_iv=16, block_iz=16 → grid (1,1): single fused block (Fig 7).

The (mx, my) inner loops always stay inside the block — my=65 is the short
loop whose 32-way splitting destroyed FX100 pipelining (Fig 14); on TPU it
maps to the VPU lane dimension and must never be split across grid.

3-D field blocks drop the iv grid index in their index_map — the physical
realization of the Fortran broadcast, with zero memory amplification.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import CEF, CS1


def _exb_kernel(
    vl_ref,
    df1_re_ref, df1_im_ref, df2_re_ref, df2_im_ref,
    ex_re_ref, ex_im_ref, ey_re_ref, ey_im_ref,
    bx_re_ref, bx_im_ref, by_re_ref, by_im_ref,
    out_re_ref, out_im_ref,
):
    vl = vl_ref[...][:, None, None, None]  # (biv,1,1,1)
    cs1vl = CS1 * vl
    ey_re = ey_re_ref[...][None] - cs1vl * by_re_ref[...][None]
    ey_im = ey_im_ref[...][None] - cs1vl * by_im_ref[...][None]
    ex_re = ex_re_ref[...][None] - cs1vl * bx_re_ref[...][None]
    ex_im = ex_im_ref[...][None] - cs1vl * bx_im_ref[...][None]
    out_re_ref[...] = (df1_re_ref[...] * ey_re - df2_re_ref[...] * ex_re) * CEF
    out_im_ref[...] = (df1_im_ref[...] * ey_im - df2_im_ref[...] * ex_im) * CEF


def exb_pallas(
    inp: Dict[str, jnp.ndarray],
    block_iv: int = 1,
    block_iz: int = 16,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    iv, iz, mx, my = inp["df1_re"].shape
    if iv % block_iv or iz % block_iz:
        raise ValueError(f"blocks ({block_iv},{block_iz}) must divide ({iv},{iz})")
    grid = (iv // block_iv, iz // block_iz)

    b4 = pl.BlockSpec(
        (block_iv, block_iz, mx, my), lambda i, j: (i, j, 0, 0)
    )
    b3 = pl.BlockSpec((block_iz, mx, my), lambda i, j: (j, 0, 0))  # drops iv
    bvl = pl.BlockSpec((block_iv,), lambda i, j: (i,))

    out_shape = [
        jax.ShapeDtypeStruct((iv, iz, mx, my), jnp.float32),
        jax.ShapeDtypeStruct((iv, iz, mx, my), jnp.float32),
    ]
    fn = pl.pallas_call(
        _exb_kernel,
        grid=grid,
        in_specs=[bvl] + [b4] * 4 + [b3] * 8,
        out_specs=[b4, b4],
        out_shape=out_shape,
        interpret=interpret,
    )
    args = [
        inp["vl"],
        inp["df1_re"], inp["df1_im"], inp["df2_re"], inp["df2_im"],
        inp["ex_re"], inp["ex_im"], inp["ey_re"], inp["ey_im"],
        inp["bx_re"], inp["bx_im"], inp["by_re"], inp["by_im"],
    ]
    out_re, out_im = fn(*args)
    return out_re, out_im


def vmem_bytes(block_iv: int, block_iz: int, mx: int = 128, my: int = 65) -> int:
    """VMEM working set of one program instance (feasibility constraint)."""
    pad_my = -(-my // 128) * 128  # lane padding on real TPU
    b4 = block_iv * block_iz * mx * pad_my * 4
    b3 = block_iz * mx * pad_my * 4
    return 6 * b4 + 8 * b3 + block_iv * 4  # 4 in + 2 out 4-D, 8 3-D, vl
