"""Pure-jnp oracle for the GKV exb kernel (split re/im layout).

The TPU adaptation UNPACKS the Fortran complex packing into separate
float32 planes (docs/design.md §2): the original cmplx() trick packs two
independent real fields; on TPU separate planes vectorize on the VPU
without complex emulation, and the 3-D fields stay 3-D (the iv broadcast
happens through BlockSpec index maps, not materialized memory).

Inputs (C-order):
    df1_re/df1_im/df2_re/df2_im : (iv, iz, mx, my) f32
    ex_re/ex_im/ey_re/ey_im/bx_re/bx_im/by_re/by_im : (iz, mx, my) f32
    vl : (iv,) f32
Output: out_re/out_im : (iv, iz, mx, my) f32
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

CS1 = 0.8775825618903728
CEF = 1.0 / (2 * 128 * 2 * 64)


def exb_ref(inp: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    vl = inp["vl"][:, None, None, None]  # (iv,1,1,1)
    ey_re = inp["ey_re"][None] - CS1 * vl * inp["by_re"][None]
    ey_im = inp["ey_im"][None] - CS1 * vl * inp["by_im"][None]
    ex_re = inp["ex_re"][None] - CS1 * vl * inp["bx_re"][None]
    ex_im = inp["ex_im"][None] - CS1 * vl * inp["bx_im"][None]
    out_re = (inp["df1_re"] * ey_re - inp["df2_re"] * ex_re) * CEF
    out_im = (inp["df1_im"] * ey_im - inp["df2_im"] * ex_im) * CEF
    return out_re, out_im


def make_inputs(key: jax.Array, dims=(16, 16, 128, 65)) -> Dict[str, jnp.ndarray]:
    iv, iz, mx, my = dims
    names4 = ["df1_re", "df1_im", "df2_re", "df2_im"]
    names3 = ["ex_re", "ex_im", "ey_re", "ey_im", "bx_re", "bx_im", "by_re", "by_im"]
    ks = jax.random.split(key, len(names4) + len(names3) + 1)
    out = {}
    for n, k in zip(names4, ks):
        out[n] = jax.random.normal(k, (iv, iz, mx, my), jnp.float32)
    for n, k in zip(names3, ks[len(names4):]):
        out[n] = jax.random.normal(k, (iz, mx, my), jnp.float32)
    out["vl"] = jax.random.normal(ks[-1], (iv,), jnp.float32)
    return out
