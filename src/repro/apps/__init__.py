"""The paper's two target applications, rebuilt in JAX.

* :mod:`repro.apps.gkv` — GKV plasma-turbulence ``exb_realspcal`` quadruple
  loop (paper §III/§V target; Watanabe & Sugama 2006).
* :mod:`repro.apps.seism3d` — ppOpen-APPL/FDM / Seism3D ``update_stress``
  (paper §IV target; Mori, Matsumoto & Furumura 2015).
"""
from . import gkv, seism3d

__all__ = ["gkv", "seism3d"]
