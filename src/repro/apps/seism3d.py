"""Seism3D / ppOpen-APPL/FDM ``update_stress`` — the paper's §IV target.

``update_stress`` advances the six stress components of the 3-D
velocity–stress staggered-grid FDM by one time step, given the nine velocity
derivative fields (computed by the companion ``update_vel``-side difference
routines, which ppOpen-APPL/FDM keeps separate).  Per grid point::

    RL    = lam(i,j,k)            ! Lamé lambda
    RM    = rig(i,j,k)            ! rigidity mu
    RM2   = 2*RM
    RLRM2 = RL + RM2
    D3    = dxVx + dyVy + dzVz
    Sxx  += dt * (RLRM2*D3 - RM2*(dyVy + dzVz))
    Syy  += dt * (RLRM2*D3 - RM2*(dxVx + dzVz))
    Szz  += dt * (RLRM2*D3 - RM2*(dxVx + dyVy))
    Sxy  += dt * RM * (dxVy + dyVx)
    Sxz  += dt * RM * (dxVz + dzVx)
    Syz  += dt * RM * (dyVz + dzVy)

This routine is 35 % of Seism3D's total run time (paper §IV.B) and is
elementwise in the derivative arrays, so it brackets directly as a 3-deep
(k, j, i) AT LoopNest.  The paper tunes only the thread count for it; we
expose the full (variant × degree) space and use it for the Fig-12
degree-switch-overhead experiment.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import ATRegion, LoopNest

# A NUMA-node-scale grid; the FX100 experiment ran 8 MPI ranks x 8 nodes.
SEISM_DIMS: Tuple[Tuple[str, int], ...] = (("k", 64), ("j", 64), ("i", 64))

DT = 5.0e-3

_DERIVS = ("dxVx", "dyVy", "dzVz", "dxVy", "dyVx", "dxVz", "dzVx", "dyVz", "dzVy")
_STRESS = ("Sxx", "Syy", "Szz", "Sxy", "Sxz", "Syz")


def update_stress_body(inp: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    rl = inp["lam"]
    rm = inp["rig"]
    rm2 = 2.0 * rm
    rlrm2 = rl + rm2
    d3 = inp["dxVx"] + inp["dyVy"] + inp["dzVz"]
    return {
        "Sxx": inp["Sxx"] + DT * (rlrm2 * d3 - rm2 * (inp["dyVy"] + inp["dzVz"])),
        "Syy": inp["Syy"] + DT * (rlrm2 * d3 - rm2 * (inp["dxVx"] + inp["dzVz"])),
        "Szz": inp["Szz"] + DT * (rlrm2 * d3 - rm2 * (inp["dxVx"] + inp["dyVy"])),
        "Sxy": inp["Sxy"] + DT * rm * (inp["dxVy"] + inp["dyVx"]),
        "Sxz": inp["Sxz"] + DT * rm * (inp["dxVz"] + inp["dzVx"]),
        "Syz": inp["Syz"] + DT * rm * (inp["dyVz"] + inp["dzVy"]),
    }


def make_inputs(
    key: jax.Array, dims: Sequence[Tuple[str, int]] = SEISM_DIMS
) -> Dict[str, jnp.ndarray]:
    shape = tuple(n for _, n in dims)
    names = list(_STRESS) + list(_DERIVS) + ["lam", "rig"]
    ks = jax.random.split(key, len(names))
    out = {}
    for name, k in zip(names, ks):
        x = jax.random.normal(k, shape, jnp.float32)
        if name in ("lam", "rig"):
            x = 1.0 + jnp.abs(x)  # physical: positive moduli
        out[name] = x
    return out


def stress_nest(dims: Sequence[Tuple[str, int]] = SEISM_DIMS) -> LoopNest:
    return LoopNest("seism3d_update_stress", dims, update_stress_body)


def stress_region(
    dims: Sequence[Tuple[str, int]] = SEISM_DIMS,
    degrees: Sequence[int] = (1, 2, 4, 8, 16, 32),
) -> ATRegion:
    return stress_nest(dims).at_region(degrees=degrees)


def reference(inputs: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return update_stress_body(inputs)


def flops_per_point() -> int:
    """1 (rm2) + 1 (rlrm2) + 2 (d3) + 3*(2+1+1+1) + 3*(1+1+1) = 28."""
    return 28
