"""GKV ``exb_realspcal`` — the paper's §III/§V tuning target, in JAX.

The Fortran original (paper Fig. 1) updates the E×B drift term of the
gyrokinetic Vlasov distribution in real space::

    do iv = 1, 2*nv
    !$OMP parallel do private(mx, my)
      do iz = -nz, nz-1
        do mx = ist_xw, iend_xw
          do my = 0, nyw
            wkdf1_xw(my,mx,iz,iv) = cmplx(
               real (wkdf1)*real (wkeyw - cs1*vl(iv)*wkbyw)
             - real (wkdf2)*real (wkexw - cs1*vl(iv)*wkbxw),
               aimag(wkdf1)*aimag(wkeyw - cs1*vl(iv)*wkbyw)
             - aimag(wkdf2)*aimag(wkexw - cs1*vl(iv)*wkbxw)) * cef

The curious real/imag-split product exists because GKV packs two real-space
fields into one complex array after a real-to-complex FFT; the component-wise
product is two independent real multiplies, NOT a complex multiply.  We keep
that exactly (it is what makes the kernel memory-light and vector-friendly —
2 real FMAs per component).

Index domain (paper §III.C, FX100 run):
    iv: 16,  iz: 16,  mx: 128,  my: 65   (Fortran array order is reversed;
    we store C-order ``(iv, iz, mx, my)``).

Fields:
    wkdf1_xw, wkdf2_xw              complex64 over (iv, iz, mx, my)
    wkexw_xw, wkeyw_xw,
    wkbxw_xw, wkbyw_xw              complex64 over (iz, mx, my)
    vl                              float32 over (iv,)
    cs1, cef                        real scalars

The loop nest is bracketed as an AT region over the paper's 10 Exchange ×
LoopFusion variants and the degree domain {1,...,32} — §V's joint space.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import ATRegion, LoopNest

# Paper §III.C experimental domain.
GKV_DIMS: Tuple[Tuple[str, int], ...] = (
    ("iv", 16),
    ("iz", 16),
    ("mx", 128),
    ("my", 65),
)

CS1 = 0.8775825618903728  # cos(0.5); any O(1) physics constant works
CEF = 1.0 / (2 * 128 * 2 * 64)  # 1/(2nx * 2ny) FFT back-normalization


def exb_body(inp: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """The calculation kernel, shape-polymorphic and elementwise.

    All leaves of ``inp`` share one (block) shape; ``vl`` etc. are already
    broadcast by :func:`make_inputs`.  Returns the updated ``wkdf1_xw``.
    """
    ey = inp["wkeyw"] - CS1 * inp["vl"] * inp["wkbyw"]
    ex = inp["wkexw"] - CS1 * inp["vl"] * inp["wkbxw"]
    re = inp["wkdf1"].real * ey.real - inp["wkdf2"].real * ex.real
    im = inp["wkdf1"].imag * ey.imag - inp["wkdf2"].imag * ex.imag
    return {"wkdf1": jax.lax.complex(re, im) * CEF}


def make_inputs(
    key: jax.Array, dims: Sequence[Tuple[str, int]] = GKV_DIMS
) -> Dict[str, jnp.ndarray]:
    """Random physical fields, pre-broadcast to the full (iv,iz,mx,my) domain.

    Broadcasting happens once, outside any timed region — mirroring that the
    Fortran code streams the rank-3 fields once per iv iteration anyway.
    """
    shape = tuple(n for _, n in dims)
    iv, iz, mx, my = shape
    ks = jax.random.split(key, 13)

    def cplx(k1, k2, s):
        return jax.lax.complex(
            jax.random.normal(k1, s, jnp.float32), jax.random.normal(k2, s, jnp.float32)
        )

    f3 = (iz, mx, my)
    out = {
        "wkdf1": cplx(ks[0], ks[1], shape),
        "wkdf2": cplx(ks[2], ks[3], shape),
        "wkexw": jnp.broadcast_to(cplx(ks[4], ks[5], f3), shape),
        "wkeyw": jnp.broadcast_to(cplx(ks[6], ks[7], f3), shape),
        "wkbxw": jnp.broadcast_to(cplx(ks[8], ks[9], f3), shape),
        "wkbyw": jnp.broadcast_to(cplx(ks[10], ks[11], f3), shape),
        "vl": jnp.broadcast_to(
            jax.random.normal(ks[12], (iv, 1, 1, 1), jnp.float32), shape
        ),
    }
    # Materialize broadcasts so every candidate sees identical concrete inputs.
    return {k: jnp.asarray(v) for k, v in out.items()}


def exb_nest(dims: Sequence[Tuple[str, int]] = GKV_DIMS) -> LoopNest:
    return LoopNest("gkv_exb_realspcal", dims, exb_body)


def exb_region(
    dims: Sequence[Tuple[str, int]] = GKV_DIMS,
    degrees: Sequence[int] = (1, 2, 4, 8, 16, 32),
) -> ATRegion:
    """The paper's AT region: 10 loop variants × thread degrees (§V)."""
    return exb_nest(dims).at_region(degrees=degrees)


def reference(inputs: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Pure-jnp oracle on the whole domain."""
    return exb_body(inputs)


def flops_per_point() -> int:
    """Real FLOPs per domain point (for roofline napkin math).

    ey/ex: 2 complex scale+sub = 2*(2 mul + 2 sub) = 8 each -> 16
    re/im: 2 mul + 1 sub each -> 6;  final scale: 2.  Total 24.
    """
    return 24
