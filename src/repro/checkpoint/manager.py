"""Checkpointing: atomic, restartable, reshard-on-load.

Format: one directory per step —

    <dir>/step_0000400/
        manifest.json   # step, pytree structure, leaf dtypes/shapes
        arrays.npz      # flattened leaves keyed "l<000i>"

Written to ``<name>.tmp`` then ``os.replace``d: a crash mid-save never
corrupts the latest checkpoint (the FIBER DB uses the same discipline).

Elastic rescale: leaves are stored *unsharded*; ``load_checkpoint`` takes an
optional ``shardings`` pytree and ``jax.device_put``s each leaf onto the new
mesh — so a job restarted on a different mesh shape (e.g. 256 → 512 chips)
resumes transparently.  (A production store would write per-shard files;
single-host np.savez keeps this container honest.)
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Atomically write ``tree`` (a pytree of arrays) for ``step``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = jax.tree.flatten(tree)
    arrays = {}
    dtypes = []
    for i, leaf in enumerate(leaves):
        a = np.asarray(leaf)
        dtypes.append(str(a.dtype))
        if a.dtype.kind == "V" or not a.dtype.isbuiltin:
            # ml_dtypes types (bfloat16, fp8) are not npz-serializable —
            # store the raw bits as a same-width unsigned view.
            a = a.view(f"u{a.dtype.itemsize}")
        arrays[f"l{i:05d}"] = a
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": dtypes,
        "shapes": [list(a.shape) for a in arrays.values()],
        "format": 1,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_checkpoint(
    path: str, like: Any, shardings: Optional[Any] = None
) -> Tuple[int, Any]:
    """Load a checkpoint dir into the structure of ``like``.

    ``shardings`` (optional pytree of NamedSharding, same structure) places
    each leaf directly on the (possibly different) target mesh.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = []
        for i in range(manifest["n_leaves"]):
            a = z[f"l{i:05d}"]
            want = manifest["dtypes"][i]
            if str(a.dtype) != want:  # bit-view restore for ml_dtypes
                import ml_dtypes

                a = a.view(np.dtype(getattr(ml_dtypes, want, want)))
            arrays.append(a)
    leaves_like, treedef = jax.tree.flatten(like)
    if len(leaves_like) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, expected {len(leaves_like)}"
        )
    if shardings is not None:
        shard_leaves = jax.tree.leaves(shardings)
        placed = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    else:
        placed = [jax.numpy.asarray(a) for a in arrays]
    return manifest["step"], jax.tree.unflatten(treedef, placed)


def latest_step_dir(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best: Optional[Tuple[int, str]] = None
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m:
            s = int(m.group(1))
            if best is None or s > best[0]:
                best = (s, os.path.join(directory, name))
    return best[1] if best else None


class CheckpointManager:
    """Keep-N rotation + resume discovery + save cadence."""

    def __init__(self, directory: str, save_every: int = 100, keep: int = 3) -> None:
        self.directory = directory
        self.save_every = save_every
        self.keep = keep

    def maybe_save(self, step: int, tree: Any, force: bool = False) -> Optional[str]:
        if not force and (step == 0 or step % self.save_every):
            return None
        path = save_checkpoint(self.directory, step, tree)
        self._rotate()
        return path

    def restore_latest(
        self, like: Any, shardings: Optional[Any] = None
    ) -> Optional[Tuple[int, Any]]:
        path = latest_step_dir(self.directory)
        if path is None:
            return None
        return load_checkpoint(path, like, shardings)

    def _rotate(self) -> None:
        steps: List[Tuple[int, str]] = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m:
                steps.append((int(m.group(1)), os.path.join(self.directory, name)))
        steps.sort()
        for _, path in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(path, ignore_errors=True)
