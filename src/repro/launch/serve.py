"""Production serve CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.data import synthetic_requests
    from repro.models import init_params, param_specs
    from repro.runtime import Server

    cfg = get_config(args.arch, smoke=not args.full)
    params = init_params(jax.random.PRNGKey(0), param_specs(cfg))
    server = Server(cfg, params, batch_size=args.requests)
    out = server.run(
        synthetic_requests(cfg, args.requests, args.prompt_len, args.new_tokens)
    )
    print(f"served {len(out)} requests, {server.stats.tokens_out} tokens, "
          f"{server.stats.decode_tok_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()
