"""Production serve CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b

``--trace mixed`` replays a mixed prefill/decode trace through the
traffic-class autotuner (docs/serving.md): unseen classes tune on the
background worker while the hot path serves the precompiled default, then
hot-swap to the tuned winner.  ``--inline-tune`` instead tunes on the hot
path (the latency-comparison baseline); the default performs no tuning.

``--stream`` swaps the static batch Server for the continuous-batching
:class:`~repro.runtime.engine.StreamingEngine`: an open-loop bursty arrival
trace feeds an admission queue, the iteration-level scheduler interleaves
prefill and decode over a paged KV cache, and the report adds TTFT
percentiles (the metric static batching loses under bursty load).

Overload/chaos knobs (stream mode, docs/serving.md): ``--deadline`` sets a
per-request TTL, ``--queue-limit``/``--shed-policy`` bound the admission
queue, and ``--chaos-seed`` runs the trace under the seeded
:class:`~repro.runtime.chaos.ChaosInjector` (transient step faults, KV
squeezes, delays) on the adversarial trace.  The run exits non-zero if the
hardened engine fails to retire every request exactly once — the drain
contract the chaos-smoke CI job asserts.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument(
        "--batch-size", type=int, default=None,
        help="serve batch width (default: min(4, requests))",
    )
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--trace", choices=("uniform", "mixed", "bursty"), default="uniform",
        help="uniform: identical requests; mixed: prefill/decode-heavy mix; "
             "bursty: the mixed mix with open-loop burst arrivals "
             "(--stream's default)",
    )
    ap.add_argument(
        "--stream", action="store_true",
        help="serve with the continuous-batching StreamingEngine "
             "(admission queue + paged KV cache + tuned scheduler knobs) "
             "instead of the static-batch Server",
    )
    ap.add_argument(
        "--blocks", type=int, default=8,
        help="paged KV cache pool size (stream mode): max concurrent "
             "in-flight requests",
    )
    ap.add_argument(
        "--max-len", type=int, default=None,
        help="per-request KV capacity (stream mode); default: sized to the "
             "longest prompt+completion in the trace",
    )
    ap.add_argument(
        "--burst-size", type=int, default=4,
        help="requests per arrival burst (bursty trace)",
    )
    ap.add_argument(
        "--burst-gap", type=float, default=0.05,
        help="virtual seconds between bursts (bursty trace)",
    )
    ap.add_argument(
        "--deadline", type=float, default=None,
        help="per-request TTL in virtual seconds (stream mode): a request "
             "not finished within this of its arrival retires timed_out",
    )
    ap.add_argument(
        "--queue-limit", type=int, default=None,
        help="admission queue bound (stream mode): excess waiting requests "
             "are shed per --shed-policy",
    )
    ap.add_argument(
        "--shed-policy", default=None,
        choices=("reject-new", "drop-oldest", "deadline-aware"),
        help="load-shedding policy when the queue exceeds --queue-limit "
             "(default: let the tuned scheduler knob pick)",
    )
    ap.add_argument(
        "--chaos-seed", type=int, default=None,
        help="run under the seeded ChaosInjector (stream mode): transient "
             "step faults, KV-pool squeezes, and virtual delays; the trace "
             "switches to the adversarial variant (deadlines + priorities)",
    )
    ap.add_argument(
        "--chaos-fault-rate", type=float, default=0.05,
        help="per-step transient fault probability under --chaos-seed",
    )
    ap.add_argument(
        "--unhardened", action="store_true",
        help="disable the engine's hardened paths (strict upfront "
             "validation, raise-on-stall) — the crash/deadlock baseline",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write a Chrome/Perfetto trace of the run (tuner trials, "
             "background jobs, engine request timelines on the virtual "
             "clock) to PATH; view at ui.perfetto.dev or validate with "
             "`repro.launch.observe trace`",
    )
    ap.add_argument(
        "--metrics-out", default=None, metavar="PATH",
        help="write the run's metrics registry (engine/server, chaos, "
             "background-tuner stats) as Prometheus text to PATH",
    )
    ap.add_argument(
        "--tick-timer", type=float, default=None, metavar="SECONDS",
        help="deterministic measurement clock (stream mode): every timed "
             "step costs exactly this many virtual seconds, so a seeded "
             "--chaos-seed run produces a byte-identical --trace-out",
    )
    tune_mode = ap.add_mutually_exclusive_group()
    tune_mode.add_argument(
        "--background-tune", action="store_true",
        help="tune unseen traffic classes on a background worker",
    )
    tune_mode.add_argument(
        "--inline-tune", action="store_true",
        help="tune unseen traffic classes on the hot path (baseline)",
    )
    tune_mode.add_argument(
        "--joint-tune", action="store_true",
        help="joint AT of (prefill x decode) degrees on the measured "
             "full serve step before serving (docs/program.md)",
    )
    ap.add_argument("--tuning-db", default=None, help="persistent TuningDB path")
    ap.add_argument(
        "--device-key", action="store_true",
        help="namespace DB entries under the host DeviceFingerprint, so a "
             "fleet-shared DB never recalls a foreign host's final "
             "(docs/fleet.md)",
    )
    ap.add_argument(
        "--drift-factor", type=float, default=None,
        help="enable the drift watch: demote + canary-re-tune a final whose "
             "observed cost exceeds its recorded cost by this factor "
             "(requires --background-tune: the re-tune must stay off the "
             "hot path)",
    )
    ap.add_argument(
        "--fleet-workers", type=int, default=None,
        help="shard background searches across N in-process fleet workers "
             "(requires --background-tune; best for compile-dominated "
             "costs — concurrent measured timings on one device reflect "
             "contention)",
    )
    args = ap.parse_args()
    if args.drift_factor and not args.background_tune:
        ap.error("--drift-factor requires --background-tune "
                 "(an inline re-tune would run the search on the hot path)")
    if args.fleet_workers and not args.background_tune:
        ap.error("--fleet-workers requires --background-tune "
                 "(there is no background search to shard without it)")
    if args.stream:
        if args.trace == "uniform":
            args.trace = "bursty"
        if args.joint_tune:
            ap.error("--joint-tune is a static-Server mode (the engine "
                     "tunes its scheduler knobs per traffic class instead)")
        if args.drift_factor:
            ap.error("--drift-factor is a static-Server mode")
    else:
        for flag, val in (("--deadline", args.deadline),
                          ("--queue-limit", args.queue_limit),
                          ("--shed-policy", args.shed_policy),
                          ("--chaos-seed", args.chaos_seed),
                          ("--tick-timer", args.tick_timer)):
            if val is not None:
                ap.error(f"{flag} requires --stream (the static Server has "
                         "no admission queue to bound)")

    import jax

    from repro.configs import get_config
    from repro.core import TuningDB
    from repro.data import (
        adversarial_trace, bursty_open_loop_trace, mixed_traffic_trace,
        synthetic_requests,
    )
    from repro.fleet import DriftMonitor, FleetCoordinator
    from repro.models import init_params, param_specs
    from repro.obs import MetricsRegistry, TickTimer, Tracer, set_tracer
    from repro.runtime import (
        BackgroundTuner, ChaosInjector, Server, StreamingEngine,
    )

    tracer = Tracer() if args.trace_out else None
    if tracer is not None:
        # process-wide: tuner trials, search stages, background jobs, and
        # fleet calls all land on the same flight recorder as the engine
        set_tracer(tracer)
    registry = MetricsRegistry() if args.metrics_out else None

    cfg = get_config(args.arch, smoke=not args.full)
    params = init_params(jax.random.PRNGKey(0), param_specs(cfg))
    if args.stream and args.chaos_seed is not None:
        # the overload trace: the bursty mix plus deadlines and priorities,
        # so the hardened paths (timeout, shed, preempt) actually fire
        requests = adversarial_trace(
            cfg, args.requests, seed=args.chaos_seed,
            scale=1.0 if args.full else 0.25,
            burst_size=args.burst_size, burst_gap_s=args.burst_gap,
            deadline_ttl_s=args.deadline or 0.5,
        )
    elif args.trace == "bursty":
        # smoke configs get a scaled-down trace: full-length decodes dominate
        # a CI smoke run without exercising anything extra
        requests = bursty_open_loop_trace(
            cfg, args.requests, scale=1.0 if args.full else 0.25,
            burst_size=args.burst_size, burst_gap_s=args.burst_gap,
        )
    elif args.trace == "mixed":
        requests = mixed_traffic_trace(cfg, args.requests)
    else:
        requests = synthetic_requests(
            cfg, args.requests, args.prompt_len, args.new_tokens
        )

    fleet = (
        FleetCoordinator(workers=args.fleet_workers, backend="thread")
        if args.fleet_workers else None
    )
    tuner = BackgroundTuner(fleet=fleet) if args.background_tune else None

    if args.stream:
        max_len = args.max_len or max(
            len(r.prompt) + r.max_new_tokens for r in requests
        )
        chaos = (
            ChaosInjector(
                seed=args.chaos_seed,
                step_fault_rate=args.chaos_fault_rate,
                squeeze_rate=0.1,
                delay_rate=0.1,
            )
            if args.chaos_seed is not None else None
        )
        engine = StreamingEngine(
            cfg,
            params,
            n_blocks=args.blocks,
            max_len=max_len,
            tuning_db=TuningDB(args.tuning_db) if args.tuning_db else None,
            background_tuner=tuner,
            inline_tune=args.inline_tune,
            device_key=args.device_key,
            hardened=not args.unhardened,
            queue_limit=args.queue_limit,
            shed_policy=args.shed_policy,
            default_ttl_s=args.deadline,
            chaos=chaos,
            timer=TickTimer(args.tick_timer) if args.tick_timer else None,
            tracer=tracer,
        )
        out = engine.serve(requests)
        s = engine.stats
        print(
            f"served {len(out)} requests, {s.tokens_out} tokens, "
            f"{s.tok_per_s:.1f} tok/s "
            f"({s.prefill_steps} prefill / {s.decode_steps} decode steps, "
            f"peak in-flight {s.peak_in_flight})"
        )
        # every stat object flows through the one registry pipe — the
        # report below and --metrics-out render the same source of truth
        registry = registry or MetricsRegistry()
        registry.register_stats("engine", s, help="streaming-engine stats")
        if chaos is not None:
            registry.register_stats(
                "chaos", chaos.stats, help="chaos-injector stats"
            )

        def _retired(reg):
            for status in ("ok", "timed_out", "shed", "error"):
                n = sum(
                    1 for r in engine.results.values() if r.status == status
                )
                reg.gauge(
                    "engine_retired", help="terminal request statuses"
                ).set(n, status=status)

        registry.register_collector(_retired)
        print(registry.report(title="stream metrics"))
        if not args.unhardened:
            unique_rids = {r.rid for r in requests}
            if set(engine.results) != unique_rids:
                missing = sorted(unique_rids - set(engine.results))
                print(f"ERROR: drain incomplete — {len(missing)} requests "
                      f"never retired: {missing[:8]}")
                sys.exit(1)
        print(f"traffic classes: {', '.join(engine.traffic_classes_seen) or '-'}")
        print(f"hot-path tuning evaluations: {engine.hot_path_cost_evaluations}")
        if tuner is not None:
            drained = tuner.drain(timeout=300)
            tuner.stop()
            print(
                f"background-tuned classes: "
                f"{', '.join(tuner.tuned_labels) or '-'} "
                f"({tuner.background_evaluations} evaluations off the hot path)"
            )
            sched = engine.tuned_scheduler_classes
            print(f"tuned scheduler classes: {', '.join(sched) or '-'}")
            if not drained:
                print("WARNING: background tuning did not drain within 300s")
            for label, err in tuner.errors:
                print(f"WARNING: background tuning failed for {label}: {err!r}")
        if args.metrics_out:
            registry.write(args.metrics_out)
            print(f"metrics written to {args.metrics_out}")
        if tracer is not None:
            set_tracer(None)
            tracer.write(args.trace_out)
            print(f"trace written to {args.trace_out} "
                  f"({tracer.emitted} events, {tracer.dropped} dropped)")
        return

    drift = (
        DriftMonitor(background=tuner, factor=args.drift_factor)
        if args.drift_factor else None
    )
    server = Server(
        cfg,
        params,
        batch_size=args.batch_size or min(4, args.requests),
        tuning_db=TuningDB(args.tuning_db) if args.tuning_db else None,
        background_tuner=tuner,
        inline_tune=args.inline_tune,
        device_key=args.device_key,
        drift_monitor=drift,
    )
    if args.joint_tune:
        r = server.joint_tune(requests)
        src = "recalled by fingerprint" if r.from_cache else (
            f"{r.evaluations} measured step evaluations"
        )
        print(f"joint serve winner: {r.assignment} ({src})")
    out = server.run(requests)
    print(f"served {len(out)} requests, {server.stats.tokens_out} tokens, "
          f"{server.stats.decode_tok_per_s:.1f} tok/s")
    print(f"traffic classes: {', '.join(server.traffic_classes_seen) or '-'}")
    print(f"hot-path tuning evaluations: {server.hot_path_cost_evaluations}")
    if tuner is not None:
        drained = tuner.drain(timeout=300)
        tuner.stop()
        print(f"background-tuned classes: {', '.join(tuner.tuned_labels) or '-'} "
              f"({tuner.background_evaluations} evaluations off the hot path)")
        if not drained:
            print("WARNING: background tuning did not drain within 300s")
        for label, err in tuner.errors:
            print(f"WARNING: background tuning failed for {label}: {err!r}")
    if drift is not None and drift.transitions:
        kinds = ", ".join(kind for _, kind in drift.transitions)
        print(f"drift transitions: {kinds}")
    if args.metrics_out:
        registry = registry or MetricsRegistry()
        registry.register_stats(
            "server", server.stats, help="static-server stats"
        )
        registry.write(args.metrics_out)
        print(f"metrics written to {args.metrics_out}")
    if tracer is not None:
        set_tracer(None)
        tracer.write(args.trace_out)
        print(f"trace written to {args.trace_out} "
              f"({tracer.emitted} events, {tracer.dropped} dropped)")


if __name__ == "__main__":
    main()
