"""Production train CLI.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 100 --ckpt-dir /tmp/run1 [--smoke]

On this host the full configs are CPU-prohibitive; --smoke (default) uses
the reduced config.  On a real TPU slice the same entry point shards
params/opt-state with the tuned sharding rule (see launch/dryrun.py for the
rule selection machinery).
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import SyntheticLMDataset
    from repro.optim import AdamWConfig
    from repro.runtime import Trainer, TrainLoopConfig

    cfg = get_config(args.arch, smoke=not args.full)
    trainer = Trainer(
        cfg,
        AdamWConfig(total_steps=args.steps),
        TrainLoopConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir,
            n_microbatches=args.microbatches,
        ),
    )
    ds = SyntheticLMDataset(cfg, global_batch=args.batch, seq_len=args.seq)
    hist = trainer.run(ds)
    print(f"final loss: {hist['loss'][-1]:.4f} after {len(hist['loss'])} steps")


if __name__ == "__main__":
    main()
