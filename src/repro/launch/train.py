"""Production train CLI.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 100 --ckpt-dir /tmp/run1 [--smoke]

On this host the full configs are CPU-prohibitive; --smoke (default) uses
the reduced config.  On a real TPU slice the same entry point shards
params/opt-state with the tuned sharding rule (see launch/dryrun.py for the
rule selection machinery).

``--joint-tune`` runs whole-program joint AT (docs/program.md) before the
loop: the (microbatch degree × remat directive) composition is searched
against the *measured full train step*, the winner persists in the tuning
DB under the program fingerprint (``--tuning-db`` makes it survive runs),
and hot-applies through ``region.select``.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument(
        "--joint-tune", action="store_true",
        help="joint AT of (microbatch degree x remat) on the measured step",
    )
    ap.add_argument(
        "--joint-cap", type=int, default=16,
        help="joint-candidate budget: products under the cap measure "
             "exhaustively, larger ones switch to coordinate descent "
             "(hard-stopped at 2x the cap, plus finals re-measurements)",
    )
    ap.add_argument(
        "--joint-k", type=int, default=None,
        help="per-member survivor count (default: the whole member space)",
    )
    ap.add_argument("--tuning-db", default=None, help="persistent TuningDB path")
    ap.add_argument(
        "--device-key", action="store_true",
        help="namespace DB entries (and the joint-program fingerprint) "
             "under the host DeviceFingerprint (docs/fleet.md)",
    )
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core import TuningDB
    from repro.data import SyntheticLMDataset
    from repro.optim import AdamWConfig
    from repro.runtime import Trainer, TrainLoopConfig

    cfg = get_config(args.arch, smoke=not args.full)
    trainer = Trainer(
        cfg,
        AdamWConfig(total_steps=args.steps),
        TrainLoopConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir,
            n_microbatches=args.microbatches,
            joint_tune=args.joint_tune, joint_cap=args.joint_cap,
            joint_k=args.joint_k, device_key=args.device_key,
        ),
        tuning_db=TuningDB(args.tuning_db) if args.tuning_db else None,
    )
    ds = SyntheticLMDataset(cfg, global_batch=args.batch, seq_len=args.seq)
    hist = trainer.run(ds)
    print(f"final loss: {hist['loss'][-1]:.4f} after {len(hist['loss'])} steps")
    if trainer.joint_result is not None:
        r = trainer.joint_result
        src = "recalled by fingerprint" if r.from_cache else (
            f"{r.evaluations} measured step evaluations"
        )
        print(f"joint winner: {r.assignment} ({src})")


if __name__ == "__main__":
    main()
