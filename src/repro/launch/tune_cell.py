import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Before-execution AT of a full training/serving cell through the FIBER
tuner — the paper's §IV procedure ("user fixes BP; measure all candidates;
persist; select") executed at 256-chip scale with the hardware absent.

BP  = (arch, shape, mesh)
PP  = (sharding rule, remat policy, microbatch degree, attention blocks)
cost = CompiledRooflineCost: lower + compile each candidate, score with the
       trip-count-aware three-term roofline (max of C/M/X), with an HBM
       feasibility penalty.

    PYTHONPATH=src python -m repro.launch.tune_cell --arch qwen2.5-32b \
        --shape prefill_32k --db results/cell_tuning.json
"""
import argparse
import json
from typing import Any, Dict, Mapping

from repro.configs import SHAPES, ARCH_IDS, get_config
from repro.core import (
    ATRegion,
    BasicParams,
    ParamSpace,
    PerfParam,
    Tuner,
    TuningDB,
)
from repro.core.cost import TPU_V5E, roofline_from_compiled
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh, n_chips

HBM_BYTES = 16 * 2**30


def tune_cell(
    arch: str,
    shape: str,
    db_path: str,
    multi_pod: bool = False,
    hbm_penalty: float = 10.0,
) -> Dict[str, Any]:
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = n_chips(mesh)
    cfg = get_config(arch)

    params = [
        PerfParam("rule", ("tp",) + (("tp_ep",) if cfg.family == "moe" else ())
                  + (("tp_kvseq",) if cell.kind == "decode" else ())),
        PerfParam("attn_block_q", (512, 1024)),
        PerfParam("attn_block_kv", (1024, 4096)),
    ]
    if cell.kind == "train":
        params.append(PerfParam("remat", ("full", "dots")))
        params.append(PerfParam("n_micro", (1, 4)))
    space = ParamSpace(params)

    results: Dict[str, Any] = {}

    def cost(point: Mapping[str, Any]) -> float:
        overrides = {
            "attn_block_q": point["attn_block_q"],
            "attn_block_kv": point["attn_block_kv"],
        }
        if "remat" in point:
            overrides["remat"] = point["remat"]
        if cfg.family == "moe" and point["rule"] == "tp_ep":
            overrides["moe_groups"] = mesh.shape.get("data", 16)
        lowered, _ = lower_cell(
            arch, cell, mesh, point["rule"],
            cfg_overrides=overrides, n_micro=point.get("n_micro", 1),
        )
        compiled = lowered.compile()
        terms = roofline_from_compiled(lowered, compiled, chips, TPU_V5E)
        ma = compiled.memory_analysis()
        mem = (
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        )
        c = terms.total_s * (hbm_penalty if mem > HBM_BYTES else 1.0)
        results[json.dumps(dict(point), sort_keys=True)] = {
            "terms": terms.asdict(), "mem_per_dev": int(mem), "cost": c,
        }
        print(
            f"[tune] {dict(point)} -> C={terms.compute_s:.2e} M={terms.memory_s:.2e} "
            f"X={terms.collective_s:.2e} mem={mem / 2**30:.1f}GiB cost={c:.2e}"
        )
        return c

    region = ATRegion(f"{arch}/{shape}", space, instantiate=lambda p: (lambda: p))
    bp = BasicParams.make(arch=arch, shape=shape, chips=chips)
    tuner = Tuner(TuningDB(db_path))
    res = tuner.tune(region, bp, cost)
    print(f"\n[tune] best PP for BP({arch}, {shape}, {chips} chips): "
          f"{res.best.point}  cost={res.best.cost:.3e}s "
          f"({res.evaluations} candidates compiled)")
    return {"best": res.best.point, "cost": res.best.cost, "all": results}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--db", default="results/cell_tuning.json")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    tune_cell(args.arch, args.shape, args.db, args.multi_pod)


if __name__ == "__main__":
    main()
