"""Fleet tuning CLI — sharded before-execution AT (docs/fleet.md).

    PYTHONPATH=src python -m repro.launch.fleet --kernel demo \
        --workers 2 --backend spawn --shard-policy stride --sync-every 4

Partitions the kernel's PP space across ``--workers`` workers (in-process
threads or ``multiprocessing`` spawn), each running the existing search on
its shard against a scratch TuningDB, then merges at the barrier and
records the fleet winner — by construction the single-process winner.

``--kernel demo`` is a deterministic analytic problem (the only one the
spawn backend accepts: real-kernel costs close over device arrays); any
registered kernel name runs wall-clock measured on the thread backend.
``--check-equivalence`` re-runs single-worker and verifies the winner
matches — the CI smoke gate for the multiprocessing path.

Global tuning service (docs/fleet.md):

    # terminal 1 — the service, persisting to a DB file
    PYTHONPATH=src python -m repro.launch.fleet --serve-db \
        --db /tmp/service-db.json --port 8761

    # terminals 2..N — one process per host, each measuring its slice
    PYTHONPATH=src python -m repro.launch.fleet --kernel demo \
        --backend spawn --service-url http://127.0.0.1:8761 \
        --hosts 2 --host-index 0
    PYTHONPATH=src python -m repro.launch.fleet --kernel demo \
        --backend spawn --service-url http://127.0.0.1:8761 \
        --hosts 2 --host-index 1 --check-equivalence

``--serve-db`` runs the long-lived service; each host pushes its shard's
trials and pulls everyone else's at the merge barrier, so the *last*
host's recorded winner is the global single-process winner (what
``--check-equivalence`` asserts in service mode).  ``--fault-seed`` /
``--fault-drop`` / ``--fault-dup`` / ``--fault-reorder`` wrap the
transport in the deterministic fault injector — the CI service smoke runs
the whole flow over a deliberately lossy link to prove the lattice-join
protocol converges anyway.
"""
import argparse
import json


def serve(args: argparse.Namespace) -> None:
    """``--serve-db``: run the global tuning service until interrupted."""
    from repro.fleet import TuningService, serve_http

    service = TuningService(path=args.db)
    server = serve_http(service, host=args.host, port=args.port)
    host, port = server.server_address[:2]
    print(f"tuning service listening on http://{host}:{port} "
          f"(db={args.db or '<memory>'}, "
          f"{len(service.db.fingerprints())} entries; "
          f"GET /metrics for Prometheus text)", flush=True)
    try:
        import threading

        threading.Event().wait()  # serve_forever runs on a daemon thread
    except KeyboardInterrupt:
        server.shutdown()


def make_client(args: argparse.Namespace):
    """A ServiceClient over HTTP, optionally behind the fault injector."""
    from repro.fleet import FaultInjectionTransport, HTTPTransport, ServiceClient

    transport = HTTPTransport(args.service_url, timeout_s=args.timeout)
    injector = None
    if args.fault_seed is not None:
        injector = FaultInjectionTransport(
            transport, seed=args.fault_seed,
            drop_request=args.fault_drop, drop_response=args.fault_drop,
            duplicate=args.fault_dup, reorder=args.fault_reorder,
        )
        transport = injector
    client = ServiceClient(transport, retries=args.retries,
                           jitter_seed=args.host_index)
    return client, injector


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--kernel", default="demo",
        help="'demo' (analytic, spawn-safe) or a registered kernel name",
    )
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--shard-policy", choices=("stride", "block"), default="stride")
    ap.add_argument("--backend", choices=("thread", "spawn", "remote"),
                    default="thread")
    ap.add_argument(
        "--sync-every", type=int, default=8,
        help="trials between scratch-DB syncs (0 = merge barrier only)",
    )
    ap.add_argument("--db", default=None, help="persistent TuningDB path")
    ap.add_argument("--scratch-dir", default=None,
                    help="directory for per-worker scratch DBs")
    ap.add_argument("--keep-scratch", action="store_true",
                    help="leave scratch files on disk after the barrier")
    ap.add_argument(
        "--no-device-key", action="store_true",
        help="do not namespace DB entries under the host DeviceFingerprint",
    )
    ap.add_argument(
        "--check-equivalence", action="store_true",
        help="re-run with one worker and assert the same winner (CI smoke)",
    )
    # -- global tuning service ------------------------------------------------
    ap.add_argument("--serve-db", action="store_true",
                    help="run the global tuning service (uses --db/--host/--port)")
    ap.add_argument("--host", default="127.0.0.1", help="--serve-db bind host")
    ap.add_argument("--port", type=int, default=0,
                    help="--serve-db bind port (0 = ephemeral)")
    ap.add_argument("--service-url", default=None,
                    help="global tuning service URL (http://host:port)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="total hosts sharing the space through the service")
    ap.add_argument("--host-index", type=int, default=0,
                    help="this host's slice index in [0, --hosts)")
    ap.add_argument("--timeout", type=float, default=5.0,
                    help="per-request service timeout (seconds)")
    ap.add_argument("--retries", type=int, default=5,
                    help="service retries per call (bounded backoff)")
    # -- deterministic fault injection (the CI service smoke) -----------------
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="enable the fault injector with this RNG seed")
    ap.add_argument("--fault-drop", type=float, default=0.0,
                    help="per-call drop probability (requests and responses)")
    ap.add_argument("--fault-dup", type=float, default=0.0,
                    help="per-call duplicate-delivery probability")
    ap.add_argument("--fault-reorder", type=float, default=0.0,
                    help="per-call hold-and-replay (reorder) probability")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the run's worker/client/fault metrics as "
                         "Prometheus text to PATH")
    args = ap.parse_args()

    if args.serve_db:
        serve(args)
        return

    from repro.core import BasicParams, TuningDB
    from repro.fleet import FleetCoordinator, device_bp_entries, local_device
    from repro.fleet.workloads import demo_cost, demo_space, kernel_problem

    if args.kernel == "demo":
        space, cost = demo_space(), demo_cost
    else:
        if args.backend == "spawn":
            ap.error("--backend spawn requires --kernel demo "
                     "(measured kernel costs close over device arrays)")
        _, space, cost = kernel_problem(args.kernel)

    client, injector = (None, None)
    if args.service_url:
        client, injector = make_client(args)
    elif args.backend == "remote":
        ap.error("--backend remote requires --service-url")

    entries = {} if args.no_device_key else device_bp_entries()
    bp = BasicParams.make(kernel=f"fleet/{args.kernel}", **entries)
    db = TuningDB(args.db) if args.db else None

    coordinator = FleetCoordinator(
        workers=args.workers,
        shard_policy=args.shard_policy,
        backend=args.backend,
        sync_every=args.sync_every,
        scratch_dir=args.scratch_dir,
        service=client,
        hosts=args.hosts,
        host_index=args.host_index,
        keep_scratch=args.keep_scratch,
    )
    fleet = coordinator.search(space, cost, bp=bp, db=db)

    print(f"device: {'-' if args.no_device_key else local_device().label}")
    print(f"space: {space.size()} candidates, {len(fleet.workers)} workers "
          f"({args.backend}/{args.shard_policy}, sync_every={args.sync_every})")
    if args.hosts > 1:
        print(f"host {args.host_index}/{args.hosts}: this process measured "
              f"its slice only; the service holds the union")

    # worker/client/fault stats all flow through the one registry pipe
    # (docs/observability.md) — the printed report and --metrics-out render
    # the same source of truth
    from repro.obs import MetricsRegistry

    registry = MetricsRegistry()
    for w in fleet.workers:
        registry.register_stats(
            "fleet_worker", w, help="per-worker shard stats", worker=w.worker
        )
    if client is not None:
        registry.register_stats(
            "service_client", client.stats, help="service-client stats"
        )
        registry.gauge(
            "service_client_degraded", help="1 = merge barrier ran local-only"
        ).set(0 if fleet.service_synced else 1)
    if injector is not None:
        registry.register_stats(
            "fault_injector", injector.stats, help="injected transport faults"
        )
    print(registry.report(title="fleet metrics"))
    print(f"fleet winner: {json.dumps(fleet.best.point, sort_keys=True)} "
          f"@ {fleet.best.cost:.3e} ({fleet.evaluations} total evaluations)")
    if client is not None and not fleet.service_synced:
        print("WARNING: service DEGRADED — merge barrier ran local-only")
    if args.metrics_out:
        registry.write(args.metrics_out)
        print(f"metrics written to {args.metrics_out}")

    if args.check_equivalence:
        single = FleetCoordinator(
            workers=1, shard_policy=args.shard_policy, backend="thread",
            sync_every=0,
        ).search(space, cost, bp=bp)
        if single.best.point != fleet.best.point:
            raise SystemExit(
                f"FLEET EQUIVALENCE VIOLATED: {args.workers}-worker winner "
                f"{fleet.best.point} != single-process winner {single.best.point}"
            )
        scope = ("fleet-union" if args.hosts > 1 else f"{args.workers}-worker")
        print(f"equivalence OK: {scope} winner == single-process winner")

    if args.db:
        print(f"tuning DB: {args.db} "
              f"({len(fleet.merged.fingerprints())} entries)")


if __name__ == "__main__":
    main()
