"""Fleet tuning CLI — sharded before-execution AT (docs/fleet.md).

    PYTHONPATH=src python -m repro.launch.fleet --kernel demo \
        --workers 2 --backend spawn --shard-policy stride --sync-every 4

Partitions the kernel's PP space across ``--workers`` workers (in-process
threads or ``multiprocessing`` spawn), each running the existing search on
its shard against a scratch TuningDB, then merges at the barrier and
records the fleet winner — by construction the single-process winner.

``--kernel demo`` is a deterministic analytic problem (the only one the
spawn backend accepts: real-kernel costs close over device arrays); any
registered kernel name runs wall-clock measured on the thread backend.
``--check-equivalence`` re-runs single-worker and verifies the winner
matches — the CI smoke gate for the multiprocessing path.
"""
import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--kernel", default="demo",
        help="'demo' (analytic, spawn-safe) or a registered kernel name",
    )
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--shard-policy", choices=("stride", "block"), default="stride")
    ap.add_argument("--backend", choices=("thread", "spawn"), default="thread")
    ap.add_argument(
        "--sync-every", type=int, default=8,
        help="trials between scratch-DB syncs (0 = merge barrier only)",
    )
    ap.add_argument("--db", default=None, help="persistent TuningDB path")
    ap.add_argument("--scratch-dir", default=None,
                    help="directory for per-worker scratch DBs")
    ap.add_argument(
        "--no-device-key", action="store_true",
        help="do not namespace DB entries under the host DeviceFingerprint",
    )
    ap.add_argument(
        "--check-equivalence", action="store_true",
        help="re-run with one worker and assert the same winner (CI smoke)",
    )
    args = ap.parse_args()

    from repro.core import BasicParams, TuningDB
    from repro.fleet import FleetCoordinator, device_bp_entries, local_device
    from repro.fleet.workloads import demo_cost, demo_space, kernel_problem

    if args.kernel == "demo":
        space, cost = demo_space(), demo_cost
    else:
        if args.backend == "spawn":
            ap.error("--backend spawn requires --kernel demo "
                     "(measured kernel costs close over device arrays)")
        _, space, cost = kernel_problem(args.kernel)

    entries = {} if args.no_device_key else device_bp_entries()
    bp = BasicParams.make(kernel=f"fleet/{args.kernel}", **entries)
    db = TuningDB(args.db) if args.db else None

    coordinator = FleetCoordinator(
        workers=args.workers,
        shard_policy=args.shard_policy,
        backend=args.backend,
        sync_every=args.sync_every,
        scratch_dir=args.scratch_dir,
    )
    fleet = coordinator.search(space, cost, bp=bp, db=db)

    print(f"device: {'-' if args.no_device_key else local_device().label}")
    print(f"space: {space.size()} candidates, {len(fleet.workers)} workers "
          f"({args.backend}/{args.shard_policy}, sync_every={args.sync_every})")
    for w in fleet.workers:
        print(f"  worker {w.worker}: {w.points} points, "
              f"{w.evaluations} evals, {w.wall_s * 1e3:.1f} ms, "
              f"shard best {w.best_point} @ {w.best_cost:.3e}")
    print(f"fleet winner: {json.dumps(fleet.best.point, sort_keys=True)} "
          f"@ {fleet.best.cost:.3e} ({fleet.evaluations} total evaluations)")

    if args.check_equivalence:
        single = FleetCoordinator(
            workers=1, shard_policy=args.shard_policy, backend="thread",
            sync_every=0,
        ).search(space, cost, bp=bp)
        if single.best.point != fleet.best.point:
            raise SystemExit(
                f"FLEET EQUIVALENCE VIOLATED: {args.workers}-worker winner "
                f"{fleet.best.point} != single-process winner {single.best.point}"
            )
        print(f"equivalence OK: {args.workers}-worker winner == "
              "single-process winner")

    if args.db:
        print(f"tuning DB: {args.db} "
              f"({len(fleet.merged.fingerprints())} entries)")


if __name__ == "__main__":
    main()
