import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is FIBER before-execution AT with the hardware absent: the candidate
(sharding rule, remat policy, microbatch degree, ...) is lowered with
``jax.jit(step, in_shardings=...).lower(**input_specs)``, compiled (no
allocation — all inputs are ShapeDtypeStructs), and scored by
``memory_analysis()`` + the trip-count-aware HLO cost walk.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init), which is why it is the first statement of the
module.  Nothing else in the repo sets it — smoke tests and benches see the
host's real single device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod-only
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, ShapeCell, all_cells, get_config, skipped_cells
from repro.core.cost import TPU_V5E, roofline_from_compiled
from repro.distributed.sharding import (
    RULES,
    activation_sharding,
    logical_to_spec,
    opt_state_sharding,
    param_sharding,
)
from repro.launch.mesh import make_production_mesh, n_chips
from repro.models import (
    analytic_param_count,
    analytic_step_flops,
    decode_fn,
    input_logical_axes,
    input_specs,
    param_specs,
    prefill_fn,
    train_loss,
)
from repro.models.spec import as_shape_dtype_structs
from repro.optim import AdamWConfig, adamw_init_specs, adamw_update
from jax.sharding import NamedSharding


def _shard_tree(tree_specs, axes_tree, rule, mesh):
    def one(spec, axes):
        return NamedSharding(mesh, logical_to_spec(rule, spec.shape, axes, mesh))

    return jax.tree.map(one, tree_specs, axes_tree, is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


def lower_cell(
    arch: str,
    cell: ShapeCell,
    mesh,
    rule_name: str = "tp",
    opt_cfg: Optional[AdamWConfig] = None,
    cfg_overrides: Optional[Dict[str, Any]] = None,
    n_micro: int = 1,
):
    """Build and lower the step function for one cell.  Returns Lowered."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    rule = RULES[rule_name]
    specs = param_specs(cfg)
    p_shard = param_sharding(rule, specs, mesh)
    p_sds = as_shape_dtype_structs(specs)
    ins = input_specs(cfg, cell.kind, cell.global_batch, cell.seq_len)
    in_axes = input_logical_axes(cfg, cell.kind, ins)
    batch_shard = _shard_tree(ins["batch"], in_axes["batch"], rule, mesh)

    if cell.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        o_specs = adamw_init_specs(specs, opt_cfg)
        o_shard = opt_state_sharding(rule, o_specs, mesh)
        o_sds = as_shape_dtype_structs(o_specs)

        def train_step(params, opt_state, batch):
            if n_micro == 1:
                loss, grads = jax.value_and_grad(
                    lambda p: train_loss(p, batch, cfg)
                )(params)
            else:  # gradient-accumulation degree (the paper's thread-count PP)
                micro = jax.tree.map(
                    lambda x: x.reshape(
                        (x.shape[0], n_micro, x.shape[1] // n_micro) + x.shape[2:]
                    ).swapaxes(0, 1)
                    if x.ndim >= 2 and x.shape[0] == 3  # mrope positions
                    else x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                    batch,
                )
                zeros = jax.tree.map(
                    lambda q: jnp.zeros(q.shape, jnp.float32), params
                )

                def body(carry, mb):
                    g_acc, l_acc = carry
                    l, g = jax.value_and_grad(
                        lambda p: train_loss(p, mb, cfg)
                    )(params)
                    g_acc = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), g_acc, g
                    )
                    return (g_acc, l_acc + l), None

                (gs, ls), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), micro)
                grads = jax.tree.map(lambda g: g / n_micro, gs)
                loss = ls / n_micro
            params, opt_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
            return params, opt_state, loss

        jitted = jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, batch_shard),
            out_shardings=(p_shard, o_shard, NamedSharding(mesh, jax.sharding.PartitionSpec())),
        )
        with activation_sharding(mesh, rule):
            return jitted.lower(p_sds, o_sds, ins["batch"]), cfg

    if cell.kind == "prefill":
        def prefill_step(params, batch):
            return prefill_fn(params, batch, cfg)

        jitted = jax.jit(prefill_step, in_shardings=(p_shard, batch_shard))
        with activation_sharding(mesh, rule):
            return jitted.lower(p_sds, ins["batch"]), cfg

    if cell.kind == "decode":
        cache_shard = _shard_tree(ins["cache"], in_axes["cache"], rule, mesh)

        def serve_step(params, batch, cache):
            return decode_fn(params, batch, cache, cfg)

        jitted = jax.jit(
            serve_step, in_shardings=(p_shard, batch_shard, cache_shard)
        )
        with activation_sharding(mesh, rule):
            return jitted.lower(p_sds, ins["batch"], ins["cache"]), cfg

    raise ValueError(cell.kind)


def model_flops(cfg, cell: ShapeCell) -> float:
    """MODEL_FLOPS: 6·N·D / 2·N·D weight flops plus the attention/scan
    sequence terms (dominant at 32k+) — see models.analytic_step_flops."""
    return analytic_step_flops(cfg, cell.kind, cell.global_batch, cell.seq_len)


def run_cell(
    arch: str,
    cell: ShapeCell,
    multi_pod: bool,
    rule_name: str = "tp",
    verbose: bool = True,
    cfg_overrides: Optional[Dict[str, Any]] = None,
    opt_cfg: Optional[AdamWConfig] = None,
    n_micro: int = 1,
    label: str = "",
    mesh_shape=None,
) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    chips = n_chips(mesh)
    t0 = time.time()
    lowered, cfg = lower_cell(arch, cell, mesh, rule_name, cfg_overrides=cfg_overrides, opt_cfg=opt_cfg, n_micro=n_micro)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    terms = roofline_from_compiled(lowered, compiled, chips, TPU_V5E)
    mf = model_flops(cfg, cell)
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": cell.name,
        "kind": cell.kind,
        "mesh": (
            "pod" + "x".join(map(str, mesh_shape))
            if mesh_shape
            else ("pod2x16x16" if multi_pod else "pod16x16")
        ),
        "chips": chips,
        "rule": rule_name,
        "n_micro": n_micro,
        "label": label,
        "overrides": cfg_overrides or {},
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "per_device_total": int(
                ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes
            ),
        },
        "roofline": terms.asdict(),
        "model_flops": mf,
        "useful_flops_ratio": mf / terms.hlo_flops if terms.hlo_flops else None,
        "status": "ok",
    }
    if verbose:
        hbm_gib = rec["memory"]["per_device_total"] / 2**30
        print(
            f"[dryrun] {arch:22s} {cell.name:12s} {rec['mesh']:11s} rule={rule_name:8s} "
            f"lower={t_lower:6.1f}s compile={t_compile:6.1f}s "
            f"mem/dev={hbm_gib:7.2f}GiB "
            f"roofline: C={terms.compute_s:.3e}s M={terms.memory_s:.3e}s "
            f"X={terms.collective_s:.3e}s -> {terms.bottleneck} "
            f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)}"
        )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--rule", default="tp", choices=list(RULES))
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    done = set()
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"], r.get("rule", "tp")))
                except Exception:
                    pass

    if args.all:
        cells = all_cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, SHAPES[args.shape])]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    results = []
    for arch, cell in cells:
        for multi_pod in meshes:
            mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
            if (arch, cell.name, mesh_name, args.rule) in done:
                print(f"[dryrun] skip existing {arch} {cell.name} {mesh_name}")
                continue
            try:
                rec = run_cell(arch, cell, multi_pod, args.rule)
            except Exception as e:
                rec = {
                    "arch": arch,
                    "shape": cell.name,
                    "mesh": mesh_name,
                    "rule": args.rule,
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
                print(f"[dryrun] FAIL {arch} {cell.name} {mesh_name}: {e}")
            results.append(rec)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")

    for arch, shape, reason in skipped_cells():
        print(f"[dryrun] skipped-by-rule {arch} {shape}: {reason}")

    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"[dryrun] {n_ok}/{len(results)} cells ok")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
