import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: hypothesis → change → re-lower → measure cycles
on the three selected cells (see EXPERIMENTS.md §Perf for the narrative).

Each experiment is a named knob assignment over the SAME cell; results are
appended to results/hillclimb.jsonl so the iteration log is reproducible.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell granite
    PYTHONPATH=src python -m repro.launch.hillclimb --cell all
"""
import argparse
import json
from typing import Any, Dict, List, Optional, Tuple

from repro.configs import SHAPES
from repro.launch.dryrun import run_cell
from repro.optim import AdamWConfig

OUT = "results/hillclimb.jsonl"

# (label, kwargs for run_cell) — ordered: each step keeps the previous step's
# winning knobs (coordinate descent along the dominant term).
EXPERIMENTS: Dict[str, Tuple[str, str, List[Tuple[str, Dict[str, Any]]]]] = {
    # Worst roofline fraction (0.0002) + collective-bound: the MoE dispatch.
    "granite": (
        "granite-moe-1b-a400m",
        "train_4k",
        [
            ("baseline", dict(rule_name="tp")),
            ("ep_capacity_shard", dict(rule_name="tp_ep")),
            ("ep+remat_dots", dict(rule_name="tp_ep", cfg_overrides={"remat": "dots"})),
            ("ep+dots+micro4", dict(rule_name="tp_ep", cfg_overrides={"remat": "dots"}, n_micro=4)),
            ("ep+full+micro4", dict(rule_name="tp_ep", n_micro=4)),
            # it.2: GShard grouped dispatch — group boundaries = data shards,
            # every dispatch gather/scatter becomes shard-local
            ("ep+groups16", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16})),
            ("ep+groups16+micro4", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16}, n_micro=4)),
            # it.3: natively-batched dispatch with per-intermediate sharding
            # constraints (vmap left intermediate sharding to propagation)
            ("ep+groups16v2", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16})),
            ("ep+groups16v3_lightconstraints", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16})),
            ("ep+groups16v3+micro4", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16}, n_micro=4)),
            ("final_vmap_groups16+micro4", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16}, n_micro=4)),
            ("final_multipod", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 32}, n_micro=4, multi_pod=True)),
        ],
    ),
    # Most collective-bound (X=343 s): scout MoE + wide attention.
    "scout": (
        "llama4-scout-17b-a16e",
        "train_4k",
        [
            ("baseline", dict(rule_name="tp")),
            ("ep_capacity_shard", dict(rule_name="tp_ep")),
            ("ep+groups16", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16})),
            ("ep+groups16+micro8", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16}, n_micro=8)),
            ("ep+groups16+dots", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16, "remat": "dots"})),
            ("ep+groups16v2+micro8", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16}, n_micro=8)),
            # attention block tuning against the memory term
            ("v2+micro8+blk1024x4096", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 16, "attn_block_q": 1024, "attn_block_kv": 4096}, n_micro=8)),
            # mesh refactorization: 40 heads % 16 != 0 -> attention replicated
            # on (16,16); (32,8) shards heads 8-ways and doubles data degree
            ("mesh32x8+groups32+micro4", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 32, "attn_block_q": 1024, "attn_block_kv": 4096}, n_micro=4, mesh_shape=(32, 8))),
            ("mesh32x8+dots+micro8", dict(rule_name="tp_ep", cfg_overrides={"moe_groups": 32, "attn_block_q": 1024, "attn_block_kv": 4096, "remat": "dots"}, n_micro=8, mesh_shape=(32, 8))),
        ],
    ),
    # Most representative of the paper's technique (flagship dense train).
    "llama3": (
        "llama3-405b",
        "train_4k",
        [
            ("baseline", dict(rule_name="tp")),
            ("remat_dots", dict(cfg_overrides={"remat": "dots"})),
            ("dots+micro8", dict(cfg_overrides={"remat": "dots"}, n_micro=8)),
            ("full+micro8", dict(n_micro=8)),
            ("full+micro8+fsdp", dict(rule_name="fsdp_tp", n_micro=8)),
            (
                "full+micro8+fsdp+bf16mom",
                dict(
                    rule_name="fsdp_tp",
                    n_micro=8,
                    opt_cfg=AdamWConfig(moment_dtype="bfloat16"),
                ),
            ),
            ("fsdp_fix_embed+micro8", dict(rule_name="fsdp_tp", n_micro=8)),
            (
                "final_multipod",
                dict(
                    rule_name="fsdp_tp",
                    n_micro=16,
                    opt_cfg=AdamWConfig(moment_dtype="bfloat16"),
                    multi_pod=True,
                ),
            ),
            # v2: keep weights TP-only across pods (no DCN weight gathers);
            # ZeRO over data handles optimizer memory; embed table fixed
            (
                "final_multipod_v2_tp",
                dict(
                    rule_name="tp",
                    n_micro=8,
                    opt_cfg=AdamWConfig(moment_dtype="bfloat16"),
                    multi_pod=True,
                ),
            ),
        ],
    ),
}


def run_experiments(cell_key: str, skip_done: bool = True) -> None:
    arch, shape, steps = EXPERIMENTS[cell_key]
    done = set()
    if skip_done and os.path.exists(OUT):
        for line in open(OUT):
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r.get("label", ""), r["mesh"]))
            except Exception:
                pass
    for label, kwargs in steps:
        kwargs = dict(kwargs)
        multi_pod = kwargs.pop("multi_pod", False)
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        if (arch, shape, f"{cell_key}/{label}", mesh_name) in done:
            print(f"[hillclimb] skip {cell_key}/{label}")
            continue
        try:
            rec = run_cell(
                arch, SHAPES[shape], multi_pod=multi_pod,
                label=f"{cell_key}/{label}", **kwargs,
            )
        except Exception as e:
            rec = {
                "arch": arch, "shape": shape, "label": f"{cell_key}/{label}",
                "mesh": mesh_name, "status": "error",
                "error": f"{type(e).__name__}: {e}",
            }
            print(f"[hillclimb] FAIL {cell_key}/{label}: {e}")
        os.makedirs(os.path.dirname(OUT), exist_ok=True)
        with open(OUT, "a") as f:
            f.write(json.dumps(rec) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(EXPERIMENTS) + ["all"], default="all")
    args = ap.parse_args()
    cells = list(EXPERIMENTS) if args.cell == "all" else [args.cell]
    for c in cells:
        run_experiments(c)


if __name__ == "__main__":
    main()
