"""Observability CLI (docs/observability.md).

    # decision audit: why is each class running the candidate it runs?
    PYTHONPATH=src python -m repro.launch.observe explain --db db.json

    # DB roll-up as a metrics-style report
    PYTHONPATH=src python -m repro.launch.observe report --db db.json

    # validate + summarize a Perfetto trace written by --trace-out
    PYTHONPATH=src python -m repro.launch.observe trace --path trace.json

    # validate Prometheus text from --metrics-out or a live GET /metrics
    PYTHONPATH=src python -m repro.launch.observe metrics --path metrics.prom
    PYTHONPATH=src python -m repro.launch.observe metrics \
        --url http://127.0.0.1:8761/metrics

``trace`` and ``metrics`` exit non-zero on malformed input — they are the
CI observability-smoke job's validators, not just pretty-printers.
"""
import argparse
import json
import sys


def cmd_report(args: argparse.Namespace) -> int:
    from repro.core import TuningDB
    from repro.obs import MetricsRegistry
    from repro.obs.explain import db_summary

    db = TuningDB(args.db)
    registry = MetricsRegistry()
    registry.register_stats("tuning_db", db_summary(db),
                            help="tuning DB summary")
    print(registry.report(title=f"tuning DB {args.db}"))
    return 0


def cmd_trace(args: argparse.Namespace) -> int:
    """Validate the Chrome/Perfetto ``trace_event`` JSON shape and print a
    per-name event census."""
    try:
        doc = json.load(open(args.path))
    except (OSError, json.JSONDecodeError) as e:
        print(f"ERROR: cannot read trace {args.path}: {e}")
        return 1
    events = doc.get("traceEvents")
    if not isinstance(doc, dict) or not isinstance(events, list):
        print("ERROR: not a trace_event document "
              "(expected {'traceEvents': [...]})")
        return 1
    problems = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            problems.append(f"event {i}: missing name")
        if ph != "M" and not isinstance(ev.get("ts"), int):
            problems.append(f"event {i}: non-integer ts")
        if ph == "X" and not isinstance(ev.get("dur"), int):
            problems.append(f"event {i}: complete event without integer dur")
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            problems.append(f"event {i}: missing pid/tid")
    if problems:
        for p in problems[:10]:
            print(f"ERROR: {p}")
        return 1
    tracks = {
        ev["tid"]: ev["args"]["name"]
        for ev in events if ev.get("ph") == "M" and ev.get("name") == "thread_name"
    }
    census = {}
    for ev in events:
        if ev.get("ph") == "M":
            continue
        key = f"{tracks.get(ev['tid'], ev['tid'])}/{ev['name']}"
        census[key] = census.get(key, 0) + 1
    n = sum(census.values())
    print(f"trace OK: {n} events on {len(tracks)} tracks ({args.path})")
    for key in sorted(census):
        print(f"  {key} x{census[key]}")
    return 0


def cmd_metrics(args: argparse.Namespace) -> int:
    from repro.obs import parse_prometheus

    if args.url:
        from urllib.request import urlopen

        try:
            with urlopen(args.url, timeout=args.timeout) as resp:
                text = resp.read().decode()
        except OSError as e:
            print(f"ERROR: cannot fetch {args.url}: {e}")
            return 1
        source = args.url
    else:
        try:
            text = open(args.path).read()
        except OSError as e:
            print(f"ERROR: cannot read {args.path}: {e}")
            return 1
        source = args.path
    try:
        families = parse_prometheus(text)
    except ValueError as e:
        print(f"ERROR: malformed Prometheus text from {source}: {e}")
        return 1
    n = sum(len(samples) for samples in families.values())
    print(f"metrics OK: {len(families)} families, {n} samples ({source})")
    for name in sorted(families):
        for labels, value in families[name]:
            body = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
            print(f"  {name}{{{body}}} = {value}" if body
                  else f"  {name} = {value}")
    return 0


def cmd_explain(args: argparse.Namespace) -> int:
    from repro.core import TuningDB
    from repro.obs.explain import explain_all, explain_fingerprint, render_report

    db = TuningDB(args.db)
    if args.fingerprint:
        try:
            reports = [explain_fingerprint(db, args.fingerprint)]
        except KeyError as e:
            print(f"ERROR: {e.args[0]}")
            return 1
    else:
        reports = explain_all(db, kernel=args.kernel)
    if not reports:
        scope = f"kernel {args.kernel!r}" if args.kernel else "DB"
        print(f"no entries in {scope} ({args.db})")
        return 1
    if args.json:
        print(json.dumps(reports, indent=2, sort_keys=True, default=str))
        return 0
    for i, report in enumerate(reports):
        if i:
            print()
        print(render_report(report))
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(prog="repro.launch.observe")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("report", help="tuning-DB roll-up via the registry")
    p.add_argument("--db", required=True, help="TuningDB path")
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("trace", help="validate + summarize a Perfetto trace")
    p.add_argument("--path", required=True, help="trace JSON from --trace-out")
    p.set_defaults(fn=cmd_trace)

    p = sub.add_parser("metrics", help="validate Prometheus exposition text")
    src = p.add_mutually_exclusive_group(required=True)
    src.add_argument("--path", help="file from --metrics-out")
    src.add_argument("--url", help="live endpoint, e.g. http://host:port/metrics")
    p.add_argument("--timeout", type=float, default=5.0)
    p.set_defaults(fn=cmd_metrics)

    p = sub.add_parser("explain", help="tuning-decision audit per shape class")
    p.add_argument("--db", required=True, help="TuningDB path")
    p.add_argument("--kernel", default=None, help="restrict to one kernel class")
    p.add_argument("--fingerprint", default=None, help="one exact entry")
    p.add_argument("--json", action="store_true", help="structured output")
    p.set_defaults(fn=cmd_explain)

    args = ap.parse_args()
    sys.exit(args.fn(args))


if __name__ == "__main__":
    main()
