"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

Topology (TPU v5e):
* single pod:  (data=16, model=16) = 256 chips
* multi-pod:   (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
  the DCN dimension — gradient reduction is hierarchical (reduce-scatter
  in-pod over ICI, all-reduce across pods over DCN).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False, shape: Optional[Tuple[int, ...]] = None):
    """``shape`` overrides the default axis sizes (same axis names) — the
    mesh factorization itself is a tunable degree PP: e.g. (32, 8) fixes
    llama4-scout, whose 40 attention heads are indivisible by model=16 and
    run replicated on the default mesh (§Perf cell 2)."""
    import jax

    default: Tuple[int, ...] = (2, 16, 16) if multi_pod else (16, 16)
    shape = tuple(shape) if shape is not None else default
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} must have {len(axes)} axes")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, found {len(devices)}. "
            "The dry-run entry point must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import (see launch/dryrun.py)."
        )
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_host_mesh(model: int = 1):
    """A tiny mesh over however many devices the host actually has (tests)."""
    import jax

    devices = np.asarray(jax.devices())
    data = len(devices) // model
    return jax.sharding.Mesh(
        devices[: data * model].reshape(data, model), ("data", "model")
    )


def n_chips(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
