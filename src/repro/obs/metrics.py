"""Metrics registry: counters / gauges / histograms with label sets, a
Prometheus text-exposition writer, and adapters that pull every ad-hoc
stats object (``StreamStats``, ``ChaosStats``, ``ClientStats``,
``WorkerReport``, ``TuningService.stats``) through one pipe.

Stdlib-only, no repro imports (see trace.py for the layering rule).

The collection model is pull-based: :meth:`MetricsRegistry.register_stats`
stores a *collector* closure that re-snapshots its stats object each time
the registry is rendered, so ``GET /metrics`` on a live service and
``--metrics-out`` at the end of a run both observe current values.  Stats
objects opt in by exposing ``as_metrics() -> dict[str, number]``; plain
dicts of numbers work too.
"""
from __future__ import annotations

import re
import threading
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = [
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "parse_prometheus",
    "snapshot_stats",
]

DEFAULT_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0,
)

_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_NAME_FIX = re.compile(r"[^a-zA-Z0-9_:]")
_LINE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+([+-]?(?:\d+\.?\d*(?:[eE][+-]?\d+)?|Inf|NaN))$"
)


def sanitize_name(name: str) -> str:
    name = _NAME_FIX.sub("_", str(name))
    if not name or not _NAME_OK.match(name):
        name = "_" + name
    return name


def _escape_label(value: Any) -> str:
    return (
        str(value).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    )


def _fmt(value: float) -> str:
    """Deterministic sample formatting: integers render bare, floats via
    repr (shortest round-trip form)."""
    f = float(value)
    if f != f:
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def snapshot_stats(stats: Any) -> Dict[str, float]:
    """Normalize a stats object into a flat name->number snapshot.

    Prefers the ``as_metrics()`` protocol; falls back to a numeric-valued
    mapping (``TuningService.stats`` is a plain dict of counters)."""
    if hasattr(stats, "as_metrics"):
        raw = stats.as_metrics()
    elif isinstance(stats, Mapping):
        raw = stats
    else:  # last resort: public numeric attributes
        raw = {
            k: v for k, v in vars(stats).items()
            if not k.startswith("_") and isinstance(v, (int, float))
        }
    out: Dict[str, float] = {}
    for k, v in raw.items():
        if isinstance(v, bool):
            out[str(k)] = 1.0 if v else 0.0
        elif isinstance(v, (int, float)):
            out[str(k)] = float(v)
    return out


class _Labeled:
    """Shared label-keyed storage for one metric family."""

    def __init__(self, name: str, help: str):
        self.name = sanitize_name(name)
        self.help = help
        self._values: Dict[Tuple[Tuple[str, str], ...], Any] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _key(labels: Mapping[str, Any]) -> Tuple[Tuple[str, str], ...]:
        return tuple(sorted((str(k), str(v)) for k, v in labels.items()))

    def label_sets(self) -> List[Tuple[Tuple[str, str], ...]]:
        with self._lock:
            return sorted(self._values)


class Counter(_Labeled):
    kind = "counter"

    def inc(self, value: float = 1.0, **labels: Any) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        k = self._key(labels)
        with self._lock:
            self._values[k] = self._values.get(k, 0.0) + float(value)

    def value(self, **labels: Any) -> float:
        with self._lock:
            return float(self._values.get(self._key(labels), 0.0))

    def samples(self) -> Iterable[Tuple[str, Tuple[Tuple[str, str], ...], float]]:
        with self._lock:
            items = sorted(self._values.items())
        for k, v in items:
            yield self.name, k, v


class Gauge(_Labeled):
    kind = "gauge"

    def set(self, value: float, **labels: Any) -> None:
        with self._lock:
            self._values[self._key(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels: Any) -> None:
        k = self._key(labels)
        with self._lock:
            self._values[k] = self._values.get(k, 0.0) + float(value)

    def value(self, **labels: Any) -> float:
        with self._lock:
            return float(self._values.get(self._key(labels), 0.0))

    samples = Counter.samples


class Histogram(_Labeled):
    kind = "histogram"

    def __init__(self, name: str, help: str, buckets: Optional[Iterable[float]] = None):
        super().__init__(name, help)
        self.buckets = tuple(sorted(float(b) for b in (buckets or DEFAULT_BUCKETS)))

    def observe(self, value: float, **labels: Any) -> None:
        v = float(value)
        k = self._key(labels)
        with self._lock:
            st = self._values.setdefault(
                k, {"counts": [0] * len(self.buckets), "sum": 0.0, "count": 0}
            )
            st["sum"] += v
            st["count"] += 1
            for i, b in enumerate(self.buckets):
                if v <= b:
                    # per-bucket counts; samples() accumulates into the
                    # cumulative ``le`` form Prometheus expects
                    st["counts"][i] += 1
                    break

    def samples(self) -> Iterable[Tuple[str, Tuple[Tuple[str, str], ...], float]]:
        with self._lock:
            items = sorted(
                (k, {"counts": list(s["counts"]), "sum": s["sum"], "count": s["count"]})
                for k, s in self._values.items()
            )
        for k, st in items:
            cum = 0
            for b, n in zip(self.buckets, st["counts"]):
                cum += n
                yield f"{self.name}_bucket", k + (("le", _fmt(b)),), float(cum)
            yield f"{self.name}_bucket", k + (("le", "+Inf"),), float(st["count"])
            yield f"{self.name}_sum", k, float(st["sum"])
            yield f"{self.name}_count", k, float(st["count"])


class MetricsRegistry:
    """Family registry + pull-time collectors + exposition writers."""

    def __init__(self) -> None:
        self._metrics: Dict[str, _Labeled] = {}
        self._collectors: List[Callable[["MetricsRegistry"], None]] = []
        self._lock = threading.Lock()

    def _family(self, cls: type, name: str, help: str, **kw: Any) -> Any:
        name = sanitize_name(name)
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._family(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._family(Gauge, name, help)

    def histogram(
        self, name: str, help: str = "", buckets: Optional[Iterable[float]] = None
    ) -> Histogram:
        return self._family(Histogram, name, help, buckets=buckets)

    def register_collector(self, fn: Callable[["MetricsRegistry"], None]) -> None:
        """``fn(registry)`` runs at every exposition; it refreshes gauges."""
        self._collectors.append(fn)

    def register_stats(
        self,
        prefix: str,
        stats: Any,
        help: str = "",
        **labels: Any,
    ) -> None:
        """Adapt one ad-hoc stats object (``as_metrics()`` protocol or a
        numeric mapping) into per-field gauges ``<prefix>_<field>``,
        re-snapshotted at every exposition so live values flow through."""

        def _collect(reg: "MetricsRegistry") -> None:
            for field, value in snapshot_stats(stats).items():
                reg.gauge(f"{prefix}_{field}", help=help).set(value, **labels)

        self.register_collector(_collect)

    # -- exposition --------------------------------------------------------

    def collect(self) -> List[_Labeled]:
        for fn in list(self._collectors):
            fn(self)
        with self._lock:
            return [self._metrics[n] for n in sorted(self._metrics)]

    def prometheus_text(self) -> str:
        """Prometheus text exposition format 0.0.4, deterministically
        ordered (families by name, samples by label set)."""
        lines: List[str] = []
        for m in self.collect():
            lines.append(f"# HELP {m.name} {m.help or m.name}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for sample_name, label_key, value in m.samples():
                if label_key:
                    body = ",".join(
                        f'{sanitize_name(k)}="{_escape_label(v)}"'
                        for k, v in label_key
                    )
                    lines.append(f"{sample_name}{{{body}}} {_fmt(value)}")
                else:
                    lines.append(f"{sample_name} {_fmt(value)}")
        return "\n".join(lines) + "\n"

    def report(self, title: Optional[str] = None) -> str:
        """Human-oriented plain report — the unified replacement for the
        per-class ad-hoc stat printing in the launch CLIs."""
        lines: List[str] = []
        if title:
            lines.append(f"-- {title} --")
        for m in self.collect():
            for sample_name, label_key, value in m.samples():
                if sample_name.endswith(("_bucket", "_sum")):
                    continue  # histogram detail stays in /metrics
                label = (
                    "{" + ",".join(f"{k}={v}" for k, v in label_key) + "}"
                    if label_key else ""
                )
                lines.append(f"  {sample_name}{label} = {_fmt(value)}")
        return "\n".join(lines)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.prometheus_text())


def parse_prometheus(text: str) -> Dict[str, List[Tuple[Dict[str, str], float]]]:
    """Minimal strict parser for the text we emit (used by the observe CLI
    and the CI smoke job to assert ``GET /metrics`` output parses).
    Raises ``ValueError`` on any malformed line."""
    out: Dict[str, List[Tuple[Dict[str, str], float]]] = {}
    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            if line.startswith("#") and not line.startswith(("# HELP ", "# TYPE ")):
                raise ValueError(f"line {ln}: malformed comment {raw!r}")
            continue
        m = _LINE.match(line)
        if not m:
            raise ValueError(f"line {ln}: malformed sample {raw!r}")
        name, labels_raw, value = m.group(1), m.group(2), m.group(3)
        labels: Dict[str, str] = {}
        if labels_raw:
            body = labels_raw[1:-1].strip()
            if body:
                for part in re.findall(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"', body):
                    labels[part[0]] = part[1]
                if len(labels) != body.count("="):
                    raise ValueError(f"line {ln}: malformed labels {raw!r}")
        out.setdefault(name, []).append((labels, float(value)))
    if not out:
        raise ValueError("no samples found")
    return out
