"""Unified observability layer (docs/observability.md).

Three pillars:

* :mod:`repro.obs.trace` — nested spans, ring-buffer flight recorder,
  deterministic Perfetto ``trace_event`` export.
* :mod:`repro.obs.metrics` — counter/gauge/histogram registry with a
  Prometheus text writer and ``as_metrics()`` stats adapters.
* :mod:`repro.obs.explain` — TuningDB-backed decision audit reports.

This package init re-exports only the stdlib-pure pillars: core modules
import ``repro.obs.trace``/``repro.obs.metrics`` from inside ``repro.core``
and ``repro.runtime``, so importing :mod:`repro.obs.explain` here (it
imports ``repro.core.db``) would create an import cycle — consumers import
it lazily (``from repro.obs import explain``).
"""
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    parse_prometheus,
    snapshot_stats,
)
from .trace import TickTimer, Tracer, current_tracer, set_tracer, use_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "parse_prometheus",
    "snapshot_stats",
    "TickTimer",
    "Tracer",
    "current_tracer",
    "set_tracer",
    "use_tracer",
]
