"""Tuning-decision explainability: reconstruct, from a :class:`TuningDB`,
*why* a shape class is running the candidate it is running.

The report assembles the full decision audit trail per entry —

* the BP echo and the emitted-space signature the final was searched under,
* warm-start seed provenance (``warm_start`` events: which sibling class
  seeded the search, at what BP distance),
* prescreen ranks vs. measured costs (``search_completed`` events record
  the cost-model ranking; ``trials`` holds what measurement then said),
* quarantine verdicts,
* the drift lifecycle (``space_invalidated``, demotions, canary events)
  and fleet adoption (``adopted_from_service``),
* the final best and how it got there.

This module may import ``repro.core`` (unlike obs.trace/obs.metrics, which
sit below core in the import graph) — import it lazily from consumers.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.core.db import TOMBSTONE_KIND, TuningDB

__all__ = ["explain_fingerprint", "explain_all", "render_report", "db_summary"]

# Event kinds that are decisions (shown in full) vs. raw telemetry.
_DECISION_KINDS = (
    "warm_start", "search_completed", "space_invalidated", "demoted",
    "retune_scheduled", "canary_start", "promoted", "rolled_back",
    "adopted_from_service", TOMBSTONE_KIND,
)


def _entry(db: TuningDB, fingerprint: str) -> Dict[str, Any]:
    entry = db._data.get(fingerprint)
    if entry is None:
        raise KeyError(f"no DB entry for fingerprint {fingerprint!r}")
    return json.loads(json.dumps(entry, default=str))


def explain_fingerprint(db: TuningDB, fingerprint: str) -> Dict[str, Any]:
    """Structured decision report for one shape-class entry."""
    entry = _entry(db, fingerprint)
    bp = entry.get("bp", {})
    best = entry.get("best") or {}
    trials = entry.get("trials", {})
    events = entry.get("events", [])
    ranked_trials = sorted(trials.items(), key=lambda kv: (kv[1], kv[0]))
    by_kind: Dict[str, List[Dict[str, Any]]] = {}
    for ev in events:
        by_kind.setdefault(ev.get("kind", "?"), []).append(ev)

    search = (by_kind.get("search_completed") or [None])[-1]
    prescreen_rank = list(search.get("prescreen_rank", [])) if search else []
    measured_rank = [k for k, _ in ranked_trials]
    # how well the cost-model prescreen ordering predicted measurement:
    # position of the measured winner in the prescreen ranking (0 = agreed)
    winner_prescreen_pos = (
        prescreen_rank.index(measured_rank[0])
        if prescreen_rank and measured_rank and measured_rank[0] in prescreen_rank
        else None
    )

    final_point = best.get("point")
    source = "untuned"
    if best:
        if by_kind.get("adopted_from_service"):
            source = "adopted_from_service"
        elif best.get("final"):
            source = "local_search"
        elif best.get("demoted"):
            source = "demoted"
        else:
            source = "interim"

    return {
        "fingerprint": fingerprint,
        "kernel": bp.get("kernel"),
        "bp": bp,
        "layer": entry.get("layer"),
        "space_signature": best.get("space_sig"),
        "warm_start": (by_kind.get("warm_start") or [None])[-1],
        "search": search,
        "prescreen_rank": prescreen_rank,
        "measured_trials": [
            {"pp": k, "cost": c} for k, c in ranked_trials
        ],
        "winner_prescreen_pos": winner_prescreen_pos,
        "quarantined": entry.get("quarantined", {}),
        "decision_events": [
            ev for ev in events if ev.get("kind") in _DECISION_KINDS
        ],
        "events_truncated": (by_kind.get(TOMBSTONE_KIND) or [None])[-1],
        "runtime_observations": len(entry.get("history", [])),
        "final": {
            "point": final_point,
            "cost": best.get("cost"),
            "final": bool(best.get("final")),
            "demoted": bool(best.get("demoted")),
            "source": source,
        } if best else None,
    }


def explain_all(
    db: TuningDB, kernel: Optional[str] = None
) -> List[Dict[str, Any]]:
    """Reports for every entry (optionally one kernel/class), sorted by
    (kernel, fingerprint) so output order is deterministic."""
    out = []
    for fp in sorted(db.fingerprints()):
        entry = db._data.get(fp, {})
        if kernel is not None and entry.get("bp", {}).get("kernel") != kernel:
            continue
        out.append(explain_fingerprint(db, fp))
    out.sort(key=lambda r: (str(r.get("kernel")), r["fingerprint"]))
    return out


def _fmt_point(point: Any) -> str:
    if isinstance(point, dict):
        return json.dumps(point, sort_keys=True)
    return str(point)


def render_report(report: Dict[str, Any]) -> str:
    """Human-readable rendering of one :func:`explain_fingerprint` report:
    the decision chain in lifecycle order (emit signature -> warm start ->
    prescreen -> measured trials -> quarantines -> drift/canary events ->
    final)."""
    lines: List[str] = []
    lines.append(
        f"class {report.get('kernel') or '?'}  "
        f"[fingerprint {report['fingerprint'][:16]}]"
    )
    bp = {k: v for k, v in report.get("bp", {}).items() if k != "kernel"}
    lines.append(f"  BP: {_fmt_point(bp)}  (layer: {report.get('layer')})")
    sig = report.get("space_signature")
    lines.append(f"  emitted-space signature: {sig or '<none recorded>'}")

    ws = report.get("warm_start")
    if ws:
        lines.append(
            f"  warm start: seeded from {str(ws.get('source_fp'))[:16]} "
            f"(bp distance {ws.get('distance')}) -> {_fmt_point(ws.get('seed'))}"
        )
    else:
        lines.append("  warm start: none (cold search)")

    search = report.get("search")
    if search:
        lines.append(
            f"  search: {search.get('evaluations')} measured evaluations, "
            f"{search.get('prescreen_evaluations')} prescreen scores"
        )
        if report.get("prescreen_rank"):
            lines.append("  prescreen rank (cost model, best first):")
            for i, pp in enumerate(report["prescreen_rank"]):
                lines.append(f"    #{i}: {pp}")
    else:
        lines.append("  search: no search_completed event recorded")

    trials = report.get("measured_trials", [])
    if trials:
        lines.append(f"  measured trials ({len(trials)}, best first):")
        final = report.get("final") or {}
        winner_pp = _fmt_point(final.get("point")) if final.get("point") else None
        for i, t in enumerate(trials[:10]):
            mark = "  <- winner" if (
                winner_pp and _fmt_point(json.loads(t["pp"])) == winner_pp
            ) else ""
            lines.append(f"    #{i}: {t['pp']} @ {t['cost']:.3e}{mark}")
        if len(trials) > 10:
            lines.append(f"    ... {len(trials) - 10} more")
        pos = report.get("winner_prescreen_pos")
        if pos is not None:
            lines.append(
                f"  prescreen vs measurement: measured winner was "
                f"prescreen rank #{pos}"
            )
    else:
        lines.append("  measured trials: none")

    q = report.get("quarantined", {})
    if q:
        lines.append(f"  quarantined ({len(q)}):")
        for pp, rec in sorted(q.items()):
            lines.append(f"    {pp}: {rec.get('reason')}")

    tomb = report.get("events_truncated")
    if tomb:
        lines.append(
            f"  NOTE: {tomb.get('count')} older events truncated "
            f"(t {tomb.get('oldest_t')}..{tomb.get('newest_t')})"
        )
    evs = [
        ev for ev in report.get("decision_events", [])
        if ev.get("kind") not in ("warm_start", "search_completed",
                                  TOMBSTONE_KIND)
    ]
    if evs:
        lines.append(f"  lifecycle events ({len(evs)}):")
        for ev in evs:
            extra = {k: v for k, v in ev.items() if k not in ("kind", "t", "seq")}
            lines.append(f"    t={ev.get('t')}: {ev.get('kind')} {_fmt_point(extra)}")

    nobs = report.get("runtime_observations", 0)
    if nobs:
        lines.append(f"  run-time layer: {nobs} live observations recorded")

    final = report.get("final")
    if final:
        state = (
            "final" if final["final"] else
            "demoted" if final["demoted"] else "interim"
        )
        lines.append(
            f"  decision: {_fmt_point(final['point'])} @ {final['cost']:.3e} "
            f"({state}, via {final['source']})"
        )
    else:
        lines.append("  decision: none recorded")
    return "\n".join(lines)


def db_summary(db: TuningDB) -> Dict[str, float]:
    """Registry-ready roll-up of a DB's contents (the ``report`` subcommand
    and the service's ``/metrics`` gauge source)."""
    entries = len(db._data)
    finals = demoted = trials = quarantined = events = truncated = 0
    for entry in db._data.values():
        best = entry.get("best") or {}
        finals += 1 if best.get("final") else 0
        demoted += 1 if best.get("demoted") else 0
        trials += len(entry.get("trials", {}))
        quarantined += len(entry.get("quarantined", {}))
        evs = entry.get("events", [])
        events += len(evs)
        truncated += sum(
            int(e.get("count", 0)) for e in evs
            if e.get("kind") == TOMBSTONE_KIND
        )
    return {
        "entries": entries, "finals": finals, "demoted": demoted,
        "trials": trials, "quarantined": quarantined,
        "events": events, "events_truncated": truncated,
        "db_events": len(db.db_events()),
    }
