"""Structured tracing: nested spans, a ring-buffer flight recorder, and
deterministic Chrome/Perfetto ``trace_event`` JSON export.

Design constraints (docs/observability.md):

* **Stdlib-only, no repro imports** — core modules (tuner, engine, fleet)
  import this module, so it must sit below everything else in the import
  graph.
* **Zero-cost when disabled** — instrumented seams guard with
  ``tr = current_tracer()`` / ``if tr is not None`` and the dispatch fast
  path (:meth:`AutotunedOp.__call__`) carries *no* tracer code at all; the
  guard lives only on slow paths.  The ``bench_dispatch`` >=10x gate and the
  ``obs_overhead`` <=2% gate in ``benchmarks/`` enforce this.
* **Deterministic export** — the clock is injectable (the engine passes its
  virtual clock / a :class:`TickTimer`), timestamps are rounded to integer
  microseconds, and :meth:`Tracer.to_json` sorts events and track-ids
  canonically so the same run produces byte-identical trace files.

Span timestamps are *seconds* at the API (matching ``time.perf_counter``
and the engine's virtual ``now``); export converts to the integer
microseconds Perfetto expects.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterator, List, Optional

__all__ = [
    "Tracer",
    "TickTimer",
    "current_tracer",
    "set_tracer",
    "use_tracer",
]


def _us(t: float) -> int:
    """Seconds -> integer microseconds (deterministic across platforms)."""
    return int(round(float(t) * 1e6))


def _jsonable(value: Any) -> Any:
    """Coerce span attrs to JSON-safe, deterministic values."""
    if value is None or isinstance(value, (bool, int, str)):
        return value
    if isinstance(value, float):
        # round-trip-stable and finite-only: Perfetto JSON has no Infinity
        if value != value or value in (float("inf"), float("-inf")):
            return repr(value)
        return value
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _jsonable(v) for k, v in value.items()}
    return str(value)


class TickTimer:
    """Deterministic stand-in for ``time.perf_counter``: the n-th call
    returns ``n * tick_s``.  Injected into the engine (``timer=``) so a
    seeded chaos trace produces byte-identical virtual-clock timelines —
    every measured step costs exactly one tick regardless of host speed."""

    def __init__(self, tick_s: float = 1e-3):
        self.tick_s = float(tick_s)
        self.n = 0
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            self.n += 1
            return self.n * self.tick_s


class Tracer:
    """Process-local tracer with a bounded flight recorder.

    Events live in a ring buffer (``capacity`` newest events are kept, the
    ``dropped`` counter records overflow) so an always-on tracer has bounded
    memory.  Two emission styles:

    * :meth:`span` — context manager stamping ``clock()`` at enter/exit
      (wall-time instrumentation: tuner trials, fleet RPCs, background jobs).
    * :meth:`complete` / :meth:`instant` — explicit timestamps for code that
      owns its own clock (the streaming engine's virtual ``now``).
    """

    def __init__(
        self,
        clock: Callable[[], float] = time.perf_counter,
        capacity: int = 65536,
    ):
        self.clock = clock
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.emitted = 0

    # -- emission ----------------------------------------------------------

    def _emit(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self.emitted += 1
            self._events.append(ev)

    @property
    def dropped(self) -> int:
        return self.emitted - len(self._events)

    def _track(self, track: Optional[str]) -> str:
        return track if track is not None else threading.current_thread().name

    @contextmanager
    def span(
        self, name: str, cat: str = "", track: Optional[str] = None, **attrs: Any
    ) -> Iterator[Dict[str, Any]]:
        """Record a complete span around the with-block.  Yields the attrs
        dict so the body can attach results (cost, verdict, ...) before the
        span closes.  Nesting is positional: spans closed LIFO on one thread
        render as a properly nested flame on that thread's track."""
        t0 = self.clock()
        args = dict(attrs)
        try:
            yield args
        finally:
            self.complete(name, t0, self.clock(), cat=cat, track=track, **args)

    def complete(
        self,
        name: str,
        t0: float,
        t1: float,
        cat: str = "",
        track: Optional[str] = None,
        **attrs: Any,
    ) -> None:
        """Explicit-timestamp complete ("X") event; ``t0``/``t1`` seconds."""
        ts = _us(t0)
        self._emit({
            "ph": "X", "name": str(name), "cat": str(cat), "ts": ts,
            "dur": max(0, _us(t1) - ts), "track": self._track(track),
            "args": _jsonable(attrs),
        })

    def instant(
        self,
        name: str,
        t: Optional[float] = None,
        cat: str = "",
        track: Optional[str] = None,
        **attrs: Any,
    ) -> None:
        """Point-in-time ("i") event; ``t`` defaults to ``clock()``."""
        self._emit({
            "ph": "i", "name": str(name), "cat": str(cat),
            "ts": _us(self.clock() if t is None else t),
            "track": self._track(track), "args": _jsonable(attrs),
        })

    # -- inspection / export ----------------------------------------------

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(e) for e in self._events]

    def summary(self) -> Dict[str, int]:
        """Event counts keyed ``track/name`` — the span-taxonomy view."""
        out: Dict[str, int] = {}
        for e in self.events():
            key = f"{e['track']}/{e['name']}"
            out[key] = out.get(key, 0) + 1
        return dict(sorted(out.items()))

    def trace_events(self) -> List[Dict[str, Any]]:
        """Chrome ``trace_event`` array, canonically ordered.

        Track names are mapped to tids in sorted order and events are
        sorted by (ts, tid, name, canonical-json) so export is a pure
        function of the event *set* — thread interleaving during capture
        cannot change the output bytes."""
        evs = self.events()
        tracks = sorted({e["track"] for e in evs})
        tid = {t: i + 1 for i, t in enumerate(tracks)}
        out: List[Dict[str, Any]] = []
        for e in evs:
            d: Dict[str, Any] = {
                "name": e["name"], "cat": e["cat"] or "repro", "ph": e["ph"],
                "ts": e["ts"], "pid": 1, "tid": tid[e["track"]],
                "args": e["args"],
            }
            if e["ph"] == "X":
                d["dur"] = e["dur"]
            elif e["ph"] == "i":
                d["s"] = "t"
            out.append(d)
        out.sort(key=lambda d: (
            d["ts"], d["tid"], d["name"],
            json.dumps(d, sort_keys=True, default=str),
        ))
        meta = [
            {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid[t],
             "args": {"name": t}}
            for t in tracks
        ]
        return meta + out

    def to_json(self) -> str:
        return json.dumps(
            {"displayTimeUnit": "ms", "traceEvents": self.trace_events()},
            sort_keys=True, separators=(",", ":"),
        )

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


# -- process-global tracer (the instrumentation guard) ----------------------

_ACTIVE: Optional[Tracer] = None


def current_tracer() -> Optional[Tracer]:
    """The installed tracer, or ``None`` when tracing is disabled.  Every
    instrumented seam guards on this — when it returns ``None`` the cost is
    one global load + one comparison, off every hot dispatch path."""
    return _ACTIVE


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or, with ``None``, remove) the process tracer; returns the
    previous one so callers can restore it."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer
    return prev


@contextmanager
def use_tracer(tracer: Optional[Tracer]) -> Iterator[Optional[Tracer]]:
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)
