from .pipeline import (
    SyntheticLMDataset,
    ServingRequest,
    adversarial_trace,
    bursty_open_loop_trace,
    mixed_traffic_trace,
    synthetic_requests,
)

__all__ = [
    "SyntheticLMDataset",
    "ServingRequest",
    "adversarial_trace",
    "bursty_open_loop_trace",
    "mixed_traffic_trace",
    "synthetic_requests",
]
