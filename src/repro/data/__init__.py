from .pipeline import (
    SyntheticLMDataset,
    ServingRequest,
    mixed_traffic_trace,
    synthetic_requests,
)

__all__ = [
    "SyntheticLMDataset",
    "ServingRequest",
    "mixed_traffic_trace",
    "synthetic_requests",
]
