from .pipeline import SyntheticLMDataset, ServingRequest, synthetic_requests

__all__ = ["SyntheticLMDataset", "ServingRequest", "synthetic_requests"]
