"""Deterministic synthetic data pipeline.

Fault-tolerance contract: ``batch(step)`` is a pure function of
``(seed, step, topology)`` — a restarted job replays the exact token stream
from its restored step with no data-loader state to checkpoint.  This is the
standard trick for elastic training (MaxText's grain indices, etc.) reduced
to its essence for a synthetic stream.

The generator fabricates "documents": runs of tokens from a per-document
vocabulary slice with an EOS separator, so the stream has enough structure
for overfit-style convergence checks in the examples (a pure-uniform stream
is unlearnable and would hide optimizer bugs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.models.config import ModelConfig


class SyntheticLMDataset:
    def __init__(
        self,
        cfg: ModelConfig,
        global_batch: int,
        seq_len: int,
        seed: int = 0,
        doc_len: int = 128,
    ) -> None:
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.doc_len = doc_len

    def batch(
        self, step: int, host_id: int = 0, n_hosts: int = 1
    ) -> Dict[str, np.ndarray]:
        """The (host-sharded) batch for ``step``.  Pure in (seed, step)."""
        if self.global_batch % n_hosts:
            raise ValueError("global_batch must divide n_hosts")
        local = self.global_batch // n_hosts
        rows = []
        for r in range(local):
            global_row = host_id * local + r
            rows.append(self._row(step, global_row))
        tokens = np.stack(rows)  # (local, seq+1)
        out: Dict[str, np.ndarray] = {
            "tokens": tokens[:, :-1].astype(np.int32),
            "targets": tokens[:, 1:].astype(np.int32),
            "loss_mask": np.ones((local, self.seq_len), np.float32),
        }
        cfgm = self.cfg
        if cfgm.family == "vlm":
            rng = self._rng(step, 1_000_003)
            out["vision_embeds"] = rng.standard_normal(
                (local, cfgm.n_vision_tokens, cfgm.d_model), dtype=np.float32
            )
            pos = np.broadcast_to(
                np.arange(self.seq_len, dtype=np.int32), (local, self.seq_len)
            )
            out["positions"] = np.broadcast_to(pos, (3, local, self.seq_len)).copy()
            out["loss_mask"][:, : cfgm.n_vision_tokens] = 0.0
        if cfgm.is_encoder_decoder:
            rng = self._rng(step, 2_000_003)
            out["frames"] = rng.standard_normal(
                (local, cfgm.encoder_len, cfgm.d_model), dtype=np.float32
            ).astype(np.float32)
        return out

    # -- internals -------------------------------------------------------------

    def _rng(self, step: int, salt: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, salt])
        )

    def _row(self, step: int, row: int) -> np.ndarray:
        """One (seq_len+1)-token row built from synthetic documents."""
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step, row]))
        V = self.cfg.vocab_size
        eos = V - 1
        toks: List[int] = []
        need = self.seq_len + 1
        while len(toks) < need:
            # each document draws from a narrow vocab band -> learnable bigrams
            base = int(rng.integers(0, max(1, V - 64)))
            width = int(rng.integers(8, 64))
            ln = int(rng.integers(self.doc_len // 2, self.doc_len))
            walk = rng.integers(0, width, size=ln)
            toks.extend((base + np.cumsum(walk) % width).tolist())
            toks.append(eos)
        return np.asarray(toks[:need], dtype=np.int64)


# ---------------------------------------------------------------------------
# Serving-side synthetic requests
# ---------------------------------------------------------------------------


@dataclass
class ServingRequest:
    rid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int
    # open-loop arrival offset in (virtual) seconds since trace start; 0 for
    # the closed-loop traces, so every pre-stream consumer is unaffected.
    arrival_s: float = 0.0
    # absolute virtual-clock deadline: past it the hardened engine retires
    # the request ``timed_out`` instead of serving it.  None = no deadline
    # (the engine-level default TTL, if any, applies).
    deadline_s: Optional[float] = None
    # admission priority: higher admits first, and a strictly higher waiting
    # priority may preempt a lower in-flight one when the KV pool is
    # exhausted.  0 (the default) reproduces pre-hardening scheduling.
    priority: int = 0


def synthetic_requests(
    cfg: ModelConfig,
    n: int,
    prompt_len: int,
    max_new_tokens: int,
    seed: int = 0,
) -> List[ServingRequest]:
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        p = rng.integers(0, cfg.vocab_size - 1, size=prompt_len).astype(np.int32)
        out.append(ServingRequest(rid=i, prompt=p, max_new_tokens=max_new_tokens))
    return out


# (profile name, prompt-length range, new-token range): a prefill-heavy mode
# (long prompt, short completion), a decode-heavy mode (short prompt, long
# completion), and a balanced middle — the mix a real endpoint sees, and the
# load shape the traffic-class tuner (docs/serving.md) buckets.
_TRACE_MODES = (
    ("prefill_heavy", (48, 96), (2, 6)),
    ("decode_heavy", (4, 12), (16, 48)),
    ("balanced", (16, 32), (8, 16)),
)


def mixed_traffic_trace(
    cfg: ModelConfig,
    n: int,
    seed: int = 0,
    scale: float = 1.0,
) -> List[ServingRequest]:
    """A deterministic mixed prefill/decode request trace.

    Interleaves prefill-heavy, decode-heavy, and balanced requests so a
    server sees several distinct traffic classes in one pass.  ``scale``
    multiplies all lengths (e.g. 0.25 for fast CI smoke runs).
    """
    rng = np.random.default_rng(seed)
    out: List[ServingRequest] = []
    for i in range(n):
        _, (p_lo, p_hi), (t_lo, t_hi) = _TRACE_MODES[
            int(rng.integers(0, len(_TRACE_MODES)))
        ]
        plen = max(1, int(rng.integers(p_lo, p_hi + 1) * scale))
        if cfg.family == "vlm":
            # vision embeds replace the first n_vision_tokens slots of the
            # prompt; shorter prompts would be all-vision (degenerate)
            plen = max(plen, cfg.n_vision_tokens + 1)
        new = max(1, int(rng.integers(t_lo, t_hi + 1) * scale))
        prompt = rng.integers(0, cfg.vocab_size - 1, size=plen).astype(np.int32)
        out.append(ServingRequest(rid=i, prompt=prompt, max_new_tokens=new))
    return out


def bursty_open_loop_trace(
    cfg: ModelConfig,
    n: int,
    seed: int = 0,
    scale: float = 1.0,
    burst_size: int = 4,
    burst_gap_s: float = 0.05,
    jitter_s: float = 0.005,
) -> List[ServingRequest]:
    """An open-loop arrival trace: bursts of requests separated by quiet gaps.

    The request mix is exactly :func:`mixed_traffic_trace` (same seed, same
    prompts and lengths) with arrival timestamps layered on top: requests
    land in bursts of ``burst_size`` (all members of a burst arrive within
    ``jitter_s`` of the burst start), and consecutive bursts are
    ``burst_gap_s`` apart.  Open loop means arrivals do not wait for the
    server — a slow scheduler sees the queue build up, which is what the
    time-to-first-token percentiles in bench_serve_stream measure.

    Fully deterministic in ``(seed, n, scale, burst_size, burst_gap_s,
    jitter_s)``: replayable across processes for tuning and benchmarking.
    """
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    reqs = mixed_traffic_trace(cfg, n, seed=seed, scale=scale)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xB125_7]))
    for i, r in enumerate(reqs):
        burst = i // burst_size
        r.arrival_s = burst * burst_gap_s + float(rng.uniform(0.0, jitter_s))
    # within-burst jitter may reorder neighbours; keep the list sorted by
    # arrival so replay loops can admit with a simple cursor
    reqs.sort(key=lambda r: (r.arrival_s, r.rid))
    return reqs


def adversarial_trace(
    cfg: ModelConfig,
    n: int,
    seed: int = 0,
    scale: float = 1.0,
    burst_size: int = 4,
    burst_gap_s: float = 0.05,
    deadline_fraction: float = 0.5,
    deadline_ttl_s: float = 0.5,
    priority_levels: int = 3,
    malformed_rate: float = 0.0,
    max_len_hint: int = 0,
) -> List[ServingRequest]:
    """The overload/chaos trace: :func:`bursty_open_loop_trace` made hostile.

    Layers, from a separate seeded RNG (so the prompt/length mix stays
    byte-identical to the bursty trace at the same ``(seed, n, scale)``):

    * **deadlines** — a ``deadline_fraction`` subset gets an absolute
      deadline ``arrival_s + deadline_ttl_s`` (tight enough to miss under
      queueing, generous enough to make under light load);
    * **priorities** — uniform over ``[0, priority_levels)``, so the
      hardened engine's priority admission and KV-block preemption paths
      actually fire;
    * **malformed requests** — at ``malformed_rate``, a request is replaced
      by one of the malformed variants the hardened engine must absorb
      (empty prompt; ``max_new_tokens`` 0; prompt longer than the engine
      capacity ``max_len_hint`` when given): per-request validation retires
      them with ``error`` status, the un-hardened engine raises.

    Deterministic in all arguments; sorted by ``(arrival_s, rid)`` like
    every open-loop trace.
    """
    if not (0.0 <= deadline_fraction <= 1.0):
        raise ValueError(f"deadline_fraction must be in [0, 1], got {deadline_fraction}")
    if not (0.0 <= malformed_rate <= 1.0):
        raise ValueError(f"malformed_rate must be in [0, 1], got {malformed_rate}")
    if priority_levels < 1:
        raise ValueError(f"priority_levels must be >= 1, got {priority_levels}")
    reqs = bursty_open_loop_trace(
        cfg, n, seed=seed, scale=scale,
        burst_size=burst_size, burst_gap_s=burst_gap_s,
    )
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xAD5E_5]))
    for r in reqs:
        if deadline_fraction and rng.random() < deadline_fraction:
            r.deadline_s = r.arrival_s + deadline_ttl_s
        r.priority = int(rng.integers(0, priority_levels))
        if malformed_rate and rng.random() < malformed_rate:
            kind = int(rng.integers(0, 3 if max_len_hint else 2))
            if kind == 0:
                r.prompt = np.zeros((0,), dtype=np.int32)
            elif kind == 1:
                r.max_new_tokens = 0
            else:
                overlong = max_len_hint + 8
                r.prompt = rng.integers(
                    0, cfg.vocab_size - 1, size=overlong
                ).astype(np.int32)
    return reqs
