"""Transports for the global tuning service (docs/fleet.md).

The service protocol is one JSON request/response pair per operation, so a
transport is a single method: ``request(op, payload) -> response``.  Three
implementations:

* :class:`InProcessTransport` — direct calls into a live
  :class:`~repro.fleet.service.TuningService` instance.  Zero networking;
  the substrate the fault-injection transport and the benchmarks wrap.
* :class:`HTTPTransport` — stdlib ``urllib`` against the service's
  ``http.server`` endpoint (no new dependencies).  Any socket-level
  failure, non-200 status, or timeout surfaces as :class:`TransportError`
  so the client's retry/degrade machinery treats real networks and
  injected faults identically.
* :class:`FaultInjectionTransport` — the deterministic test seam: wraps any
  inner transport and injects dropped requests, dropped responses,
  duplicated deliveries, reordered (held-then-replayed) deliveries, and a
  full partition, all driven by one seeded RNG.  Every push-style
  operation in the protocol is an idempotent lattice join, which is
  exactly why this menu of faults is survivable: a retry after a dropped
  *response* re-applies a join that already landed, a held duplicate
  replays it later, and neither changes the merged state.

Faults only apply to mutating operations (``MUTATING_OPS``); read-only
pulls fail only under partition.  That mirrors reality — a lost read is
just retried — and keeps the convergence property tests focused on the
write path, where duplication/reordering could corrupt a non-CRDT store.
"""
from __future__ import annotations

import json
import random
import urllib.error
import urllib.request
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# Operations whose delivery the fault injector may drop/duplicate/reorder.
# All of them are idempotent joins (push/sync merge entries; demote is a
# flag strip that is a no-op when re-applied), so any delivery schedule
# converges — the property tests/test_db_merge_properties.py pins.
MUTATING_OPS = ("push", "sync", "demote")


class TransportError(RuntimeError):
    """A request did not complete: timeout, refused, dropped, partitioned."""


class VirtualClock:
    """A monotonic clock + sleep that advances instantly (test seam).

    The service client takes ``sleep``/``now`` callables, so backoff tests
    assert exact retry *timing* without a single real sleep.
    """

    def __init__(self, start: float = 0.0) -> None:
        self._t = float(start)
        self.sleeps: List[float] = []

    def now(self) -> float:
        return self._t

    def sleep(self, seconds: float) -> None:
        self.sleeps.append(float(seconds))
        self._t += float(seconds)


class Transport:
    """One service operation in, one response out (or TransportError)."""

    def request(self, op: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        raise NotImplementedError

    def close(self) -> None:  # pragma: no cover - nothing to release
        pass


class InProcessTransport(Transport):
    """Direct dispatch into a TuningService living in this process."""

    def __init__(self, service: Any) -> None:
        self.service = service

    def request(self, op: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        return self.service.handle(op, payload)


class HTTPTransport(Transport):
    """The service's JSON-over-HTTP endpoint via stdlib urllib."""

    def __init__(self, url: str, timeout_s: float = 5.0) -> None:
        self.url = url.rstrip("/")
        self.timeout_s = float(timeout_s)

    def request(self, op: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        body = json.dumps({"op": op, "payload": payload}, default=str).encode()
        req = urllib.request.Request(
            f"{self.url}/rpc", data=body,
            headers={"Content-Type": "application/json"},
        )
        try:
            with urllib.request.urlopen(req, timeout=self.timeout_s) as resp:
                if resp.status != 200:
                    raise TransportError(f"service returned {resp.status}")
                return json.loads(resp.read().decode())
        except TransportError:
            raise
        except (urllib.error.URLError, OSError, ValueError) as e:
            # URLError wraps socket timeouts and refused connections;
            # ValueError covers a half-written JSON body from a dying server
            raise TransportError(f"{op}: {e}") from e


@dataclass
class FaultStats:
    """What the injector actually did — asserted by tests and benchmarks."""

    requests: int = 0
    delivered: int = 0
    dropped_requests: int = 0
    dropped_responses: int = 0
    duplicated: int = 0
    reordered: int = 0
    replayed: int = 0
    partition_rejections: int = 0
    partitions: int = 0
    heals: int = 0

    @property
    def faults(self) -> int:
        return (self.dropped_requests + self.dropped_responses
                + self.duplicated + self.reordered
                + self.partition_rejections)


class FaultInjectionTransport(Transport):
    """Deterministic seeded fault injection around any inner transport.

    Per mutating request, in order, the seeded RNG may:

    * **reorder** (``reorder``): hold the request undelivered and raise —
      the client retries (a fresh delivery), and the held original is
      replayed *after* a later request, i.e. delivered out of order;
    * **drop the request** (``drop_request``): never delivered, raise;
    * **duplicate** (``duplicate``): delivered twice back to back;
    * **drop the response** (``drop_response``): delivered, but the caller
      sees a timeout — the retry double-applies the join.

    ``partition()`` fails every call (reads included) until ``heal()``,
    which also replays any held reordered requests.  All decisions come
    from one ``random.Random(seed)``, so a given (seed, call sequence) is
    exactly reproducible — the whole service stack is exercisable in CI
    with zero real networking and zero real time.
    """

    def __init__(
        self,
        inner: Transport,
        seed: int = 0,
        drop_request: float = 0.0,
        drop_response: float = 0.0,
        duplicate: float = 0.0,
        reorder: float = 0.0,
    ) -> None:
        self.inner = inner
        self.drop_request = drop_request
        self.drop_response = drop_response
        self.duplicate = duplicate
        self.reorder = reorder
        self._rng = random.Random(seed)
        self._held: List[Tuple[str, Dict[str, Any]]] = []
        self.partitioned = False
        self.stats = FaultStats()

    # -- fault control (the test's hand on the network) ----------------------

    def partition(self) -> None:
        if not self.partitioned:
            self.partitioned = True
            self.stats.partitions += 1

    def heal(self) -> None:
        """End the partition and replay held (reordered) requests."""
        if self.partitioned:
            self.partitioned = False
            self.stats.heals += 1
        self._replay_held()

    # -- Transport -----------------------------------------------------------

    def request(self, op: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        self.stats.requests += 1
        if self.partitioned:
            self.stats.partition_rejections += 1
            raise TransportError(f"{op}: network partition")
        if op in MUTATING_OPS:
            if self._rng.random() < self.reorder:
                # held: a *later* request will carry it to the service
                self._held.append((op, json.loads(json.dumps(payload,
                                                             default=str))))
                self.stats.reordered += 1
                raise TransportError(f"{op}: request delayed (reordered)")
            if self._rng.random() < self.drop_request:
                self.stats.dropped_requests += 1
                raise TransportError(f"{op}: request lost")
        resp = self._deliver(op, payload)
        self._replay_held()
        if op in MUTATING_OPS:
            if self._rng.random() < self.duplicate:
                self._deliver(op, payload)
                self.stats.duplicated += 1
            if self._rng.random() < self.drop_response:
                self.stats.dropped_responses += 1
                raise TransportError(f"{op}: response lost")
        return resp

    # -- internals -----------------------------------------------------------

    def _deliver(self, op: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        self.stats.delivered += 1
        return self.inner.request(op, payload)

    def _replay_held(self) -> None:
        while self._held:
            op, payload = self._held.pop(0)
            self.stats.replayed += 1
            self.inner.request(op, payload)
